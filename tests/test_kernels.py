"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes/dtypes/epilogues, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_pallas,
                                            decode_attention_ref)
from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_pallas)
from repro.kernels.tensor_alu import requantize, tensor_alu, tensor_alu_ref
from repro.kernels.vta_gemm import (quantized_linear, vta_gemm,
                                    vta_gemm_pallas, vta_gemm_ref)


# ----------------------------------------------------------------------
# vta_gemm
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 128)])
@pytest.mark.parametrize("epilogue", ["none", "requant", "dequant"])
def test_vta_gemm_matches_ref(shape, epilogue):
    M, N, K = shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)
    bias = jnp.asarray(rng.integers(-1000, 1000, (N,)), jnp.int32)
    scale = jnp.asarray(rng.uniform(0.001, 0.01, (N,)), jnp.float32)
    kw = dict(epilogue=epilogue, shift=7)
    if epilogue != "dequant":
        scale_arg = None
    else:
        scale_arg = scale
    got = vta_gemm(a, w, bias, scale_arg, use_pallas=True, interpret=True, **kw)
    want = vta_gemm_ref(a, w, bias, scale_arg, **kw)
    if epilogue == "dequant":
        np.testing.assert_allclose(got, want, rtol=1e-6)
    else:
        np.testing.assert_array_equal(got, want)


def test_vta_gemm_nonaligned_padding():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-128, 128, (100, 200)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (200, 72)), jnp.int8)
    got = vta_gemm(a, w, use_pallas=True, interpret=True)
    want = vta_gemm_ref(a, w)
    np.testing.assert_array_equal(got, want)


def test_vta_gemm_block_shape_sweep():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 256)), jnp.int8)
    want = vta_gemm_ref(a, w)
    for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]:
        got = vta_gemm(a, w, use_pallas=True, interpret=True,
                       bm=bm, bn=bn, bk=bk)
        np.testing.assert_array_equal(got, want)


def test_quantized_linear_close_to_float():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 256)), jnp.float32)
    w = rng.normal(size=(256, 128)).astype(np.float32) / 16
    w_amax = np.abs(w).max(axis=0)
    w_scale = jnp.asarray(w_amax / 127.0, jnp.float32)
    w_q = jnp.asarray(np.round(w / (w_amax / 127.0)), jnp.int8)
    y = quantized_linear(x, w_q, w_scale, use_pallas=True, interpret=True)
    y_ref = x @ jnp.asarray(w)
    corr = np.corrcoef(np.asarray(y).ravel(), np.asarray(y_ref).ravel())[0, 1]
    assert corr > 0.999


# ----------------------------------------------------------------------
# tensor_alu
# ----------------------------------------------------------------------
@pytest.mark.parametrize("chain", [
    (("add", 5),), (("min", 100), ("max", -100)),
    (("shr", 4),), (("shr", -2),), (("mul", 3), ("add", None)),
])
def test_tensor_alu_matches_ref(chain):
    rng = np.random.default_rng(4)
    d = jnp.asarray(rng.integers(-2**20, 2**20, (256, 256)), jnp.int32)
    s = jnp.asarray(rng.integers(-2**10, 2**10, (256, 256)), jnp.int32)
    got = tensor_alu(d, s, chain=chain, use_pallas=True, interpret=True)
    want = tensor_alu_ref(d, s, chain=chain)
    np.testing.assert_array_equal(got, want)


@given(shift=st.integers(0, 16), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_requantize_property(shift, seed):
    """requantize == truncating shift then clip, for any shift."""
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.integers(-2**24, 2**24, (8, 128)), jnp.int32)
    got = np.asarray(requantize(acc, shift, use_pallas=True, interpret=True))
    want = np.clip(np.asarray(acc) >> shift, -128, 127)
    np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------------
# flash attention (prefill)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    # (B, S, HQ, KH, D)
    (1, 512, 4, 4, 64),     # MHA
    (2, 512, 8, 2, 64),     # GQA 4:1
    (1, 1024, 4, 1, 128),   # MQA
])
def test_flash_attention_matches_ref(cfg, dtype):
    B, S, HQ, KH, D = cfg
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(B, S, HQ, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    got = flash_attention(q, k, v, causal=True, use_pallas=True,
                          interpret=True, bq=256, bk=256)
    want = flash_attention(q, k, v, causal=True, use_pallas=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, use_pallas=True,
                          interpret=True, bq=128, bk=128)
    want = flash_attention(q, k, v, causal=False, use_pallas=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_block_sweep():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 1, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 1, 64)), jnp.float32)
    want = flash_attention(q, k, v, use_pallas=False)
    for bq, bk in [(64, 128), (128, 64), (256, 512), (512, 256)]:
        got = flash_attention(q, k, v, use_pallas=True, interpret=True,
                              bq=bq, bk=bk)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5,
                                   err_msg=f"bq={bq} bk={bk}")


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    # (B, S, HQ, KH, D, kv_len)
    (2, 1024, 8, 2, 64, 1024),
    (1, 2048, 4, 4, 128, 1536),   # partial cache (padded tail)
    (4, 512, 8, 1, 64, 100),      # MQA, short cache
])
def test_decode_attention_matches_ref(cfg):
    B, S, HQ, KH, D, kv_len = cfg
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(B, 1, HQ, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    got = decode_attention(q, k, v, jnp.int32(kv_len), use_pallas=True,
                           interpret=True, bk=256)
    want = decode_attention(q, k, v, jnp.int32(kv_len), use_pallas=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_matches_prefill_last_token():
    """Decode over a cache == last row of full causal prefill."""
    rng = np.random.default_rng(9)
    B, S, HQ, KH, D = 1, 256, 4, 2, 64
    q_full = jnp.asarray(rng.normal(size=(B, S, HQ, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    full = flash_attention(q_full, k, v, causal=True, use_pallas=False)
    dec = decode_attention(q_full[:, -1:], k, v, jnp.int32(S),
                           use_pallas=True, interpret=True, bk=64)
    np.testing.assert_allclose(dec[:, 0], full[:, -1], atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------------
# gla_chunk (Mamba2 / mLSTM chunk scan)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    # (B, S, H, N, P, chunk)
    (2, 256, 3, 32, 32, 64),
    (1, 512, 2, 64, 64, 128),
    (2, 128, 4, 16, 48, 32),   # N != P (mLSTM-style)
])
def test_gla_chunk_kernel_matches_ref(cfg):
    from repro.kernels.gla_chunk import gla_chunk
    B, S, H, N, P, chunk = cfg
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.3, jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, H, N, P)) * 0.1, jnp.float32)
    y_p, h_p = gla_chunk(q, k, v, la, h0, chunk=chunk, use_pallas=True,
                         interpret=True)
    y_r, h_r = gla_chunk(q, k, v, la, h0, chunk=chunk, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               atol=3e-4, rtol=3e-4)


def test_gla_chunk_kernel_vs_recurrence():
    """Kernel against the raw step-by-step recurrence (independent of the
    model-layer oracle)."""
    from repro.kernels.gla_chunk import gla_chunk
    from repro.models.ssm import gla_step
    rng = np.random.default_rng(12)
    B, S, H, N, P = 1, 64, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, jnp.float32)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(S):
        h, yt = gla_step(h, q[:, t], k[:, t], v[:, t], jnp.exp(la[:, t]))
        ys.append(yt)
    y_ref = jnp.stack(ys, axis=1)
    y_p, h_p = gla_chunk(q, k, v, la, None, chunk=16, use_pallas=True,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h), atol=3e-4,
                               rtol=3e-4)
