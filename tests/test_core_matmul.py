"""End-to-end VTA core: schedule -> JIT -> encoded stream -> simulator."""
import numpy as np
import pytest

from repro.core import hwspec
from repro.core.isa import AluOp
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, matmul_reference,
                                  read_matmul_result, read_vector_result,
                                  schedule_matmul, schedule_vector_binop)
from repro.core.simulator import TimingModel


def _run_matmul(M, N, K, vt, epilogue=None, seed=0, spec=None):
    spec = spec or hwspec.pynq()
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=(M, K), dtype=np.int8)
    w = rng.integers(-128, 128, size=(N, K), dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_matmul(rt, a, w, epilogue=epilogue, virtual_threads=vt)
    stats = rt.synchronize()
    got = read_matmul_result(rt, plan)
    want = matmul_reference(a, w, epilogue=epilogue, spec=spec)
    np.testing.assert_array_equal(got, want)
    return stats


@pytest.mark.parametrize("vt", [1, 2])
@pytest.mark.parametrize("shape", [(16, 16, 16), (64, 64, 64), (48, 32, 80)])
def test_matmul_exact(shape, vt):
    M, N, K = shape
    _run_matmul(M, N, K, vt)


def test_matmul_large_multitile():
    _run_matmul(256, 256, 256, vt=2)


def test_matmul_with_epilogue():
    spec = hwspec.pynq()
    N = 64
    rng = np.random.default_rng(1)
    bias_n = rng.integers(-1000, 1000, size=N, dtype=np.int32)
    nb = N // spec.block_out
    bias_blocked = np.repeat(
        bias_n.reshape(nb, 1, spec.block_out), spec.batch, axis=1)
    ep = Epilogue(bias_blocked=bias_blocked, shift=6, relu=True)
    _run_matmul(64, N, 128, vt=2, epilogue=ep)


def test_matmul_timed_latency_hiding():
    """Virtual threading must improve compute utilization (Fig. 15)."""
    spec = hwspec.pynq()
    stats = {}
    for vt in (1, 2):
        rng = np.random.default_rng(0)
        a = rng.integers(-128, 128, size=(256, 256), dtype=np.int8)
        w = rng.integers(-128, 128, size=(256, 256), dtype=np.int8)
        rt = Runtime(spec)
        schedule_matmul(rt, a, w, virtual_threads=vt)
        stats[vt] = rt.synchronize(timing=TimingModel(spec))
    assert stats[2].total_cycles < stats[1].total_cycles
    assert stats[2].compute_utilization > stats[1].compute_utilization


def test_vector_add():
    spec = hwspec.pynq()
    rng = np.random.default_rng(2)
    n = 1000
    a = rng.integers(-64, 64, size=n, dtype=np.int32)
    b = rng.integers(-63, 63, size=n, dtype=np.int32)
    rt = Runtime(spec)
    c_addr, shape = schedule_vector_binop(rt, a, b, op=AluOp.ADD)
    rt.synchronize()
    got = read_vector_result(rt, c_addr, shape, n)
    want = (a + b).astype(np.int8)  # truncating out store
    np.testing.assert_array_equal(got, want)
