"""The shipped examples must run end-to-end (subprocess, defaults)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script), *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "exact int8 result ok" in out
    assert "virtual_threads=2" in out
    assert "program JIT ok" in out
    # step 8: kh*kw>1 conv on the coalesced fast path, mode surfaced
    assert "c2:direct" in out
    assert "0 eager fallbacks" in out


def test_resnet18_offload():
    out = _run("resnet18_offload.py", "C12")
    assert "exact on VTA" in out
    # the heterogeneous chain runs end to end on both engines via Program
    assert out.count("exact end-to-end") == 2
    assert "cpu step(s)" in out
    assert "stream cache hit" in out
    # the kh*kw>1 body conv stays on the coalesced fast path
    assert ":direct" in out and "0 eager fallbacks" in out


def test_train_lm_short():
    out = _run("train_lm.py", "--arch", "olmo-1b", "--steps", "40")
    assert "LEARNING" in out


def test_serve_lm():
    # pool-served greedy decode through the compiled path, 2 dialogues
    out = _run("serve_lm.py", "--sessions", "2", "--steps", "6",
               "--pool", "2")
    assert "persistent B/session" in out
    assert "ganged segments" in out
    assert "reproduce the eager numpy reference" in out
