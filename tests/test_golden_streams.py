"""Golden instruction-stream regression tests.

The scheduler's output *shape* — instruction counts per module, opcode
histogram, dependence-token balance, program-level barrier count — is a
contract: backends coalesce against it, the timing model prices it, and
silent changes (an extra load per tile, a lost WAR token, a barrier where
a drain sufficed) are exactly the regressions that keep results correct
but quietly destroy overlap or fast-path coverage.  These tests pin that
shape for one fixed schedule per lowering mode (matmul, direct conv,
im2col conv, 1x1-via-GEMM) on the pynq template.

If a change here is *intentional* (a better schedule), update the GOLDEN
table in the same commit and say why in the message.
"""
from collections import Counter

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.conv import ConvShape, schedule_conv2d
from repro.core.isa import COMPUTE_Q, LOAD_Q, STORE_Q, route_queue
from repro.core.program import Program
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue, schedule_matmul


def snapshot(rt: Runtime) -> dict:
    q = Counter(route_queue(i) for i in rt.stream)
    op = Counter(i.opcode.name for i in rt.stream)
    return dict(n=len(rt.stream),
                load=q[LOAD_Q], compute=q[COMPUTE_Q], store=q[STORE_Q],
                ops=dict(sorted(op.items())),
                balance=rt.token_balance())


_CONV = ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3, stride=1, pad=1)
_CONV_EP = Epilogue(shift=5, relu=True)
_PW = ConvShape(n=2, h=8, w=8, ic=32, oc=48, kh=1, kw=1, stride=1, pad=0)

GOLDEN = {
    # C = A@W^T requant, 64x64x64, vt=2: one macro tile per thread pair
    "matmul": dict(n=11, load=2, compute=8, store=1,
                   ops={"ALU": 3, "GEMM": 2, "LOAD": 5, "STORE": 1},
                   balance={"l2c": 0, "c2l": 1, "c2s": 0, "s2c": 1}),
    # direct conv: one GEMM per output row (oht=14 rows + reset), padded
    # 2D DMAs, 2 output-channel-block stores
    "conv_direct": dict(n=40, load=3, compute=35, store=2,
                        ops={"ALU": 4, "GEMM": 15, "LOAD": 19, "STORE": 2},
                        balance={"l2c": 0, "c2l": 1, "c2s": 0, "s2c": 1}),
    # im2col conv: kh*kw*cbt gather DMAs per k-chunk, ONE GEMM per chunk
    "conv_im2col": dict(n=109, load=76, compute=29, store=4,
                        ops={"ALU": 12, "GEMM": 8, "LOAD": 85, "STORE": 4},
                        balance={"l2c": 0, "c2l": 2, "c2s": 0, "s2c": 2}),
    # pointwise via transposed GEMM, n=2 image planes joined by a barrier
    "conv1x1": dict(n=26, load=7, compute=15, store=4,
                    ops={"ALU": 6, "GEMM": 6, "LOAD": 10, "STORE": 4},
                    balance={"l2c": 0, "c2l": 1, "c2s": 0, "s2c": 1}),
}


def _schedule(name: str) -> Runtime:
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    rt = Runtime(spec)
    if name == "matmul":
        a = rng.integers(-128, 128, size=(64, 64), dtype=np.int8)
        w = rng.integers(-128, 128, size=(64, 64), dtype=np.int8)
        schedule_matmul(rt, a, w, epilogue=Epilogue(shift=5),
                        virtual_threads=2)
    elif name in ("conv_direct", "conv_im2col"):
        x = rng.integers(-64, 64, size=(1, 32, 14, 14), dtype=np.int8)
        k = rng.integers(-16, 16, size=(32, 32, 3, 3), dtype=np.int8)
        schedule_conv2d(rt, x, k, _CONV, epilogue=_CONV_EP,
                        lowering=name.split("_")[1])
    else:
        x = rng.integers(-64, 64, size=(2, 32, 8, 8), dtype=np.int8)
        k = rng.integers(-16, 16, size=(48, 32, 1, 1), dtype=np.int8)
        schedule_conv2d(rt, x, k, _PW, epilogue=Epilogue(shift=4),
                        lowering="via_matmul")
    return rt


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_stream_shape_is_stable(name):
    got = snapshot(_schedule(name))
    assert got == GOLDEN[name], (
        f"{name} stream shape changed: {got} != {GOLDEN[name]} — if this "
        "is an intentional schedule change, update GOLDEN and justify it")


def test_streams_are_deterministic():
    """Same inputs -> byte-identical encoded streams (the JIT-cache and
    golden-test premise)."""
    for name in GOLDEN:
        s1 = _schedule(name).finalize_stream()
        s2 = _schedule(name).finalize_stream()
        np.testing.assert_array_equal(s1, s2, err_msg=name)


def _conv_chain_program():
    spec = hwspec.pynq()
    p = Program(spec)
    t = p.conv2d(p.input("x", (1, 32, 14, 14)),
                 p.input("k", (32, 32, 3, 3)), _CONV, epilogue=_CONV_EP,
                 name="body")
    p.conv2d(t, p.input("k3", (32, 32, 1, 1)),
             ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=1, kw=1,
                       stride=1, pad=0),
             epilogue=Epilogue(shift=4), name="point")
    return p


def test_program_chain_fences_and_modes():
    """A direct conv chained into a pointwise conv compiles to ONE stream
    joined by exactly one buffer fence (dependent ops), no barriers, no
    partial drains — and the fence edge, per-node lowering decisions, and
    serving arena/staging summary are all visible in describe()."""
    c = _conv_chain_program().compile(use_cache=False)
    (step,) = c.accel_steps
    assert c.insn_count == 56
    assert c.n_barriers == 0
    assert c.n_fences == 1
    assert step.fence_edges == ((2, 4),)   # body -> point
    assert step.n_drains == 0
    assert c.describe() == (
        "accel[body:direct,point:via_matmul: 56 insns, 0 barriers, "
        "1 fences (body->point)] | arena 6400B/1 blocks for "
        "1 intermediates (0 reused, 0 split) | staged 896B"
        " | tune 0 hit/2 miss")


def test_program_chain_barrier_baseline():
    """fence_mode="barrier" keeps the PR-2 full-rendezvous lowering as
    the A/B baseline: one join barrier, three more instructions."""
    c = _conv_chain_program().compile(use_cache=False, fence_mode="barrier")
    (step,) = c.accel_steps
    assert c.insn_count == 59
    assert c.n_barriers == 1
    assert c.n_fences == 0
    assert step.n_drains == 0


def _fanout_program():
    """stem feeds TWO consumers (a residual-style branch): the consumers
    must both be ordered behind the stem's final store, and the stem's
    buffer must stay live past the first consumer."""
    p = Program(hwspec.pynq())
    x = p.input("x", (32, 64))
    t = p.matmul(x, p.input("w0", (64, 64)),
                 epilogue=Epilogue(shift=5, relu=True), name="stem")
    a = p.matmul(t, p.input("w1", (64, 64)),
                 epilogue=Epilogue(shift=5, relu=True), name="left")
    b = p.matmul(t, p.input("w2", (48, 64)),
                 epilogue=Epilogue(shift=5, relu=True), name="right")
    p.output(a)
    p.output(b)
    return p


def test_program_fanout_fenced_stream_shape():
    """Golden snapshot for a fenced fan-out graph: both branch consumers
    ride buffer fences (never a barrier), the recorded fence edge names
    the in-flight producer, and the shared stem buffer is the single
    arena intermediate — the fan-out liveness contract.  The second
    consumer's fence publishes "all stores done", which includes the
    stem's, so it carries no named edge (the producer already retired
    from the live set)."""
    c = _fanout_program().compile(use_cache=False)
    (step,) = c.accel_steps
    assert c.insn_count == 40
    assert c.n_barriers == 0
    assert c.n_fences == 2
    assert step.fence_edges == ((2, 4),)     # stem -> left
    assert step.n_drains == 0
    assert c.describe() == (
        "accel[stem,left,right: 40 insns, 0 barriers, 2 fences "
        "(stem->left)] | arena 2048B/1 blocks for 1 intermediates "
        "(0 reused, 0 split) | staged 640B | tune 0 hit/3 miss")


def test_program_fanout_barrier_baseline_shape():
    c = _fanout_program().compile(use_cache=False, fence_mode="barrier")
    (step,) = c.accel_steps
    assert c.insn_count == 45
    assert c.n_barriers == 2
    assert c.n_fences == 0
    assert step.n_drains == 0
