"""Design-space autotuner tests (paper §4) + the two cost-model bugfix
regressions underneath it.

Covers: seeded search determinism, oracle-vs-measured rank sanity,
tuning-cache hit/miss accounting + invalidation on spec change, the
differential fuzzer rejecting a corrupted winner, cycle-driven conv
lowering auto-selection, and the clock-domain fix in
``DevicePool._accel_step_seconds``.
"""
import types

import numpy as np
import pytest

from repro.core import autotune, hwspec
from repro.core.autotune import (Candidate, TuningRecord, ValidationError,
                                 enumerate_candidates, matmul_workload,
                                 predict_program_cycles, search, spec_key,
                                 validate_candidate)
from repro.core.compiler import AccelStep
from repro.core.conv import (ConvShape, cheapest_conv_lowering,
                             conv2d_reference, predict_conv_cycles,
                             select_conv_lowering)
from repro.core.isa import IsaLayout
from repro.core.program import Program, op_signature
from repro.core.scheduler import Epilogue, matmul_reference
from repro.core.serve import DevicePool
from repro.core.simulator import TimingModel, replay_timing


@pytest.fixture(autouse=True)
def _pristine_global_cache():
    """Snapshot + clear the process-wide TuningCache around every test:
    searches and manual ``put``s here must never leak into other test
    files (the golden-stream tests assert exact hit/miss counts)."""
    gc = autotune.global_cache()
    snap = (dict(gc.entries), gc.hits, gc.misses)
    gc.clear()
    yield
    gc.entries, gc.hits, gc.misses = snap


# ----------------------------------------------------------------------
# candidate space
# ----------------------------------------------------------------------
def test_enumerate_candidates_feasible_and_deterministic():
    base = hwspec.pynq()
    grid = enumerate_candidates(base)
    assert grid[0] == Candidate(base, 2, None)       # baseline is always #0
    assert grid == enumerate_candidates(base)         # deterministic order
    budget = (base.inp_buff_bytes + base.wgt_buff_bytes
              + base.acc_buff_bytes)
    for c in grid:
        assert hwspec.spec_feasible(c.spec) is None, c.label()
        assert (c.spec.inp_buff_bytes + c.spec.wgt_buff_bytes
                + c.spec.acc_buff_bytes) <= budget, c.label()
    assert len({c.label() for c in grid}) == len(grid)


def test_spec_feasible_rejects_uop_budget_overflow():
    # blowing every SRAM up past the base budget widens the derived uop
    # address fields beyond the 32-bit uop word: the front-gate must say so
    big = hwspec.pynq().replace(acc_buff_bytes=32 * 1024 * 1024,
                                inp_buff_bytes=32 * 1024 * 1024)
    assert hwspec.spec_feasible(big) is not None
    assert hwspec.spec_feasible(hwspec.pynq()) is None


# ----------------------------------------------------------------------
# the search: determinism + rank sanity
# ----------------------------------------------------------------------
def _oracle_table(res):
    return [(t.candidate.label(), t.predicted_cycles, t.error)
            for t in res.trials]


def test_search_is_deterministic_for_a_fixed_seed():
    wl = matmul_workload(32, 64, 64, seed=3)
    kw = dict(seed=11, n_candidates=6, top_n=0, repeats=1,
              cache=autotune.TuningCache())
    r1 = search(wl, **kw)
    r2 = search(wl, **kw)
    # the sampled candidate set and every oracle prediction must match
    # exactly run-to-run (measured wall time is the only noisy field)
    assert _oracle_table(r1) == _oracle_table(r2)
    assert r1.candidates_total == r2.candidates_total > 6


def test_search_winner_confirmed_by_measurement_and_cached():
    """Rank sanity: the oracle's top picks, once measured, must actually
    beat the baseline — and the winner's decisions land in the cache."""
    cache = autotune.TuningCache()
    res = search(matmul_workload(64, 128, 128, seed=0), seed=0,
                 n_candidates=8, top_n=3, repeats=2, cache=cache)
    assert res.winner is not None and res.winner.validated
    assert res.winner is not res.baseline
    assert res.winner.predicted_cycles < res.baseline.predicted_cycles
    assert res.winner.measured_s < res.baseline.measured_s
    assert res.speedup_predicted > 1.0 and res.speedup_measured > 1.0
    assert res.records_written == 1 and len(cache) == 1
    ((sk, sig), rec), = cache.entries.items()
    assert sk == spec_key(res.winner.candidate.spec)
    assert sig.startswith("matmul:m64.k128.n128")
    assert rec.validated and rec.gang_width >= 1
    # serving knobs come out as a ready SchedConfig
    cfg = res.sched_config()
    assert cfg.gang_width == res.winner.gang_width
    assert 50.0 <= cfg.window_us <= 5000.0


def test_search_drops_candidates_that_fail_validation(monkeypatch):
    """A corrupted/diverging candidate is disqualified — never the
    winner, never a tuning record — and the search still completes."""
    real = autotune.validate_candidate
    calls = []

    def sabotage(compiled, feeds, refs):
        calls.append(1)
        if len(calls) > 1:      # stage 2 validates the baseline first
            raise ValidationError("injected corruption")
        real(compiled, feeds, refs)

    monkeypatch.setattr(autotune, "validate_candidate", sabotage)
    cache = autotune.TuningCache()
    res = search(matmul_workload(32, 64, 64, seed=0), seed=0,
                 n_candidates=5, top_n=2, repeats=1, cache=cache)
    dropped = [t for t in res.trials if t.validated is False]
    assert dropped, "sabotage never triggered — widen the sample"
    for t in dropped:
        assert t.error.startswith("ValidationError")
        assert t.measured_s is None
    assert res.winner is res.baseline
    for (sk, _), rec in cache.entries.items():
        assert sk == spec_key(hwspec.pynq())


def test_validate_candidate_rejects_corrupted_constants():
    """The real fuzzer path: tamper the staged constant image in device
    DRAM — both engines then agree with each other but diverge from the
    numpy reference, and validation must refuse the candidate."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(4)
    x = rng.integers(-64, 64, size=(16, 64), dtype=np.int8)
    w = rng.integers(-16, 16, size=(64, 64), dtype=np.int8)
    ep = Epilogue(shift=6)
    p = Program(spec)
    p.matmul(p.input("x", x.shape), p.constant("w", w), epilogue=ep,
             name="y")
    compiled = p.compile(use_cache=False)
    refs = {"y": matmul_reference(x, w, epilogue=ep, spec=spec)}
    validate_candidate(compiled, {"x": x}, refs)          # clean: passes
    compiled._write(compiled.input_ids["w"], w ^ np.int8(0x11))
    with pytest.raises(ValidationError, match="reference"):
        validate_candidate(compiled, {"x": x}, refs)


# ----------------------------------------------------------------------
# tuning cache: compile-time consultation + invalidation
# ----------------------------------------------------------------------
def _conv_program(spec, shape=None):
    shape = shape or ConvShape(n=1, h=8, w=8, ic=16, oc=16, kh=3, kw=3,
                               stride=1, pad=1)
    p = Program(spec)
    p.conv2d(p.input("x", (shape.n, shape.ic, shape.h, shape.w)),
             p.input("k", (shape.oc, shape.ic, shape.kh, shape.kw)),
             shape, epilogue=Epilogue(shift=5, relu=True), name="y")
    return p, shape


def test_compile_consults_cache_and_record_steers_lowering():
    spec = hwspec.pynq()
    p, shape = _conv_program(spec)
    node = next(n for n in p.nodes if n.op == "conv2d")
    sig = op_signature(p, node)

    miss = p.compile(use_cache=False)
    assert (miss.tune_hits, miss.tune_misses) == (0, 1)
    assert "tune 0 hit/1 miss" in miss.describe()
    picked = next(n for n in miss.nodes if n.op == "conv2d").lowering
    assert picked == cheapest_conv_lowering(shape, spec)[0]

    # a stored record overrides the cycle pick: force the OTHER mode
    other = "im2col" if picked == "direct" else "direct"
    autotune.global_cache().put(spec, sig, TuningRecord(lowering=other,
                                                        validated=True))
    hit = p.compile(use_cache=False)
    assert (hit.tune_hits, hit.tune_misses) == (1, 0)
    assert "tune 1 hit/0 miss" in hit.describe()
    assert next(n for n in hit.nodes if n.op == "conv2d").lowering == other

    # and the two compilations are genuinely different artifacts
    assert hit.insn_count != miss.insn_count

    # RunStats carries the counters
    rng = np.random.default_rng(0)
    x = rng.integers(-64, 64, size=(1, 16, 8, 8), dtype=np.int8)
    k = rng.integers(-16, 16, size=(16, 16, 3, 3), dtype=np.int8)
    got = hit(backend="simulator", x=x, k=k)
    np.testing.assert_array_equal(
        got, conv2d_reference(x, k, shape,
                              epilogue=Epilogue(shift=5, relu=True)))
    assert hit.last_stats[-1].tune_cache_hits == 1
    assert hit.last_stats[-1].tune_cache_misses == 0


def test_cache_records_invalidate_on_spec_change():
    spec_a = hwspec.pynq()
    p_a, _ = _conv_program(spec_a)
    node = next(n for n in p_a.nodes if n.op == "conv2d")
    autotune.global_cache().put(spec_a, op_signature(p_a, node),
                                TuningRecord(lowering="direct",
                                             validated=True))
    assert p_a.compile(use_cache=False).tune_hits == 1
    # ANY spec field change re-keys the record: same op under a
    # re-partitioned scratchpad must miss, not reuse stale decisions
    spec_b = spec_a.replace(acc_buff_bytes=64 * 1024)
    p_b, _ = _conv_program(spec_b)
    c_b = p_b.compile(use_cache=False)
    assert (c_b.tune_hits, c_b.tune_misses) == (0, 1)
    assert spec_key(spec_a) != spec_key(spec_b)


def test_cache_json_roundtrip(tmp_path):
    cache = autotune.TuningCache()
    cache.put(hwspec.pynq(), "matmul:m8.k16.n16:ep0:vt2",
              TuningRecord(lowering=None, virtual_threads=1, gang_width=2,
                           window_us=120.0, predicted_cycles=123.0,
                           measured_s=0.5, validated=True))
    path = tmp_path / "tune.json"
    cache.save(str(path))
    fresh = autotune.TuningCache(path=str(path))
    assert fresh.entries == cache.entries


# ----------------------------------------------------------------------
# cycle-driven conv lowering (never a hardcoded rule)
# ----------------------------------------------------------------------
def test_auto_conv_lowering_tracks_the_cycle_oracle():
    """The auto pick must equal the argmin of the replayed per-mode
    cycles on EVERY spec — and the two template instances below disagree
    on the answer, proving it's priced, not pattern-matched."""
    shape = ConvShape(n=1, h=56, w=56, ic=16, oc=16, kh=3, kw=3,
                      stride=1, pad=1)
    picks = {}
    for tag, spec in (("pynq", hwspec.pynq()),
                      ("calibrated", hwspec.calibrated())):
        costs = {m: predict_conv_cycles(shape, spec, m)
                 for m in ("direct", "im2col")}
        pick = select_conv_lowering(shape, spec, None)
        assert pick == min(costs, key=costs.get), (tag, costs)
        picks[tag] = pick
    # the DMA-setup/bandwidth ratio flips the winner between instances
    assert picks == {"pynq": "direct", "calibrated": "im2col"}


def test_predict_program_cycles_matches_replay():
    """The search oracle prices programs with the same decode+replay the
    serving plane uses — one number, two consumers."""
    p, _ = _conv_program(hwspec.pynq())
    compiled = p.compile(use_cache=False)
    (step,) = compiled.accel_steps
    insns = IsaLayout(compiled.spec).decode_stream(
        np.ascontiguousarray(step.stream))
    want = replay_timing(compiled.spec, insns,
                         TimingModel(compiled.spec)).total_cycles
    assert predict_program_cycles(compiled) == pytest.approx(want)


# ----------------------------------------------------------------------
# bugfix regression: pool budgets in the program's clock domain
# ----------------------------------------------------------------------
def test_accel_step_seconds_uses_program_spec_frequency():
    """serve.DevicePool._accel_step_seconds must convert replayed cycles
    at the PROGRAM's spec frequency.  Before the fix it divided by the
    module-global HOST_FIT frequency regardless of spec, so a 10x-clock
    spec got a 10x-inflated budget (and a slower-clocked one spuriously
    tight deadlines)."""
    def step_seconds(spec):
        rng = np.random.default_rng(1)
        p = Program(spec)
        p.matmul(p.input("a", (16, 32)),
                 p.constant("w", rng.integers(-64, 64, size=(32, 32),
                                              dtype=np.int8)),
                 epilogue=Epilogue(shift=6))
        compiled = p.compile(use_cache=False)
        idx = next(i for i, s in enumerate(compiled.steps)
                   if isinstance(s, AccelStep))
        pool = types.SimpleNamespace(_budget_cache={}, timing=None)
        sec = DevicePool._accel_step_seconds(pool, compiled, 0, idx)
        step = compiled.steps[idx]
        insns = IsaLayout(spec).decode_stream(
            np.ascontiguousarray(step.stream))
        cycles = replay_timing(spec, insns, TimingModel(spec)).total_cycles
        return sec, cycles

    base = hwspec.calibrated()                    # HOST_FIT clock (11 MHz)
    fast = base.replace(freq_mhz=base.freq_mhz * 10)
    sec_base, cyc_base = step_seconds(base)
    sec_fast, cyc_fast = step_seconds(fast)
    # cycles are clock-independent; seconds must scale with the spec clock
    assert cyc_base == cyc_fast
    assert sec_base == pytest.approx(cyc_base / (base.freq_mhz * 1e6))
    assert sec_fast == pytest.approx(cyc_fast / (fast.freq_mhz * 1e6))
    assert sec_base / sec_fast == pytest.approx(10.0)
