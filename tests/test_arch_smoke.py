"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one prefill + one decode step on CPU; asserts output
shapes and no NaNs (the assignment's smoke contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, reduced
from repro.models import transformer as T

BATCH, SEQ = 2, 32


def _batch_for(cfg):
    rng = jax.random.PRNGKey(7)
    text = SEQ
    batch = {}
    if cfg.frontend == "vision_stub":
        text = SEQ - cfg.n_patches
        batch["patch_emb"] = jax.random.normal(
            rng, (BATCH, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(
            rng, (BATCH, cfg.encoder_seq, cfg.d_model), jnp.float32)
    toks = jax.random.randint(rng, (BATCH, text), 0, cfg.vocab_size)
    batch["tokens"] = toks
    batch["targets"] = toks
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_train_step(arch):
    cfg = reduced(get_arch(arch).model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(
        lambda p, b: T.forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    # one SGD step moves the loss (gradients flow end to end)
    g = jax.grad(lambda p: T.forward_train(p, cfg, batch)[0])(params)
    gnorm = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), g, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: zero/NaN grads"


@pytest.mark.parametrize("arch", list_archs())
def test_arch_prefill_decode(arch):
    cfg = reduced(get_arch(arch).model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    batch.pop("targets")
    caches = T.init_caches(cfg, BATCH, SEQ + 8, jnp.float32)
    logits, caches = jax.jit(
        lambda p, b, c: T.prefill(p, cfg, b, c))(params, batch, caches)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
    logits2, caches = step(params, caches, tok, jnp.int32(SEQ))
    assert logits2.shape == (BATCH, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: decode NaN"


def test_decode_consistency_dense():
    """Prefill(S) then decode == prefill(S+1) last logits (dense arch)."""
    cfg = reduced(get_arch("olmo-1b").model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0,
                              cfg.vocab_size)
    caches = T.init_caches(cfg, 1, 32, jnp.float32)
    _, caches = T.prefill(params, cfg, {"tokens": toks[:, :15]}, caches)
    dec_logits, _ = T.decode_step(params, cfg, caches, toks[:, 15:16],
                                  jnp.int32(15))
    caches2 = T.init_caches(cfg, 1, 32, jnp.float32)
    full_logits, _ = T.prefill(params, cfg, {"tokens": toks}, caches2)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_decode_consistency_hybrid():
    """Same consistency check through mamba2 + shared-attn caches."""
    cfg = reduced(get_arch("zamba2-1.2b").model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0,
                              cfg.vocab_size)
    caches = T.init_caches(cfg, 1, 32, jnp.float32)
    _, caches = T.prefill(params, cfg, {"tokens": toks[:, :15]}, caches)
    dec_logits, _ = T.decode_step(params, cfg, caches, toks[:, 15:16],
                                  jnp.int32(15))
    caches2 = T.init_caches(cfg, 1, 32, jnp.float32)
    full_logits, _ = T.prefill(params, cfg, {"tokens": toks}, caches2)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_decode_consistency_xlstm():
    cfg = reduced(get_arch("xlstm-1.3b").model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 16), 0,
                              cfg.vocab_size)
    caches = T.init_caches(cfg, 1, 32, jnp.float32)
    _, caches = T.prefill(params, cfg, {"tokens": toks[:, :15]}, caches)
    dec_logits, _ = T.decode_step(params, cfg, caches, toks[:, 15:16],
                                  jnp.int32(15))
    caches2 = T.init_caches(cfg, 1, 32, jnp.float32)
    full_logits, _ = T.prefill(params, cfg, {"tokens": toks}, caches2)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_quantized_inference_path():
    """VTA int8 PTQ serve path: quantize linears, decode still coherent."""
    from repro.models.layers import quantize_linear_params
    cfg = reduced(get_arch("llama3.2-3b").model)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    def quantize_tree(p, path=""):
        if isinstance(p, dict) and "w" in p and p["w"].ndim >= 2 \
                and "embed" not in path and "lm_head" not in path:
            return quantize_linear_params(p)
        if isinstance(p, dict):
            return {k: quantize_tree(v, path + "/" + k) for k, v in p.items()}
        return p

    # quantize per-layer stacked linears (vmapped over the layer dim)
    qparams = dict(params)
    def q_stacked(p):
        if isinstance(p, dict) and "w" in p and p["w"].ndim == 3:
            return jax.vmap(quantize_linear_params)(p)
        if isinstance(p, dict):
            return {k: q_stacked(v) for k, v in p.items()}
        return p
    qparams["layers"] = q_stacked(params["layers"])

    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 16), 0,
                              cfg.vocab_size)
    caches = T.init_caches(cfg, 1, 32, jnp.float32)
    ql, caches = T.prefill(qparams, cfg, {"tokens": toks}, caches)
    caches2 = T.init_caches(cfg, 1, 32, jnp.float32)
    fl, _ = T.prefill(params, cfg, {"tokens": toks}, caches2)
    corr = np.corrcoef(np.asarray(ql).ravel(), np.asarray(fl).ravel())[0, 1]
    assert np.isfinite(np.asarray(ql)).all()
    assert corr > 0.98, f"int8 path diverges from float: corr={corr}"


def test_int8_kv_cache_decode_consistency():
    """VTA-style int8 KV cache must track the bf16 cache closely."""
    base = reduced(get_arch("llama3.2-3b").model)
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 24), 0,
                              base.vocab_size)
    outs = {}
    for quant in (False, True):
        cfg = base.replace(kv_cache_quant=quant)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        caches = T.init_caches(cfg, 2, 32, jnp.float32)
        _, caches = T.prefill(params, cfg, {"tokens": toks[:, :23]}, caches)
        logits, _ = T.decode_step(params, cfg, caches, toks[:, 23:24],
                                  jnp.int32(23))
        outs[quant] = np.asarray(logits)
    corr = np.corrcoef(outs[False].ravel(), outs[True].ravel())[0, 1]
    assert corr > 0.999, f"int8 KV cache diverges: corr={corr}"


def test_seq_parallel_residual_same_loss():
    """seq_parallel_residual is a layout knob — must not change the math."""
    base = reduced(get_arch("olmo-1b").model)
    batch = _batch_for(base)
    losses = {}
    for spr in (False, True):
        cfg = base.replace(seq_parallel_residual=spr)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        losses[spr] = float(T.forward_train(params, cfg, batch)[0])
    assert abs(losses[False] - losses[True]) < 1e-4
