"""Substrate tests: optimizers, data pipeline, checkpoint/restore
(+elastic resharding semantics), fault tolerance, schedules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import DataConfig, SyntheticLMDataset
from repro.distributed.fault_tolerance import (StepWatchdog,
                                               plan_elastic_restart)
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule)


# ----------------------------------------------------------------------
# optimizers
# ----------------------------------------------------------------------
def _quadratic_params():
    return {"a": jnp.array([3.0, -2.0]), "b": {"w": jnp.ones((4, 4)) * 2}}


def test_adamw_converges_quadratic():
    params = _quadratic_params()
    state = adamw_init(params)
    loss = lambda p: (jnp.sum(p["a"] ** 2) + jnp.sum(p["b"]["w"] ** 2))
    for i in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, lr=jnp.float32(0.05),
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adafactor_converges_quadratic():
    params = _quadratic_params()
    state = adafactor_init(params)
    loss = lambda p: (jnp.sum(p["a"] ** 2) + jnp.sum(p["b"]["w"] ** 2))
    for i in range(300):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(g, state, params,
                                         lr=jnp.float32(0.05))
    assert float(loss(params)) < 1e-2


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((512, 256))}
    state = adafactor_init(params)
    v = state["v"]["w"]
    assert set(v) == {"vr", "vc"}
    assert v["vr"].shape == (512,) and v["vc"].shape == (256,)


@given(norm_cap=st.floats(0.1, 10.0), scale=st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_clip_by_global_norm_property(norm_cap, scale):
    g = {"x": jnp.ones((8,)) * scale}
    clipped, norm = clip_by_global_norm(g, norm_cap)
    out_norm = float(jnp.linalg.norm(clipped["x"]))
    assert out_norm <= norm_cap * 1.001 + 1e-6 or out_norm <= float(norm)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(jnp.int32(0), 10, 100, 1.0))
    lr_peak = float(cosine_schedule(jnp.int32(10), 10, 100, 1.0))
    lr_end = float(cosine_schedule(jnp.int32(100), 10, 100, 1.0))
    assert lr0 < lr_peak
    assert abs(lr_peak - 1.0) < 1e-6
    assert lr_end < 0.01


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    ds = SyntheticLMDataset(cfg)
    b1, b2 = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(8)["tokens"], b1["tokens"])
    # shards partition the global batch deterministically
    sh0 = SyntheticLMDataset(DataConfig(vocab_size=1000, seq_len=64,
                                        global_batch=8, seed=3,
                                        n_shards=2, shard_id=0)).batch(7)
    assert sh0["tokens"].shape == (4, 64)
    # next-token alignment
    full = ds.batch(0)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["targets"][:, :-1])


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------
def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((5,))},
            "opt": {"count": jnp.int32(7)}}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        save_checkpoint(d, 42, t, extra={"step": 42})
        assert latest_step(d) == 42
        like = jax.tree.map(jnp.zeros_like, t)
        restored, extra = restore_checkpoint(d, 42, like)
        assert extra["step"] == 42
        jax.tree.map(np.testing.assert_array_equal, restored, t)


def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree(), extra={"step": s})
        ck.wait()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
        assert steps == [3, 4]
        assert latest_step(d) == 4
        assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            restore_checkpoint(d, 1, {"w": jnp.ones((4,))})


# ----------------------------------------------------------------------
# fault tolerance
# ----------------------------------------------------------------------
def test_watchdog_flags_stragglers():
    wd = StepWatchdog(slack=2.0, min_deadline_s=0.0)
    for i in range(10):
        assert not wd.end_step(i, elapsed=1.0)
    assert wd.end_step(10, elapsed=5.0)          # 5x mean -> straggler
    assert wd.straggler_events == [(10, 5.0)]
    # straggler did not poison the EMA
    assert abs(wd.mean_step_s - 1.0) < 1e-6


def test_elastic_restart_plan():
    # lose 3 of 32 data groups on a 512-chip 2-pod mesh (TP=16)
    plan = plan_elastic_restart(n_devices=512 - 3 * 16, model_parallel=16,
                                target_batch=256, pods=2)
    assert plan.mesh_shape[-1] == 16
    total = 1
    for s in plan.mesh_shape:
        total *= s
    assert total <= 512 - 3 * 16
    assert plan.global_batch <= 256
    assert 0 < plan.lr_scale <= 1.0


def test_elastic_restart_keeps_tp_whole():
    with pytest.raises(ValueError):
        plan_elastic_restart(n_devices=8, model_parallel=16,
                             target_batch=64)
