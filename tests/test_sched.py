"""Edge-of-envelope suite for the continuous-batching control plane.

Every scheduler path that decides WHO runs TOGETHER — window timeout,
deadline expiry, shed-oldest backpressure, mixed-program admission — is
driven explicitly and byte-diffed against serial execution of the same
compiled artifact: batching is a latency/throughput policy, never a
numerics policy.  The failure-is-loud contract of the pool underneath is
regression-tested by killing a slot mid-flight (a parked AND an active
request must raise :class:`SlotDied` naming the request — never hang)
and by a host op that throws (the exception surfaces at ``wait`` with
the request id attached).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.program import Program, compile_multi
from repro.core.sched import (VMAP_INTERPRET_CLIFF, DeadlineExpired,
                              QueueFull, SchedConfig, Scheduler, Shed,
                              auto_gang_width, predict_gang_cycles)
from repro.core.scheduler import Epilogue, matmul_reference
from repro.core.serve import DevicePool, PoolClosed, SlotDied

BACKENDS = ("simulator", "pallas")
_EP = Epilogue(shift=6)


def _linear(rng, m=16, d=32, seed_tag=0):
    """One-matmul serving program (constant weight) + reference."""
    w = rng.integers(-64, 64, size=(d, d), dtype=np.int8)
    p = Program()
    x = p.input("x", (m, d))
    p.output(p.matmul(x, p.constant(f"w{seed_tag}", w), epilogue=_EP))

    def make():
        return {"x": rng.integers(-64, 64, size=(m, d), dtype=np.int8)}

    def ref(feed):
        return matmul_reference(feed["x"], w, _EP)

    return p, make, ref


def _hostful(rng, hostfn, m=16, d=32):
    """matmul -> host -> matmul: the multi-segment shape whose mid-stream
    host stage exercises the pool's host worker."""
    w1 = rng.integers(-64, 64, size=(d, d), dtype=np.int8)
    w2 = rng.integers(-64, 64, size=(d, d), dtype=np.int8)
    p = Program()
    x = p.input("x", (m, d))
    t = p.matmul(x, p.constant("w1", w1), epilogue=_EP)
    t = p.host(hostfn, t, shape=(m, d), kind="mat")
    p.output(p.matmul(t, p.constant("w2", w2), epilogue=_EP))

    def make():
        return {"x": rng.integers(-64, 64, size=(m, d), dtype=np.int8)}

    def ref(feed):
        a = matmul_reference(feed["x"], w1, _EP)
        return matmul_reference(np.asarray(hostfn(a)), w2, _EP)

    return p, make, ref


# ----------------------------------------------------------------------
# admission-window edges (satellite: scheduler edge tests)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_window_timeout_releases_gang_of_one(backend):
    """A lone request under a gang_width the traffic never fills must
    release alone when the window lapses — correct, counted, exact."""
    rng = np.random.default_rng(0)
    p, make, ref = _linear(rng)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=2, backend=backend) as pool:
        sched = Scheduler(pool, SchedConfig(window_us=2000.0,
                                            gang_width=2))
        feed = make()
        t0 = time.perf_counter()
        out = sched.submit(**feed).wait(timeout=60)
        waited = time.perf_counter() - t0
        np.testing.assert_array_equal(out, ref(feed))
        st = sched.stats()[0]
        assert st.window_timeouts == 1 and st.releases == 1
        assert st.full_releases == 0
        assert waited >= 0.002, "released before the window lapsed"
        sched.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_window_releases_without_timeout(backend):
    """gang_width submits arriving together release as one full gang
    immediately (no timeout), and match serial byte for byte."""
    rng = np.random.default_rng(1)
    p, make, ref = _linear(rng)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=4, backend=backend) as pool:
        sched = Scheduler(pool, SchedConfig(window_us=200000.0,
                                            gang_width=4))
        feeds = [make() for _ in range(4)]
        futs = [sched.submit(**f) for f in feeds]
        for f, feed in zip(futs, feeds):
            np.testing.assert_array_equal(f.wait(timeout=60), ref(feed))
        st = sched.stats()[0]
        assert st.full_releases == 1 and st.window_timeouts == 0
        assert st.max_gang == 4 or backend == "simulator"
        sched.close()


def test_deadline_expires_while_parked():
    """A parked request whose deadline lapses before release fails with
    DeadlineExpired (typed, names the request) — and the loss shows up
    in stats.expired, never silently."""
    rng = np.random.default_rng(2)
    p, make, _ = _linear(rng)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=2, backend="simulator") as pool:
        sched = Scheduler(pool, SchedConfig(window_us=500000.0,
                                            gang_width=2))
        f = sched.submit(deadline_us=1000.0, **make())
        with pytest.raises(DeadlineExpired, match=r"request #\d+"):
            f.wait(timeout=60)
        assert sched.stats()[0].expired == 1
        # the lane still works after the expiry
        out = sched.submit(**make())
        sched.flush()
        out.wait(timeout=60)
        sched.close()


def test_backpressure_reject_and_shed_oldest():
    """queue_cap is a hard bound: reject raises QueueFull at submit;
    shed_oldest evicts the OLDEST parked request with a typed Shed."""
    rng = np.random.default_rng(3)
    p, make, ref = _linear(rng)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=4, backend="simulator") as pool:
        sched = Scheduler(pool, SchedConfig(
            window_us=500000.0, gang_width=4, queue_cap=2,
            policy="reject"))
        f1, f2 = sched.submit(**make()), sched.submit(**make())
        with pytest.raises(QueueFull, match="admission queue"):
            sched.submit(**make())
        assert sched.stats()[0].rejected == 1
        sched.flush()
        f1.wait(timeout=60)
        f2.wait(timeout=60)
        sched.close()

    with DevicePool(compiled, size=4, backend="simulator") as pool:
        sched = Scheduler(pool, SchedConfig(
            window_us=500000.0, gang_width=4, queue_cap=2,
            policy="shed_oldest"))
        feeds = [make() for _ in range(3)]
        futs = [sched.submit(**f) for f in feeds]
        with pytest.raises(Shed, match=r"request #\d+"):
            futs[0].wait(timeout=60)            # oldest was evicted
        sched.flush()
        for f, feed in zip(futs[1:], feeds[1:]):
            np.testing.assert_array_equal(f.wait(timeout=60), ref(feed))
        st = sched.stats()[0]
        assert st.shed == 1 and st.completed == 2
        sched.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_mixed_programs_release_separately_and_never_gang(backend):
    """Two co-staged programs through ONE pool and ONE scheduler: the
    window groups per program, every accelerator gang is program-pure,
    and both output streams match their serial baselines."""
    rng = np.random.default_rng(4)
    pa, make_a, ref_a = _linear(rng, d=32, seed_tag=0)
    pb, make_b, ref_b = _hostful(
        rng, lambda a: np.ascontiguousarray(a[::-1]))
    ca, cb = compile_multi([pa, pb])
    assert not ca.image_range.overlaps(cb.image_range)

    gangs = []
    orig = DevicePool._exec_accel

    def spy(self, prog, step, group):
        assert all(s.active.prog is prog for s in group), \
            "mixed-program gang — admission isolation broken"
        gangs.append((id(prog), len(group)))
        return orig(self, prog, step, group)

    DevicePool._exec_accel = spy
    try:
        with DevicePool([ca, cb], size=4, backend=backend) as pool:
            sched = Scheduler(pool, SchedConfig(window_us=3000.0,
                                                gang_width=2))
            feeds = [(make_a(), 0) if i % 2 == 0 else (make_b(), 1)
                     for i in range(8)]
            futs = [sched.submit(program=pi, **f) for f, pi in feeds]
            for fut, (feed, pi) in zip(futs, feeds):
                want = (ref_a, ref_b)[pi](feed)
                np.testing.assert_array_equal(fut.wait(timeout=120),
                                              want)
            sa, sb = sched.stats()
            assert sa.completed == 4 and sb.completed == 4
            assert sa.releases >= 1 and sb.releases >= 1
            sched.close()
    finally:
        DevicePool._exec_accel = orig
    assert len({pid for pid, _ in gangs}) == 2, \
        "both programs must reach the accelerator"


def test_sched_matches_serial_randomized_arrivals():
    """Poisson-ish arrival jitter through the window on both engines —
    byte-identical to serial on every request (the tentpole acceptance
    invariant in miniature)."""
    rng = np.random.default_rng(5)
    p, make, ref = _linear(rng)
    compiled = p.compile(use_cache=False)
    for backend in BACKENDS:
        with DevicePool(compiled, size=4, backend=backend) as pool:
            sched = Scheduler(pool, SchedConfig(window_us=800.0))
            feeds = [make() for _ in range(16)]
            futs = []
            for f in feeds:
                futs.append(sched.submit(**f))
                time.sleep(float(rng.random()) * 0.002)
            for fut, feed in zip(futs, feeds):
                np.testing.assert_array_equal(fut.wait(timeout=120),
                                              ref(feed))
            sched.close()


# ----------------------------------------------------------------------
# gang-width auto-tuning
# ----------------------------------------------------------------------
def test_auto_gang_width_respects_the_vmap_cliff():
    """The tuner widens gangs while amortized cost drops and stops at
    the interpret-mode recompile cliff: with the cliff far away it takes
    everything offered; with tiles already past the cliff, wider gangs
    stop paying and the walk stops early."""
    rng = np.random.default_rng(6)
    p, _, _ = _linear(rng, m=16, d=32)
    compiled = p.compile(use_cache=False)
    assert auto_gang_width(compiled, max_width=1) == 1
    wide = auto_gang_width(compiled, max_width=8,
                           cliff=VMAP_INTERPRET_CLIFF * 64)
    assert 1 <= wide <= 8
    narrow = auto_gang_width(compiled, max_width=8, cliff=1)
    assert narrow <= wide, (narrow, wide)
    # cost model sanity: per-request cycles never increase when the
    # cliff is effectively infinite
    c1 = predict_gang_cycles(compiled, 1, cliff=10 ** 9)
    c4 = predict_gang_cycles(compiled, 4, cliff=10 ** 9)
    assert c4 <= c1 * 4


def test_auto_gang_width_decodes_stream_costs_once(monkeypatch):
    """Bugfix regression: the width walk used to re-run the decode +
    TimingModel replay (``_stream_costs``) for EVERY candidate width.
    The evaluation is now hoisted out of the loop and memoized on the
    CompiledProgram, so one tuner call — and every later consumer,
    scheduler or autotuner — costs exactly one decode."""
    import repro.core.sched as sched_mod
    rng = np.random.default_rng(6)
    p, _, _ = _linear(rng, m=16, d=32)
    compiled = p.compile(use_cache=False)

    calls = []
    real = sched_mod._stream_costs

    def spy(c, tm=None):
        calls.append(1)
        return real(c, tm)

    monkeypatch.setattr(sched_mod, "_stream_costs", spy)
    w = auto_gang_width(compiled, max_width=4)
    assert 1 <= w <= 4
    assert len(calls) == 1, "costs must be evaluated once, not per width"
    # a second tuner call (and the autotuner's oracle) hit the memo
    auto_gang_width(compiled, max_width=8)
    sched_mod.stream_costs(compiled)
    assert len(calls) == 1


def test_config_validation():
    with pytest.raises(ValueError):
        SchedConfig(window_us=-1.0)
    with pytest.raises(ValueError):
        SchedConfig(gang_width=0)
    with pytest.raises(ValueError):
        SchedConfig(queue_cap=0)
    with pytest.raises(ValueError):
        SchedConfig(policy="drop_newest")
    with pytest.raises(ValueError):
        SchedConfig(pipeline_depth=0)


# ----------------------------------------------------------------------
# failure-is-loud regressions (satellite: PoolFuture error propagation)
# ----------------------------------------------------------------------
def test_kill_slot_mid_flight_raises_never_hangs():
    """Kill the only slot while a request is INSIDE its host stage and
    another is parked behind it: both waits raise SlotDied naming the
    request, a later submit refuses loudly, and close() stays clean."""
    entered, release = threading.Event(), threading.Event()

    def blocker(a):
        entered.set()
        release.wait(timeout=60)
        return np.ascontiguousarray(a[::-1])

    rng = np.random.default_rng(7)
    p, make, _ = _hostful(rng, blocker)
    compiled = p.compile(use_cache=False)
    pool = DevicePool(compiled, size=1, backend="simulator")
    try:
        f_active = pool.submit(**make())
        assert entered.wait(timeout=60), "request never reached host"
        f_parked = pool.submit(**make())
        failed = pool.kill_slot(0)
        assert failed == 2
        with pytest.raises(SlotDied, match=r"request #\d+ .*slot 0"):
            f_active.wait(timeout=60)
        with pytest.raises(SlotDied, match=r"request #\d+ .*slot 0"):
            f_parked.wait(timeout=60)
        with pytest.raises(PoolClosed):
            pool.submit(**make())
        assert "[DEAD]" in pool.describe()
    finally:
        release.set()
        pool.close()


def test_kill_one_slot_of_many_spares_the_rest():
    rng = np.random.default_rng(8)
    p, make, ref = _linear(rng)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=3, backend="simulator") as pool:
        pool.kill_slot(1)
        feeds = [make() for _ in range(6)]
        futs = [pool.submit(**f) for f in feeds]
        for f, feed in zip(futs, feeds):
            np.testing.assert_array_equal(f.wait(timeout=60), ref(feed))
        assert pool.slot_stats()[1].calls == 0


def test_host_exception_surfaces_at_wait_with_request_id():
    """A host op that throws fails THAT future (original exception type,
    request id attached) and leaves the pool serving."""
    boom = {"n": 0}

    def sometimes(a):
        boom["n"] += 1
        if boom["n"] == 1:
            raise ValueError("host stage exploded")
        return np.ascontiguousarray(a[::-1])

    rng = np.random.default_rng(9)
    p, make, ref = _hostful(rng, sometimes)
    compiled = p.compile(use_cache=False)
    with DevicePool(compiled, size=1, backend="simulator") as pool:
        with pytest.raises(ValueError, match="host stage exploded"):
            pool.submit(**make()).wait(timeout=60)
        feed = make()
        got = pool.submit(**feed).wait(timeout=60)
        boom["n"] = 1    # reference path must take the non-raising branch
        np.testing.assert_array_equal(got, ref(feed))


def test_kill_slot_fails_scheduler_futures_typed():
    """SlotDied crosses the scheduler boundary: a windowed request whose
    released gang lands on a dying slot raises SlotDied at the
    SchedFuture, and the scheduler keeps serving."""
    entered, release = threading.Event(), threading.Event()

    def blocker(a):
        entered.set()
        release.wait(timeout=60)
        return np.ascontiguousarray(a[::-1])

    rng = np.random.default_rng(10)
    p, make, ref = _hostful(rng, blocker)
    compiled = p.compile(use_cache=False)
    pool = DevicePool(compiled, size=2, backend="simulator")
    try:
        sched = Scheduler(pool, SchedConfig(window_us=200.0,
                                            gang_width=1))
        f = sched.submit(**make())
        assert entered.wait(timeout=60)
        victim = next(s.id for s in pool.slots
                      if s.active is not None or s.queue)
        pool.kill_slot(victim)
        release.set()
        with pytest.raises(SlotDied):
            f.wait(timeout=60)
        assert sched.stats()[0].failed == 1
        feed = make()
        np.testing.assert_array_equal(
            sched.submit(**feed).wait(timeout=60), ref(feed))
        sched.close()
    finally:
        release.set()
        pool.close()


# ----------------------------------------------------------------------
# introspection
# ----------------------------------------------------------------------
def test_describe_dumps_scheduler_config_and_queue_depths():
    rng = np.random.default_rng(11)
    pa, _, _ = _linear(rng, seed_tag=0)
    pb, _, _ = _linear(rng, seed_tag=1)
    ca, cb = compile_multi([pa, pb])
    with DevicePool([ca, cb], size=2, backend="simulator") as pool:
        sched = Scheduler(pool, SchedConfig(window_us=1500.0,
                                            gang_width=2,
                                            queue_cap=64,
                                            policy="shed_oldest"))
        text = sched.describe()
        for needle in ("sched[window 1500us", "cap 64", "shed_oldest",
                       "vmap cliff", "2 program(s)", "q0"):
            assert needle in text, f"describe() missing {needle!r}:\n{text}"
        assert sched.queue_depths() == [0, 0]
        sched.close()
