"""Persistent-state programs: the third DRAM liveness class, end to end.

Covers the contract from every layer's side:

  * compiler/program: persistent buffers live at stable addresses
    outside the arena, their init images stage once at compile time,
    host ops mutate them in place (``host(updates=...)``), and the
    observability surface (describe / RunStats) reports them;
  * arena: best-fit now SPLITS free blocks, so a small intermediate
    carves what it needs out of a big dead block instead of hoarding it;
  * serving: every pool session is an isolated copy of the program's
    persistent state — interleaved sessions byte-match serial
    per-session runs on both engines, both fence modes, pool sizes
    1/2/4 — and a 64-step decode loop performs ZERO DRAM allocation
    after warmup (counter-asserted on trimmed clones);
  * models: the quantized 2-block decoder (KV caches persistent,
    attention as a host segment) is bit-exact against its eager numpy
    reference through the full compiled + pooled stack.
"""
import numpy as np
import pytest

from repro.core import hwspec
from repro.core.program import Program, clear_compile_cache
from repro.core.scheduler import Epilogue
from repro.core.serve import DevicePool
from repro.models.vta_decoder import DecoderConfig, QuantDecoder

ENGINES = ("simulator", "pallas")


# ----------------------------------------------------------------------
# program/compiler level
# ----------------------------------------------------------------------
def _accumulator_program(m=16, k=64):
    """matmul -> host op that accumulates into a persistent buffer."""
    p = Program(hwspec.pynq())
    x = p.input("x", (m, k))
    w = p.constant("w", np.random.default_rng(0).integers(
        -8, 8, (k, k), dtype=np.int8))
    h = p.matmul(x, w, epilogue=Epilogue(shift=5), name="h")
    state = p.persistent("state", (m, k))

    def accum(hv, sv):
        ns = np.clip(sv.astype(np.int32) + hv, -128, 127).astype(np.int8)
        return ns, ns

    y = p.host(accum, h, state, shape=(m, k), kind="mat",
               key="test.accum", updates=(state,))
    p.output(y)
    return p


@pytest.mark.parametrize("backend", ENGINES)
def test_persistent_state_advances_across_calls(backend):
    c = _accumulator_program().compile(use_cache=False)
    x = np.ones((16, 64), np.int8)
    first = c(backend=backend, x=x)
    for i in range(2, 5):
        out = c(backend=backend, x=x)
        np.testing.assert_array_equal(out, (first.astype(np.int32) * i)
                                      .clip(-128, 127).astype(np.int8))
    # the state buffer holds exactly the last output
    np.testing.assert_array_equal(c.read_persistent("state"), out)


def test_persistent_excluded_from_inputs_and_staged_once():
    c = _accumulator_program().compile(use_cache=False)
    # neither the constant nor the persistent buffer is a call input
    with pytest.raises(ValueError, match="inputs mismatch"):
        c(x=np.zeros((16, 64), np.int8),
          state=np.zeros((16, 64), np.int8))
    c.check_inputs({"x": np.zeros((16, 64), np.int8)})
    # init image was staged at compile time: zeros before any call
    assert not c.read_persistent("state").any()
    assert c.persistent_bytes == 16 * 64
    assert c.persistent_names == ["state"]


def test_persistent_stable_address_outside_arena():
    c = _accumulator_program().compile(use_cache=False)
    (sid,) = c.persistent_ids
    addr = c.addrs[sid]
    nbytes = c.nodes[sid].meta.nbytes(c.spec)
    for nid, a in c.addrs.items():
        if nid == sid or c.nodes[nid].op != "input":
            continue
        other = c.nodes[nid].meta.nbytes(c.spec)
        assert a + other <= addr or addr + nbytes <= a, \
            "persistent buffer overlaps another stable buffer"
    # address is identical across calls by construction (it is never
    # reassigned); describe() exposes it for capacity planning
    assert f"state@{addr:#x}" in c.describe()
    assert f"persistent {c.persistent_bytes}B" in c.describe()


def test_runstats_carry_persistent_bytes():
    c = _accumulator_program().compile(use_cache=False)
    c(x=np.zeros((16, 64), np.int8))
    assert c.last_stats, "expected at least one accel segment"
    assert all(s.persistent_bytes == c.persistent_bytes
               for s in c.last_stats)


def test_reset_and_image_roundtrip():
    c = _accumulator_program().compile(use_cache=False)
    x = np.ones((16, 64), np.int8)
    c(x=x)
    c(x=x)
    snap = c.persistent_image()
    after_two = c.read_persistent("state")
    c.reset_persistent()
    assert not c.read_persistent("state").any()
    c.load_persistent_image(snap)
    np.testing.assert_array_equal(c.read_persistent("state"), after_two)


def test_host_update_target_must_be_persistent():
    p = Program(hwspec.pynq())
    x = p.input("x", (16, 64))
    w = p.constant("w", np.zeros((64, 64), np.int8))
    h = p.matmul(x, w, epilogue=Epilogue(shift=5), name="h")
    with pytest.raises(ValueError, match="not a persistent buffer"):
        p.host(lambda a: (a, a), h, shape=(16, 64), kind="mat",
               updates=(h,))


def test_persistent_signature_distinguishes_state():
    """Two graphs identical except for the host op's `updates` set must
    not share a compile-cache signature — a cached stateless artifact
    answering for a stateful graph would silently drop the mutation."""
    clear_compile_cache()
    sigs = []
    for persist in (True, False):
        p = Program(hwspec.pynq())
        x = p.input("x", (16, 64))
        w = p.constant("w", np.ones((64, 64), np.int8))
        h = p.matmul(x, w, epilogue=Epilogue(shift=5), name="h")
        s = p.persistent("s", (16, 64))
        upd = (s,) if persist else ()
        p.host(lambda hv, sv: (hv, sv) if persist else hv, h, s,
               shape=(16, 64), kind="mat", key="sig.t", updates=upd,
               name="u")
        sigs.append(p.signature())
    assert sigs[0] != sigs[1]


# ----------------------------------------------------------------------
# arena best-fit block splitting
# ----------------------------------------------------------------------
def test_arena_split_reuses_big_block_for_small_tensor():
    """A big intermediate dies; a small later intermediate must carve a
    chunk out of its block (split) instead of allocating fresh DRAM, and
    the leftover tail must stay usable."""
    p = Program(hwspec.pynq())
    x = p.input("x", (64, 64))
    w_big = p.constant("wb", np.random.default_rng(1).integers(
        -8, 8, (256, 64), dtype=np.int8))
    big = p.matmul(x, w_big, epilogue=Epilogue(shift=5),
                   name="big")                      # (64, 256): 16384B

    def shrink(bv):
        return np.ascontiguousarray(bv[:, :64])

    # h1 is big's LAST reader, so big's block is free by the time h2
    # allocates — and h2 (4096B) must carve it out of big's 16384B block
    h1 = p.host(shrink, big, shape=(64, 64), kind="mat",
                key="test.shrink", name="h1")
    h2 = p.host(lambda tv: np.clip(tv.astype(np.int32) * 2, -128, 127)
                .astype(np.int8), h1, shape=(64, 64), kind="mat",
                key="test.double", name="h2")
    w2 = p.constant("w2", np.random.default_rng(2).integers(
        -8, 8, (64, 64), dtype=np.int8))
    t1 = p.matmul(h2, w2, epilogue=Epilogue(shift=5), name="t1")
    p.output(t1)
    c = p.compile(use_cache=False)
    assert c.arena_reuse_hits >= 1
    assert c.arena_splits >= 1, c.describe()
    assert f"{c.arena_splits} split" in c.describe()
    # and the graph still computes what the numpy oracle says
    from repro.core.scheduler import matmul_reference
    xs = np.random.default_rng(3).integers(-16, 16, (64, 64), np.int8)
    got = c(x=xs)
    big_v = matmul_reference(xs, c.nodes[c.input_ids["wb"]].const,
                             Epilogue(shift=5))
    h2_v = np.clip(np.ascontiguousarray(big_v[:, :64]).astype(np.int32)
                   * 2, -128, 127).astype(np.int8)
    want = matmul_reference(h2_v, c.nodes[c.input_ids["w2"]].const,
                            Epilogue(shift=5))
    np.testing.assert_array_equal(got, want)


def test_arena_split_tail_stays_aligned():
    """Every arena block (including split tails) starts at an
    arena_align multiple — a split can never hand out an address a DMA
    layout cannot live at."""
    from repro.core.compiler import ArenaAllocator
    allocs = []

    def bump(nbytes, align):
        base = (sum(allocs) + align - 1) // align * align
        allocs.append(nbytes)
        return base

    ar = ArenaAllocator(bump, 256)
    a1 = ar.alloc(1000, last_use=1)      # rounds to 1024
    ar.release_dead(2)
    a2 = ar.alloc(300, last_use=3)       # best-fit into the 1024 block
    assert a2 == a1                      # reused the dead block
    assert ar.splits == 1
    ar.release_dead(4)
    a3 = ar.alloc(200, last_use=5)       # the split tail serves this one
    assert a3 % 256 == 0
    assert a3 == a1 + 512                # 300->512, tail at +512
    assert ar.bytes == 1024              # no fresh DRAM after the first


# ----------------------------------------------------------------------
# serving: session isolation across the pool
# ----------------------------------------------------------------------
_SMALL = DecoderConfig(d_model=32, n_blocks=1, n_heads=2, d_ff=64,
                       vocab=16, s_max=24, seed=5)


@pytest.mark.parametrize("fence_mode", ("buffer", "barrier"))
@pytest.mark.parametrize("backend", ENGINES)
@pytest.mark.parametrize("size", (1, 2, 4))
def test_session_isolation(size, backend, fence_mode):
    """Two interleaved sessions on one pool never observe each other's
    KV bytes: every step's output and the final KV-cache images
    byte-match serial per-session executions on a private device."""
    dec = QuantDecoder(_SMALL)
    c = dec.compile(use_cache=False, fence_mode=fence_mode)
    steps = 6
    rng = np.random.default_rng(99)
    xs = [[rng.integers(-32, 32, (1, 32), np.int8) for _ in range(steps)]
          for _ in range(2)]

    # serial oracle: each session alone on its own trimmed clone
    serial_out = []
    serial_state = []
    for sess_xs in xs:
        dev = c.device.clone(trim=True)
        serial_out.append([c.run_on(dev, backend=backend, inputs={"x": x})
                           .outputs for x in sess_xs])
        serial_state.append({name: c.read_persistent(name, device=dev)
                             for name in c.persistent_names})

    with DevicePool(c, size=size, backend=backend) as pool:
        s0, s1 = pool.session(), pool.session()
        for t in range(steps):
            f0 = s0.submit(x=xs[0][t])
            f1 = s1.submit(x=xs[1][t])
            np.testing.assert_array_equal(
                f0.wait(120), serial_out[0][t],
                err_msg=f"session 0 diverged at step {t} "
                        f"(size={size} {backend} {fence_mode})")
            np.testing.assert_array_equal(
                f1.wait(120), serial_out[1][t],
                err_msg=f"session 1 diverged at step {t} "
                        f"(size={size} {backend} {fence_mode})")
        pool.drain()
        for si, sess in enumerate((s0, s1)):
            for name in c.persistent_names:
                np.testing.assert_array_equal(
                    sess.state(name), serial_state[si][name],
                    err_msg=f"session {si} KV bytes contaminated "
                            f"({name}, size={size} {backend} "
                            f"{fence_mode})")


@pytest.mark.parametrize("backend", ENGINES)
def test_decoder_pool_64_steps_bitexact_and_dram_flat(backend):
    """Acceptance criterion: the 2-block quantized decoder decodes >=64
    autoregressive steps through a DevicePool bit-exact against the
    eager numpy reference, with ZERO DRAM allocation per step after
    warmup (allocation-count asserted on the trimmed slot clones)."""
    dec = QuantDecoder(DecoderConfig(d_model=64, n_blocks=2, n_heads=2,
                                     d_ff=128, vocab=32, s_max=72,
                                     seed=11))
    c = dec.compile(use_cache=False)
    n_sessions = 2
    with DevicePool(c, size=2, backend=backend) as pool:
        sessions = [pool.session() for _ in range(n_sessions)]
        refs = [dec.reference() for _ in range(n_sessions)]
        rng = np.random.default_rng(13)
        marks = None
        for t in range(64):
            xs = [rng.integers(-32, 32, (1, 64), np.int8)
                  for _ in range(n_sessions)]
            futs = [s.submit(x=x) for s, x in zip(sessions, xs)]
            for f, r, x in zip(futs, refs, xs):
                np.testing.assert_array_equal(
                    f.wait(300), r.step(x),
                    err_msg=f"decode diverged at step {t} ({backend})")
            if t == 1:
                pool.drain()
                marks = [len(s.device.dram._allocs) for s in pool.slots]
        pool.drain()
        assert marks == [len(s.device.dram._allocs)
                         for s in pool.slots], \
            "DRAM allocation count grew during the decode loop"
        if backend == "pallas":
            # same-step sessions must share kernel launches (gangs)
            assert any(st.ganged_steps > 0 for st in pool.slot_stats())
        # describe() reports the per-slot session accounting
        assert "sessions" in pool.describe()


def test_decoder_kernel_attention_matches_reference():
    """attention="kernel" routes the host segment through the
    decode_attention Pallas op; the compiled path stays bit-exact
    against the reference (which shares the same fn)."""
    dec = QuantDecoder(DecoderConfig(d_model=32, n_blocks=1, n_heads=2,
                                     d_ff=64, vocab=16, s_max=8, seed=3,
                                     attention="kernel"))
    c = dec.compile(use_cache=False)
    ref = dec.reference()
    for t in range(4):
        x = dec.token(t)
        np.testing.assert_array_equal(c(backend="pallas", x=x),
                                      ref.step(x))


def test_kv_cache_overflow_raises():
    dec = QuantDecoder(DecoderConfig(d_model=32, n_blocks=1, n_heads=2,
                                     d_ff=64, vocab=16, s_max=2, seed=3))
    c = dec.compile(use_cache=False)
    c(x=dec.token(0))
    c(x=dec.token(1))
    with pytest.raises(RuntimeError, match="KV cache overflow"):
        c(x=dec.token(2))
