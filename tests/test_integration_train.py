"""Integration: training must actually learn, checkpoint-resume must be
bit-consistent, and the int8 serve path must track the float path."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.launch.train import Trainer


def test_train_loss_decreases_and_resumes():
    cfg = reduced(get_arch("olmo-1b").model).replace(max_seq=128)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, seq_len=128, global_batch=8, ckpt_dir=d,
                     peak_lr=3e-3, seed=1)
        hist = tr.train(60, log_every=1000, ckpt_every=30)
        assert hist["loss"][-1] < hist["loss"][0] - 0.2, \
            f"no learning: {hist['loss'][0]} -> {hist['loss'][-1]}"

        # resume from checkpoint and verify the next step is deterministic
        tr2 = Trainer(cfg, seq_len=128, global_batch=8, ckpt_dir=d,
                      peak_lr=3e-3, seed=1)
        assert tr2.maybe_restore()
        assert tr2.step == 60
        h_a = tr.train(3, log_every=1000)
        h_b = tr2.train(3, log_every=1000)
        np.testing.assert_allclose(h_a["loss"], h_b["loss"], rtol=1e-5)


def test_train_moe_arch_learns():
    cfg = reduced(get_arch("phi3.5-moe-42b-a6.6b").model).replace(max_seq=128)
    tr = Trainer(cfg, seq_len=128, global_batch=8, peak_lr=3e-3, seed=2)
    hist = tr.train(50, log_every=1000)
    assert hist["loss"][-1] < hist["loss"][0] - 0.15


def test_train_ssm_arch_learns():
    cfg = reduced(get_arch("zamba2-1.2b").model).replace(max_seq=128)
    tr = Trainer(cfg, seq_len=128, global_batch=8, peak_lr=3e-3, seed=3)
    hist = tr.train(50, log_every=1000)
    assert hist["loss"][-1] < hist["loss"][0] - 0.15
