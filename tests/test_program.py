"""Program-level JIT: compile multi-op graphs into one task-ISA stream.

Acceptance criteria of the API redesign:
  * a single Program chaining >= 3 ops (matmul MLP; conv stack with a
    cpu_only segment) compiles to one validated stream and runs bit-exact
    against the per-op references on BOTH execution backends;
  * a second invocation with new data hits the JIT cache — no
    re-scheduling (stream-build counter flat), still bit-exact;
  * cross-op WAR/RAW tokens make composed schedules safe in one stream
    (join_barrier), and the strengthened validator statically rejects
    streams where a pop precedes its matching push.
"""
from unittest import mock

import numpy as np
import pytest

from repro.core import hwspec
from repro.core import program as program_mod
from repro.core.conv import (ConvShape, conv2d_reference, read_conv_result,
                             schedule_conv2d)
from repro.core.isa import AluOp, COMPUTE_Q, STORE_Q
from repro.core.program import Program
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, matmul_reference,
                                  read_matmul_result, schedule_matmul)
from repro.core.simulator import DeadlockError, Simulator

BACKENDS = ("simulator", "pallas")


# ----------------------------------------------------------------------
# graph fixtures
# ----------------------------------------------------------------------
def _mlp(rng):
    """3-matmul MLP with requant/relu epilogues + its numpy reference."""
    x = rng.integers(-128, 128, size=(48, 64), dtype=np.int8)
    w1 = rng.integers(-128, 128, size=(64, 64), dtype=np.int8)
    w2 = rng.integers(-128, 128, size=(32, 64), dtype=np.int8)
    w3 = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    eps = (Epilogue(shift=6, relu=True), Epilogue(shift=6), Epilogue(shift=4))

    p = Program()
    h = p.matmul(p.input("x", (48, 64)), p.input("w1", (64, 64)),
                 epilogue=eps[0])
    h = p.matmul(h, p.input("w2", (32, 64)), epilogue=eps[1])
    p.matmul(h, p.input("w3", (32, 32)), epilogue=eps[2])

    ref = matmul_reference(x, w1, eps[0])
    ref = matmul_reference(ref, w2, eps[1])
    ref = matmul_reference(ref, w3, eps[2])
    return p, dict(x=x, w1=w1, w2=w2, w3=w3), ref


def _conv_chain(rng):
    """cpu_only C1-style conv -> 3x3 conv -> 1x1 conv (fast path)."""
    s1 = ConvShape(n=1, h=16, w=16, ic=3, oc=32, kh=7, kw=7, stride=2, pad=3)
    s2 = ConvShape(n=1, h=8, w=8, ic=32, oc=32, kh=3, kw=3, stride=1, pad=1)
    s3 = ConvShape(n=1, h=8, w=8, ic=32, oc=48, kh=1, kw=1, stride=1, pad=0)
    x = rng.integers(-64, 64, size=(1, 3, 16, 16), dtype=np.int8)
    k1 = rng.integers(-8, 8, size=(32, 3, 7, 7), dtype=np.int8)
    k2 = rng.integers(-8, 8, size=(32, 32, 3, 3), dtype=np.int8)
    k3 = rng.integers(-8, 8, size=(48, 32, 1, 1), dtype=np.int8)
    ep = Epilogue(shift=5, relu=True)

    p = Program()
    t = p.conv2d(p.input("x", x.shape), p.input("k1", k1.shape), s1,
                 epilogue=ep, cpu_only=True)
    t = p.conv2d(t, p.input("k2", k2.shape), s2, epilogue=ep)
    p.conv2d(t, p.input("k3", k3.shape), s3, epilogue=ep)

    ref = conv2d_reference(x, k1, s1, epilogue=ep)
    ref = conv2d_reference(ref, k2, s2, epilogue=ep)
    ref = conv2d_reference(ref, k3, s3, epilogue=ep)
    return p, dict(x=x, k1=k1, k2=k2, k3=k3), ref


# ----------------------------------------------------------------------
# acceptance: chained graphs, one stream, two engines
# ----------------------------------------------------------------------
def test_mlp_chain_single_stream_both_backends():
    p, feeds, ref = _mlp(np.random.default_rng(0))
    compiled = p.compile(use_cache=False)
    # one finalized stream for the whole 3-op chain
    assert len(compiled.accel_steps) == 1
    assert not compiled.cpu_steps
    assert compiled.insn_count > 0
    for backend in BACKENDS:
        got = compiled(backend=backend, **feeds)
        np.testing.assert_array_equal(got, ref, err_msg=backend)


def test_conv_chain_heterogeneous_segments():
    p, feeds, ref = _conv_chain(np.random.default_rng(1))
    compiled = p.compile(use_cache=False)
    # C1 runs host-side, the two accelerator convs share one stream
    assert len(compiled.cpu_steps) == 1
    assert len(compiled.accel_steps) == 1
    for backend in BACKENDS:
        got = compiled(backend=backend, **feeds)
        np.testing.assert_array_equal(got, ref, err_msg=backend)


def test_jit_cache_second_call_does_not_reschedule():
    rng = np.random.default_rng(2)
    p, feeds, ref = _mlp(rng)
    compiled = p.compile()
    first = {b: compiled(backend=b, **feeds) for b in BACKENDS}
    for b in BACKENDS:
        np.testing.assert_array_equal(first[b], ref)

    # rebind with fresh data: the stream-build counter must stay flat
    feeds2 = dict(feeds)
    feeds2["x"] = rng.integers(-128, 128, size=(48, 64), dtype=np.int8)
    builds = program_mod.STREAM_BUILDS
    second = {b: compiled(backend=b, **feeds2) for b in BACKENDS}
    assert program_mod.STREAM_BUILDS == builds, \
        "second call re-ran scheduling"
    ref2 = matmul_reference(feeds2["x"], feeds["w1"],
                            Epilogue(shift=6, relu=True))
    ref2 = matmul_reference(ref2, feeds["w2"], Epilogue(shift=6))
    ref2 = matmul_reference(ref2, feeds["w3"], Epilogue(shift=4))
    for b in BACKENDS:
        np.testing.assert_array_equal(second[b], ref2, err_msg=b)

    # structurally identical graph -> cached compiled artifact, no rebuild
    p2, _, _ = _mlp(np.random.default_rng(2))
    builds = program_mod.STREAM_BUILDS
    assert p2.compile() is compiled
    assert program_mod.STREAM_BUILDS == builds


def test_independent_ops_overlap_without_barrier():
    """The liveness pass gives independent ops disjoint SRAM partitions:
    they share the stream with only a stale-token drain between them."""
    rng = np.random.default_rng(3)
    a1 = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    w1 = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    a2 = rng.integers(-128, 128, size=(48, 64), dtype=np.int8)
    w2 = rng.integers(-128, 128, size=(16, 64), dtype=np.int8)
    p = Program()
    y1 = p.matmul(p.input("a1", a1.shape), p.input("w1", w1.shape),
                  epilogue=Epilogue(shift=4), name="y1")
    y2 = p.matmul(p.input("a2", a2.shape), p.input("w2", w2.shape),
                  epilogue=Epilogue(shift=5), name="y2")
    p.output(y1)
    p.output(y2)
    compiled = p.compile(use_cache=False)
    (step,) = compiled.accel_steps
    assert step.n_barriers == 0
    assert step.n_drains == 1
    for backend in BACKENDS:
        outs = compiled(backend=backend, a1=a1, w1=w1, a2=a2, w2=w2)
        np.testing.assert_array_equal(
            outs["y1"], matmul_reference(a1, w1, Epilogue(shift=4)))
        np.testing.assert_array_equal(
            outs["y2"], matmul_reference(a2, w2, Epilogue(shift=5)))


def test_duplicate_node_names_rejected():
    rng = np.random.default_rng(20)
    p = Program()
    a = p.input("a", (16, 16))
    w = p.input("w", (16, 16))
    p.matmul(a, w, name="y")
    with pytest.raises(ValueError, match="duplicate"):
        p.matmul(a, w, name="y")
    with pytest.raises(ValueError, match="duplicate"):
        p.input("a", (16, 16))


def test_cpu_step_splits_segments_between_independent_ops():
    """Ops separated by a host step land in different streams (and must
    not hedge SRAM for an overlap that can never happen)."""
    rng = np.random.default_rng(21)
    a = rng.integers(-128, 128, size=(16, 16), dtype=np.int8)
    w = rng.integers(-128, 128, size=(16, 16), dtype=np.int8)
    p = Program()
    m1 = p.matmul(p.input("a", (16, 16)), p.input("w", (16, 16)),
                  epilogue=Epilogue(shift=3), name="m1")
    relay = p.host(lambda v: v.astype(np.int32).reshape(-1) * 2, m1,
                   shape=(256,), kind="vec", dtype="int32", key="scale2",
                   name="relay")
    v = p.vector_binop(relay, relay, op=AluOp.ADD, name="v")
    p.output(m1)
    p.output(v)
    compiled = p.compile(use_cache=False)
    assert len(compiled.accel_steps) == 2
    assert len(compiled.cpu_steps) == 1
    ref_m = matmul_reference(a, w, Epilogue(shift=3))
    ref_v = (ref_m.reshape(-1).astype(np.int64) * 4).astype(np.int32) \
        .astype(np.int8)
    for backend in BACKENDS:
        outs = compiled(backend=backend, a=a, w=w)
        np.testing.assert_array_equal(outs["m1"], ref_m, err_msg=backend)
        np.testing.assert_array_equal(outs["v"], ref_v, err_msg=backend)


def test_dependent_ops_get_fences_or_barriers():
    p, _, _ = _mlp(np.random.default_rng(4))
    compiled = p.compile(use_cache=False)
    (step,) = compiled.accel_steps
    # each chained matmul rides a buffer fence off its producer...
    assert step.n_barriers == 0
    assert step.n_fences == 2
    assert step.fence_edges == ((2, 4), (4, 6))
    # ...and the barrier baseline still lowers the old way
    baseline = p.compile(use_cache=False, fence_mode="barrier")
    (bstep,) = baseline.accel_steps
    assert bstep.n_barriers == 2
    assert bstep.n_fences == 0


def test_mixed_graph_matmul_and_vector_binop():
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    va = rng.integers(-1000, 1000, size=600, dtype=np.int32)
    vb = rng.integers(-1000, 1000, size=600, dtype=np.int32)
    p = Program()
    m = p.matmul(p.input("a", a.shape), p.input("w", w.shape),
                 epilogue=Epilogue(shift=4), name="m")
    v = p.vector_binop(p.input("va", (600,), dtype="int32"),
                       p.input("vb", (600,), dtype="int32"),
                       op=AluOp.ADD, name="v")
    p.output(m)
    p.output(v)
    compiled = p.compile(use_cache=False)
    for backend in BACKENDS:
        outs = compiled(backend=backend, a=a, w=w, va=va, vb=vb)
        np.testing.assert_array_equal(
            outs["m"], matmul_reference(a, w, Epilogue(shift=4)))
        np.testing.assert_array_equal(outs["v"], (va + vb).astype(np.int8))


# ----------------------------------------------------------------------
# 1x1-conv fast path
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,hw,ic,oc", [(1, 14, 64, 64), (2, 8, 32, 48)])
def test_conv1x1_fast_path_exact(n, hw, ic, oc):
    """C3/C8/C11-style pointwise convs lowered through the transposed GEMM
    schedule match the conv oracle on both engines."""
    spec = hwspec.pynq()
    shape = ConvShape(n=n, h=hw, w=hw, ic=ic, oc=oc, kh=1, kw=1,
                      stride=1, pad=0)
    rng = np.random.default_rng(hw * ic + oc)
    x = rng.integers(-128, 128, size=(n, ic, hw, hw), dtype=np.int8)
    w = rng.integers(-128, 128, size=(oc, ic, 1, 1), dtype=np.int8)
    ep = Epilogue(shift=5, relu=True)
    want = conv2d_reference(x, w, shape, epilogue=ep)
    for backend in BACKENDS:
        rt = Runtime(spec)
        plan = schedule_conv2d(rt, x, w, shape, epilogue=ep, via_matmul=True)
        rt.synchronize(backend=backend)
        np.testing.assert_array_equal(read_conv_result(rt, plan), want,
                                      err_msg=backend)


def test_conv1x1_fast_path_hits_pallas_gemm():
    """The fast path must resolve through vta_gemm tiles, not the eager
    per-uop GEMM loop."""
    spec = hwspec.pynq()
    shape = ConvShape(n=1, h=8, w=8, ic=32, oc=32, kh=1, kw=1,
                      stride=1, pad=0)
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, size=(1, 32, 8, 8), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 32, 1, 1), dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_conv2d(rt, x, w, shape, epilogue=Epilogue(shift=4),
                           via_matmul=True)
    with mock.patch.object(Simulator, "_do_gemm",
                           side_effect=AssertionError("eager GEMM taken")):
        rt.synchronize(backend="pallas")
    np.testing.assert_array_equal(
        read_conv_result(rt, plan),
        conv2d_reference(x, w, shape, epilogue=Epilogue(shift=4)))


def test_conv1x1_batch_blocked_through_program():
    """The old spec.batch==1 restriction is gone: a batch-blocked template
    instance auto-selects via_matmul for pointwise convs and stays exact
    on both engines."""
    spec = hwspec.HardwareSpec(batch=2)
    shape = ConvShape(n=3, h=6, w=6, ic=32, oc=32, kh=1, kw=1,
                      stride=1, pad=0)
    rng = np.random.default_rng(17)
    x = rng.integers(-64, 64, size=(3, 32, 6, 6), dtype=np.int8)
    w = rng.integers(-16, 16, size=(32, 32, 1, 1), dtype=np.int8)
    ep = Epilogue(shift=4, relu=True)
    p = Program(spec)
    p.conv2d(p.input("x", x.shape), p.input("w", w.shape), shape,
             epilogue=ep, name="pw")
    compiled = p.compile(use_cache=False)
    assert "pw:via_matmul" in compiled.describe()
    ref = conv2d_reference(x, w, shape, epilogue=ep)
    for backend in BACKENDS:
        np.testing.assert_array_equal(
            compiled(backend=backend, x=x, w=w), ref, err_msg=backend)


def test_conv_lowering_validated_at_build_time():
    """Infeasible lowering choices fail in Program.conv2d() with an
    actionable message, not deep inside a lowering pass."""
    p = Program()
    x = p.input("x", (1, 32, 8, 8))
    w = p.input("w", (32, 32, 3, 3))
    strided = ConvShape(n=1, h=8, w=8, ic=32, oc=32, kh=3, kw=3,
                        stride=2, pad=1)
    with pytest.raises(ValueError, match="im2col.*stride=1.*direct"):
        p.conv2d(x, w, strided, lowering="im2col")
    with pytest.raises(ValueError, match="via_matmul.*pointwise"):
        p.conv2d(x, w, strided, lowering="via_matmul")
    with pytest.raises(ValueError, match="unknown conv lowering"):
        p.conv2d(x, w, strided, lowering="winograd")
    with pytest.raises(ValueError, match="cpu_only"):
        p.conv2d(x, w, strided, cpu_only=True, lowering="direct")
    # failed adds leave the graph untouched and usable
    p.conv2d(x, w, strided, name="ok")
    assert p.compile(use_cache=False).insn_count > 0


# ----------------------------------------------------------------------
# vector-ALU fast path in PallasBackend
# ----------------------------------------------------------------------
@pytest.mark.parametrize("op,ref_fn", [
    (AluOp.ADD, lambda a, b: (a.astype(np.int64) + b).astype(np.int32)),
    (AluOp.MAX, lambda a, b: np.maximum(a, b).astype(np.int32)),
    (AluOp.MUL, lambda a, b: (a.astype(np.int64) * b).astype(np.int32)),
])
def test_vector_alu_fast_path_no_eager_fallback(op, ref_fn):
    """schedule_vector_binop chunks must coalesce into tensor_alu kernel
    calls on PallasBackend — the eager numpy ALU loop is never taken —
    and stay exact including int32 wraparound."""
    from repro.core.scheduler import read_vector_result, \
        schedule_vector_binop
    spec = hwspec.pynq().replace(acc_buff_bytes=4 * 1024,
                                 out_buff_bytes=4 * 1024)
    rng = np.random.default_rng(int(op))
    n = 600                       # multiple chunks
    a = rng.integers(-2 ** 30, 2 ** 30, size=n, dtype=np.int32)
    b = rng.integers(-2 ** 30, 2 ** 30, size=n, dtype=np.int32)
    rt = Runtime(spec)
    c_addr, shape = schedule_vector_binop(rt, a, b, op=op)
    with mock.patch.object(Simulator, "_do_alu",
                           side_effect=AssertionError("eager ALU taken")):
        rt.synchronize(backend="pallas")
    got = read_vector_result(rt, c_addr, shape, n)
    np.testing.assert_array_equal(got, ref_fn(a, b).astype(np.int8))


# ----------------------------------------------------------------------
# cross-op tokens + strengthened validator
# ----------------------------------------------------------------------
def test_join_barrier_makes_composed_schedules_safe():
    """Two matmuls composed into ONE stream share every scratchpad; the
    barrier's cross-op tokens keep them exact on both engines (without
    per-op synchronize round-trips)."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(11)
    a = rng.integers(-128, 128, size=(64, 64), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 64), dtype=np.int8)
    for backend in BACKENDS:
        rt = Runtime(spec)
        p1 = schedule_matmul(rt, a, w, virtual_threads=2)
        rt.join_barrier()
        p2 = schedule_matmul(rt, w, a, virtual_threads=2)
        rt.synchronize(backend=backend)
        np.testing.assert_array_equal(read_matmul_result(rt, p1),
                                      matmul_reference(a, w), err_msg=backend)
        np.testing.assert_array_equal(read_matmul_result(rt, p2),
                                      matmul_reference(w, a), err_msg=backend)


def _deadlocking_runtime():
    """Net-zero token balance, but the store's pop precedes the compute
    push it needs and vice versa — a 2-cycle that deadlocks the modules.
    The old net-balance check accepted this stream."""
    rt = Runtime(hwspec.pynq())
    rt.dep_pop(STORE_Q, COMPUTE_Q)   # C1 pops s2c (pushed only by S1)
    rt.noop(COMPUTE_Q)
    rt.dep_pop(COMPUTE_Q, STORE_Q)   # S1 pops c2s (pushed only by C1)
    rt.noop(STORE_Q)
    rt.dep_push(STORE_Q, COMPUTE_Q)
    rt.dep_push(COMPUTE_Q, STORE_Q)
    return rt


def test_validator_rejects_pop_before_push():
    rt = _deadlocking_runtime()
    assert all(v == 0 for v in rt.token_balance().values())  # net-zero!
    with pytest.raises(ValueError, match="deadlock"):
        rt.validate_stream()


def test_deadlocking_stream_also_hangs_the_simulator():
    """The validator's verdict agrees with actual execution."""
    from repro.core.isa import DepFlags, FinishInsn
    from repro.core.simulator import run_program
    rt = _deadlocking_runtime()
    stream = rt.isa.encode_stream(rt.stream + [FinishInsn(dep=DepFlags())])
    with pytest.raises(DeadlockError):
        run_program(rt.spec, rt.device, stream)


def test_validator_still_accepts_all_lowered_streams():
    p, _, _ = _conv_chain(np.random.default_rng(12))
    compiled = p.compile(use_cache=False)   # finalize_stream validates
    assert compiled.insn_count > 0


# ----------------------------------------------------------------------
# models/quantized.py routed through the Program API
# ----------------------------------------------------------------------
def test_vta_linear_through_program():
    from repro.models.quantized import VtaLinear
    rng = np.random.default_rng(13)
    w = (rng.normal(size=(64, 48)) / 8).astype(np.float32)
    x = rng.normal(size=(2, 16, 64)).astype(np.float32)
    lin = VtaLinear(w)
    y = lin(x)
    ref = x @ w
    assert y.shape == (2, 16, 48)
    rms = np.sqrt(((y - ref) ** 2).mean()) / np.sqrt((ref ** 2).mean())
    assert rms < 0.05, rms
    # both engines produce the identical int8 stream result
    np.testing.assert_array_equal(y, lin(x, backend="pallas"))
    # repeated same-signature calls (same batch rows + requant shift)
    # rebind buffers, not rebuild streams
    builds = program_mod.STREAM_BUILDS
    lin(-x)     # new data, same activation scale
    assert program_mod.STREAM_BUILDS == builds
