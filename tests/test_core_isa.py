"""ISA encode/decode roundtrips + co-design fluidity (spec-derived widths)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hwspec
from repro.core.isa import (AluInsn, AluOp, DepFlags, FinishInsn, GemmInsn,
                            IsaLayout, LoadStoreInsn, MemId, Opcode,
                            route_queue, COMPUTE_Q, LOAD_Q, STORE_Q)
from repro.core.microop import UOp, UopLayout

SPECS = [hwspec.pynq(), hwspec.pynq_batch2(), hwspec.tpu_like()]


@pytest.mark.parametrize("spec", SPECS, ids=["pynq", "pynq_b2", "tpu_like"])
def test_loadstore_roundtrip(spec):
    isa = IsaLayout(spec)
    insn = LoadStoreInsn(
        opcode=Opcode.LOAD, dep=DepFlags(True, False, True, False),
        memory_type=MemId.INP, sram_base=17, dram_base=123456,
        y_size=14, x_size=28, x_stride=56, y_pad_0=1, y_pad_1=2,
        x_pad_0=3, x_pad_1=3)
    words = isa.encode(insn)
    got = isa.decode(*words)
    assert got == insn


@pytest.mark.parametrize("spec", SPECS, ids=["pynq", "pynq_b2", "tpu_like"])
def test_gemm_alu_finish_roundtrip(spec):
    isa = IsaLayout(spec)
    g = GemmInsn(dep=DepFlags(False, True, False, True), reset=False,
                 uop_bgn=5, uop_end=77, iter_out=14, iter_in=8,
                 dst_factor_out=56, dst_factor_in=1, src_factor_out=2,
                 src_factor_in=0, wgt_factor_out=0, wgt_factor_in=9)
    assert isa.decode(*isa.encode(g)) == g
    a = AluInsn(dep=DepFlags(), reset=False, uop_bgn=0, uop_end=1,
                iter_out=4, iter_in=16, dst_factor_out=16, dst_factor_in=1,
                src_factor_out=16, src_factor_in=1, alu_opcode=AluOp.SHR,
                use_imm=True, imm=-7)
    got = isa.decode(*isa.encode(a))
    assert got == a
    assert got.imm == -7  # sign-extended immediate
    f = FinishInsn(dep=DepFlags(True, True, False, False))
    assert isa.decode(*isa.encode(f)) == f


@given(dst=st.integers(0, 2047), src=st.integers(0, 2047),
       wgt=st.integers(0, 1023))
@settings(max_examples=200, deadline=None)
def test_uop_roundtrip_hypothesis(dst, src, wgt):
    lay = UopLayout(hwspec.pynq())
    u = UOp(dst, src, wgt)
    assert lay.decode(lay.encode(u)) == u


@given(y=st.integers(0, 1000), x=st.integers(0, 1000),
       stride=st.integers(0, 60000), base=st.integers(0, 2**31),
       pads=st.tuples(*[st.integers(0, 15)] * 4))
@settings(max_examples=200, deadline=None)
def test_loadstore_roundtrip_hypothesis(y, x, stride, base, pads):
    isa = IsaLayout(hwspec.pynq())
    insn = LoadStoreInsn(
        opcode=Opcode.STORE, dep=DepFlags(), memory_type=MemId.OUT,
        sram_base=0, dram_base=base, y_size=y, x_size=x, x_stride=stride,
        y_pad_0=pads[0], y_pad_1=pads[1], x_pad_0=pads[2], x_pad_1=pads[3])
    assert isa.decode(*isa.encode(insn)) == insn


def test_field_overflow_raises():
    isa = IsaLayout(hwspec.pynq())
    bad = LoadStoreInsn(opcode=Opcode.LOAD, dep=DepFlags(),
                        memory_type=MemId.INP, sram_base=1 << 20,
                        dram_base=0, y_size=1, x_size=1, x_stride=1)
    with pytest.raises(ValueError):
        isa.encode(bad)


def test_fetch_routing_rules():
    """§2.4: UOP/ACC loads -> compute queue; INP/WGT -> load queue."""
    def mk(mem, op=Opcode.LOAD):
        return LoadStoreInsn(opcode=op, dep=DepFlags(), memory_type=mem,
                             sram_base=0, dram_base=0, y_size=1, x_size=1,
                             x_stride=1)
    assert route_queue(mk(MemId.INP)) == LOAD_Q
    assert route_queue(mk(MemId.WGT)) == LOAD_Q
    assert route_queue(mk(MemId.UOP)) == COMPUTE_Q
    assert route_queue(mk(MemId.ACC)) == COMPUTE_Q
    assert route_queue(mk(MemId.OUT, Opcode.STORE)) == STORE_Q


def test_isa_adapts_to_spec():
    """Co-design fluidity: changing buffer sizes changes the encoding."""
    a = IsaLayout(hwspec.pynq())
    big = hwspec.pynq().replace(acc_buff_bytes=512 * 1024, uop_bits=64)
    b = IsaLayout(big)
    assert b.factor_bits > a.factor_bits
    la = UopLayout(hwspec.pynq())
    lb = UopLayout(big)
    assert lb.dst_bits > la.dst_bits


def test_large_template_widens_instruction_word():
    """tpu_like template needs 256-bit instructions; pynq fits in 128."""
    assert IsaLayout(hwspec.pynq()).insn_bits == 128
    assert IsaLayout(hwspec.tpu_like()).insn_bits == 256


def test_uop_width_guard():
    """A template instance whose indices don't fit 32-bit uops must be
    rejected at layout-derivation time."""
    huge = hwspec.pynq().replace(inp_buff_bytes=1 << 26,
                                 acc_buff_bytes=1 << 26,
                                 wgt_buff_bytes=1 << 26)
    with pytest.raises(ValueError):
        UopLayout(huge)
