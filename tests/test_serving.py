"""Serving fast path: buffer-granular fences, pre-staged persistent
streams + the intermediate arena, and batched Pallas tile dispatch.

Acceptance criteria of the perf PR:
  * dependent ops are joined by buffer fences whose streams pass the
    exact FIFO-replay validator and stay byte-exact vs the barrier
    baseline on BOTH engines;
  * the DRAM image is CONSTANT across >= 100 repeated CompiledProgram
    calls (pre-staged streams + constants + liveness arena), while the
    restage baseline provably grows;
  * the fence lowering beats the barrier baseline on the cycle model for
    dependent chains (weight tile double-buffers across the boundary);
  * same-structure pending tiles resolve through ONE vmapped kernel
    launch (tiles_resolved > tile_batches), bit-exact vs per-tile
    dispatch;
  * PallasBackend reports the same TimingModel cycles as the simulator
    for the same stream (calibration pathway).
"""
import numpy as np
import pytest

from repro.core import hwspec
from repro.core.backend import PallasBackend, assert_fast_path
from repro.core.conv import ConvShape, conv2d_reference
from repro.core.isa import COMPUTE_Q, LOAD_Q
from repro.core.program import Program
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue, matmul_reference, schedule_matmul
from repro.core.simulator import TimingModel

BACKENDS = ("simulator", "pallas")


def _chain(rng, layers=3, m=48, d=64):
    """Dependent matmul chain + feeds + reference."""
    x = rng.integers(-128, 128, size=(m, d), dtype=np.int8)
    ws = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
          for _ in range(layers)]
    ep = Epilogue(shift=6, relu=True)
    p = Program()
    t = p.input("x", x.shape)
    for i, w in enumerate(ws):
        t = p.matmul(t, p.input(f"w{i}", w.shape), epilogue=ep)
    feeds = {"x": x, **{f"w{i}": w for i, w in enumerate(ws)}}
    ref = x
    for w in ws:
        ref = matmul_reference(ref, w, ep)
    return p, feeds, ref


# ----------------------------------------------------------------------
# buffer fences: validated, byte-exact vs barrier, cheaper in cycles
# ----------------------------------------------------------------------
def test_fenced_stream_validates_and_matches_barrier_on_both_backends():
    p, feeds, ref = _chain(np.random.default_rng(0))
    outs = {}
    for fm in ("buffer", "barrier"):
        c = p.compile(use_cache=False, fence_mode=fm)  # finalize validates
        (step,) = c.accel_steps
        assert (step.n_fences > 0) == (fm == "buffer")
        for b in BACKENDS:
            outs[fm, b] = c(backend=b, **feeds)
            np.testing.assert_array_equal(outs[fm, b], ref,
                                          err_msg=f"{fm}/{b}")
    for b in BACKENDS:
        np.testing.assert_array_equal(outs["buffer", b], outs["barrier", b])


def test_fence_beats_barrier_on_the_cycle_model():
    """The consumer's first weight tile DMAs while the producer's
    epilogue/store tail drains — dependent layers double-buffer across
    the op boundary, which the barrier's full rendezvous forbids."""
    rng = np.random.default_rng(1)
    p, feeds, ref = _chain(rng, layers=4, m=128, d=256)
    spec = hwspec.pynq()
    cycles = {}
    for fm in ("buffer", "barrier"):
        c = p.compile(use_cache=False, fence_mode=fm)
        out = c(timing=TimingModel(spec), **feeds)
        np.testing.assert_array_equal(out, ref)
        cycles[fm] = sum(s.total_cycles for s in c.last_stats)
    assert cycles["buffer"] < cycles["barrier"], cycles
    # the win is the overlapped DMA, not noise: require >= 2%
    assert cycles["barrier"] / cycles["buffer"] > 1.02, cycles


def test_buffer_fence_primitive_is_replay_safe():
    """A hand-built producer/consumer pair joined by buffer_fence passes
    the exact FIFO replay; an unclaimed fence pop is rejected at
    finalize (the validator extension)."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(2)
    a = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    rt = Runtime(spec)
    schedule_matmul(rt, a, a, virtual_threads=2)
    rt.buffer_fence(consumer_loads=True)
    rt.dep_pop(COMPUTE_Q, LOAD_Q)
    schedule_matmul(rt, a, a, virtual_threads=2)
    rt.validate_stream()                       # deadlock-free statically
    rt.finalize_stream()                       # and encodable

    rt2 = Runtime(spec)
    schedule_matmul(rt2, a, a, virtual_threads=2)
    rt2.buffer_fence(consumer_loads=True)
    rt2.dep_pop(COMPUTE_Q, LOAD_Q)             # claimed by... nothing
    with pytest.raises(ValueError, match="never claimed"):
        rt2.finalize_stream()


def test_fence_counters_on_run_stats():
    p, feeds, _ = _chain(np.random.default_rng(3))
    c = p.compile(use_cache=False)
    c(**feeds)
    (stats,) = c.last_stats
    assert stats.n_buffer_fences == 2
    assert stats.n_join_barriers == 0
    assert stats.staging_bytes_per_call == c.last_staging_bytes > 0


# ----------------------------------------------------------------------
# pre-staged streams + constants + arena: zero per-call DRAM growth
# ----------------------------------------------------------------------
def test_dram_image_constant_across_100_calls():
    rng = np.random.default_rng(4)
    p, feeds, ref = _chain(rng)
    c = p.compile(use_cache=False)             # prestage=True default
    c(**feeds)
    mark = c.device.dram._next
    for _ in range(100):
        c(**feeds)
    assert c.device.dram._next == mark, "serving loop grew the DRAM image"
    np.testing.assert_array_equal(c(**feeds), ref)

    # the A/B baseline provably re-stages: one stream alloc per call
    # (plus up to one alignment gap each)
    base = p.compile(use_cache=False, prestage=False)
    base(**feeds)
    mark = base.device.dram._next
    for _ in range(10):
        base(**feeds)
    growth = base.device.dram._next - mark
    (step,) = base.accel_steps
    assert 10 * step.stream.nbytes <= growth \
        <= 10 * (step.stream.nbytes + 64)


def test_constants_staged_once_and_not_rebindable():
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, size=(32, 64), dtype=np.int8)
    w = rng.integers(-128, 128, size=(48, 64), dtype=np.int8)
    p = Program()
    p.matmul(p.input("x", x.shape), p.constant("w", w),
             epilogue=Epilogue(shift=5), name="y")
    c = p.compile(use_cache=False)
    ref = matmul_reference(x, w, Epilogue(shift=5))
    for b in BACKENDS:
        np.testing.assert_array_equal(c(backend=b, x=x), ref, err_msg=b)
    # constants are part of the artifact, not the per-call feed
    with pytest.raises(ValueError, match="unexpected.*w"):
        c(x=x, w=w)
    # different constant content -> different compile-cache entry
    w2 = rng.integers(-128, 128, size=(48, 64), dtype=np.int8)
    p2 = Program()
    p2.matmul(p2.input("x", x.shape), p2.constant("w", w2),
              epilogue=Epilogue(shift=5), name="y")
    c2 = p2.compile()
    assert c2 is not c
    np.testing.assert_array_equal(
        c2(x=x), matmul_reference(x, w2, Epilogue(shift=5)))


def test_arena_recycles_dead_intermediates():
    """In a deep chain every intermediate dies at its consumer; the
    liveness pass hands its block to a later layer instead of growing
    the bump allocator."""
    p, feeds, ref = _chain(np.random.default_rng(6), layers=6)
    c = p.compile(use_cache=False)
    assert c.n_intermediates == 5              # all but the final output
    assert c.arena_reuse_hits >= 3
    assert c.arena_blocks <= 2                 # steady-state footprint
    np.testing.assert_array_equal(c(**feeds), ref)
    for b in BACKENDS:
        np.testing.assert_array_equal(c(backend=b, **feeds), ref)


def test_arena_fanout_keeps_pending_readers_live():
    """ResNet-style branchy graph (stem feeding a deep main path AND a
    late skip consumer): the liveness pass must keep the stem buffer
    alive across the whole main path, recycle the main path's dead
    intermediates, and hold the arena high-water + per-call DRAM
    flatness — the residual/fan-out coverage the ROADMAP called for."""
    rng = np.random.default_rng(12)
    d = 64
    ep = Epilogue(shift=6, relu=True)
    x = rng.integers(-128, 128, size=(32, d), dtype=np.int8)
    ws = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
          for _ in range(6)]
    p = Program()
    t0 = p.matmul(p.input("x", x.shape), p.input("w0", ws[0].shape),
                  epilogue=ep, name="stem")
    t1 = p.matmul(t0, p.input("w1", ws[1].shape), epilogue=ep, name="main1")
    t2 = p.matmul(t1, p.input("w2", ws[2].shape), epilogue=ep, name="main2")
    t3 = p.matmul(t0, p.input("w3", ws[3].shape), epilogue=ep, name="skip")
    p.output(p.matmul(t2, p.input("w4", ws[4].shape), epilogue=ep,
                      name="head_a"))
    p.output(p.matmul(t3, p.input("w5", ws[5].shape), epilogue=ep,
                      name="head_b"))
    c = p.compile(use_cache=False)
    # 4 intermediates (stem, main1, main2, skip); stem is pinned by its
    # pending skip reader, so the high-water is 3 fresh blocks and only
    # one later intermediate can reuse a dead one
    assert c.n_intermediates == 4
    assert c.arena_blocks == 3, "fan-out liveness high-water changed"
    assert c.arena_reuse_hits == 1
    assert c.arena_bytes == 3 * 2048
    r0 = matmul_reference(x, ws[0], ep)
    r2 = matmul_reference(matmul_reference(r0, ws[1], ep), ws[2], ep)
    want = {"head_a": matmul_reference(r2, ws[4], ep),
            "head_b": matmul_reference(matmul_reference(r0, ws[3], ep),
                                       ws[5], ep)}
    feeds = {"x": x, **{f"w{i}": w for i, w in enumerate(ws)}}
    for b in BACKENDS:
        outs = c(backend=b, **feeds)
        for name in want:
            np.testing.assert_array_equal(outs[name], want[name],
                                          err_msg=f"{b}/{name}")
    mark = c.device.dram._next
    for _ in range(10):
        c(**feeds)
    assert c.device.dram._next == mark, "fan-out serving grew DRAM"


def test_arena_respects_liveness_across_cpu_steps():
    """A heterogeneous split (cpu_only middle conv) still reuses dead
    blocks and stays exact — host steps are DRAM liveness points."""
    s1 = ConvShape(n=1, h=8, w=8, ic=16, oc=16, kh=3, kw=3, stride=1, pad=1)
    rng = np.random.default_rng(7)
    x = rng.integers(-64, 64, size=(1, 16, 8, 8), dtype=np.int8)
    ks = [rng.integers(-8, 8, size=(16, 16, 3, 3), dtype=np.int8)
          for _ in range(3)]
    ep = Epilogue(shift=5, relu=True)
    p = Program()
    t = p.conv2d(p.input("x", x.shape), p.input("k0", ks[0].shape), s1,
                 epilogue=ep)
    t = p.conv2d(t, p.input("k1", ks[1].shape), s1, epilogue=ep,
                 cpu_only=True)
    p.conv2d(t, p.input("k2", ks[2].shape), s1, epilogue=ep)
    c = p.compile(use_cache=False)
    assert len(c.cpu_steps) == 1 and len(c.accel_steps) == 2
    ref = x
    for k in ks:
        ref = conv2d_reference(ref, k, s1, epilogue=ep)
    feeds = dict(x=x, k0=ks[0], k1=ks[1], k2=ks[2])
    for b in BACKENDS:
        np.testing.assert_array_equal(c(backend=b, **feeds), ref,
                                      err_msg=b)
    mark = c.device.dram._next
    for _ in range(5):
        c(**feeds)
    assert c.device.dram._next == mark


# ----------------------------------------------------------------------
# batched Pallas tile dispatch
# ----------------------------------------------------------------------
def test_peer_tiles_resolve_in_one_batched_launch():
    """With virtual_threads=2 the peer thread's tile is fully recorded at
    the group's first store, so both resolve through ONE vmapped vta_gemm
    launch — and the result is bit-exact vs per-tile dispatch."""
    rng = np.random.default_rng(8)
    x = rng.integers(-128, 128, size=(128, 256), dtype=np.int8)
    w = rng.integers(-128, 128, size=(256, 256), dtype=np.int8)
    p = Program()
    p.matmul(p.input("x", x.shape), p.input("w", w.shape),
             epilogue=Epilogue(shift=7), name="y")
    c = p.compile(use_cache=False)
    ref = matmul_reference(x, w, Epilogue(shift=7))

    batched = PallasBackend()
    out_b = c(backend=batched, x=x, w=w)
    (stats,) = c.last_stats
    assert stats.tiles_resolved > stats.tile_batches >= 1, \
        (stats.tiles_resolved, stats.tile_batches)
    assert_fast_path(stats)
    np.testing.assert_array_equal(out_b, ref)

    per_tile = PallasBackend(batch_tiles=False)
    out_p = c(backend=per_tile, x=x, w=w)
    (stats_p,) = c.last_stats
    assert stats_p.tiles_resolved == stats_p.tile_batches
    np.testing.assert_array_equal(out_p, ref)


def test_batched_dispatch_conv_direct_fast_path():
    """Direct-conv tiles (per-output-row sub-grids, requant epilogues)
    batch across virtual threads and stay on the zero-eager fast path."""
    shape = ConvShape(n=1, h=28, w=28, ic=32, oc=32, kh=3, kw=3,
                      stride=1, pad=1)          # 2 oh-tiles -> a vt pair
    rng = np.random.default_rng(9)
    x = rng.integers(-64, 64, size=(1, 32, 28, 28), dtype=np.int8)
    k = rng.integers(-16, 16, size=(32, 32, 3, 3), dtype=np.int8)
    ep = Epilogue(shift=5)
    p = Program()
    p.conv2d(p.input("x", x.shape), p.input("k", k.shape), shape,
             epilogue=ep, name="cv")
    c = p.compile(use_cache=False)
    out = c(backend="pallas", x=x, k=k)
    np.testing.assert_array_equal(
        out, conv2d_reference(x, k, shape, epilogue=ep))
    (stats,) = c.last_stats
    assert_fast_path(stats)
    assert stats.tiles_resolved > stats.tile_batches, \
        (stats.tiles_resolved, stats.tile_batches)


# ----------------------------------------------------------------------
# timing on both engines
# ----------------------------------------------------------------------
def test_pallas_reports_same_cycles_as_simulator():
    """Both engines price the SAME stream with the SAME TimingModel, so
    total_cycles must agree exactly — the calibrated-constants pathway
    (hwspec.calibrated) then makes those cycles predict wall-clock."""
    p, feeds, _ = _chain(np.random.default_rng(10))
    c = p.compile(use_cache=False)
    spec = hwspec.calibrated()
    tm = TimingModel(spec)
    c(backend="simulator", timing=tm, **feeds)
    sim_cycles = [s.total_cycles for s in c.last_stats]
    c(backend="pallas", timing=tm, **feeds)
    pal_cycles = [s.total_cycles for s in c.last_stats]
    assert sim_cycles == pal_cycles
    assert all(cyc > 0 for cyc in sim_cycles)
