"""HLO analyzer unit tests on synthetic HLO text (no compilation)."""
import textwrap

from repro.launch.hlo_analysis import HloStats, analyze, parse_hlo

HLO = textwrap.dedent("""
    HloModule test, is_scheduled=true

    %fused_computation (param_0: f32[10,64,64], param_1: s32[]) -> f32[64,64] {
      %param_0 = f32[10,64,64]{2,1,0} parameter(0)
      %param_1 = s32[] parameter(1)
      %constant.0 = s32[] constant(0)
      %dynamic_slice.0 = f32[1,64,64]{2,1,0} dynamic-slice(%param_0, %param_1, %constant.0, %constant.0), dynamic_slice_sizes={1,64,64}
      ROOT %bitcast.1 = f32[64,64]{1,0} bitcast(%dynamic_slice.0)
    }

    %body (arg: (s32[], f32[64,64], f32[10,64,64])) -> (s32[], f32[64,64], f32[10,64,64]) {
      %arg = (s32[], f32[64,64]{1,0}, f32[10,64,64]{2,1,0}) parameter(0)
      %constant.1 = s32[] constant(1)
      %gte.0 = s32[] get-tuple-element(%arg), index=0
      %gte.1 = f32[64,64]{1,0} get-tuple-element(%arg), index=1
      %gte.2 = f32[10,64,64]{2,1,0} get-tuple-element(%arg), index=2
      %w = f32[64,64]{1,0} fusion(%gte.2, %gte.0), kind=kLoop, calls=%fused_computation
      %dot.0 = f32[64,64]{1,0} dot(%gte.1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64]{1,0} all-reduce(%dot.0), channel_id=1, replica_groups=[4,8]<=[32], to_apply=%add
      %next = s32[] add(%gte.0, %constant.1)
      ROOT %tuple.0 = (s32[], f32[64,64]{1,0}, f32[10,64,64]{2,1,0}) tuple(%next, %ar, %gte.2)
    }

    %cond (arg2: (s32[], f32[64,64], f32[10,64,64])) -> pred[] {
      %arg2 = (s32[], /*index=1*/f32[64,64]{1,0}, f32[10,64,64]{2,1,0}) parameter(0)
      %c10 = s32[] constant(10)
      %g0 = s32[] get-tuple-element(%arg2), index=0
      ROOT %lt = pred[] compare(%g0, %c10), direction=LT
    }

    ENTRY %main (x: f32[64,64], ws: f32[10,64,64]) -> f32[64,64] {
      %x = f32[64,64]{1,0} parameter(0)
      %ws = f32[10,64,64]{2,1,0} parameter(1)
      %c0 = s32[] constant(0)
      %t = (s32[], f32[64,64]{1,0}, f32[10,64,64]{2,1,0}) tuple(%c0, %x, %ws)
      %wh = (s32[], /*index=1*/f32[64,64]{1,0}, f32[10,64,64]{2,1,0}) while(%t), condition=%cond, body=%body
      ROOT %out = f32[64,64]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_parse_computations_with_tuple_comments():
    comps, entry = parse_hlo(HLO)
    assert entry == "main"
    assert {"fused_computation", "body", "cond", "main"} <= set(comps)
    # tuple-typed while op with /*index=N*/ comments must parse
    ops = {o.opcode for o in comps["main"].ops}
    assert "while" in ops


def test_trip_count_multiplies_dots_and_collectives():
    st = analyze(HLO, total_devices=32)
    assert st.while_trip_counts == [10]
    assert st.dot_flops == 10 * 2 * 64 * 64 * 64
    # all-reduce inside the loop: group size 8 (from [4,8]<=[32])
    rb = 64 * 64 * 4
    expected = 10 * 2 * rb * (8 - 1) / 8
    assert abs(st.collective_bytes["all-reduce"] - expected) < 1e-6
    assert st.collective_counts["all-reduce"] == 10


def test_scan_slice_memory_not_overcounted():
    st = analyze(HLO, total_devices=32)
    # the fusion reads one (64,64) slice per trip, not the whole (10,64,64)
    # stack; memory must therefore be well below 10 trips x full stack
    full_stack = 10 * 64 * 64 * 4
    assert st.memory_bytes < 10 * (full_stack + 3 * 64 * 64 * 4)
