"""Cross-backend equivalence: one task-ISA stream, two engines (§3).

The same encoded instruction stream `schedule_matmul` lowers must execute
bit-exactly on the numpy simulator AND the Pallas engine, and both must
match the pure-numpy oracle — the paper's simulator-vs-hardware
differential flow with the simulator as oracle for the fast path.
"""
import zlib

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.backend import (CrossBackendChecker, PallasBackend,
                                SimulatorBackend, resolve_backend)
from repro.core.isa import AluInsn, AluOp
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, matmul_reference,
                                  read_matmul_result, read_vector_result,
                                  schedule_matmul, schedule_vector_binop)
from repro.core.simulator import RunStats


def _bias_epilogue(N, spec, rng, **kw):
    bias_n = rng.integers(-1000, 1000, size=N, dtype=np.int32)
    nb = N // spec.block_out
    blocked = np.repeat(bias_n.reshape(nb, 1, spec.block_out),
                        spec.batch, axis=1)
    return Epilogue(bias_blocked=blocked, **kw)


def _make_epilogue(name, N, spec, rng):
    if name == "default":
        return None                                     # plain clip
    if name == "shift_clip":
        return Epilogue(shift=5)                        # requant fast path
    if name == "relu":
        return Epilogue(relu=True)                      # folds into clip_lo
    if name == "relu_noclip":
        return Epilogue(relu=True, clip_lo=None, clip_hi=None)
    if name == "relu_cliplo":
        return Epilogue(relu=True, clip_lo=-4, shift=2)  # fold w/ shift
    if name == "wrap":
        # no clip: the int8 truncating out-store wraps around
        return Epilogue(clip_lo=None, clip_hi=None)
    if name == "bias_shift_relu":
        return _bias_epilogue(N, spec, rng, shift=6, relu=True)
    raise ValueError(name)


# >= 8 shape/epilogue configurations, including the int8 truncating-store
# edge cases ("wrap") and both virtual-threading modes
CONFIGS = [
    (16, 16, 16, "default", 1),
    (16, 16, 16, "default", 2),
    (32, 16, 48, "shift_clip", 2),
    (48, 32, 32, "relu", 1),
    (64, 64, 64, "shift_clip", 2),
    (32, 32, 64, "bias_shift_relu", 2),
    (16, 32, 32, "wrap", 1),
    (64, 32, 128, "wrap", 2),
    (48, 16, 80, "relu_cliplo", 2),
    (32, 48, 32, "relu_noclip", 2),
]


def _run_backend(backend, a, w, ep, vt, spec):
    rt = Runtime(spec)
    plan = schedule_matmul(rt, a, w, epilogue=ep, virtual_threads=vt)
    stats = rt.synchronize(backend=backend)
    return read_matmul_result(rt, plan), stats


@pytest.mark.parametrize("M,N,K,ep_name,vt", CONFIGS)
def test_cross_backend_matmul_exact(M, N, K, ep_name, vt):
    spec = hwspec.pynq()
    # crc32, not hash(): str hashing is salted per-process and would make
    # a failing config unreproducible across runs
    rng = np.random.default_rng(zlib.crc32(repr((M, N, K, ep_name, vt))
                                           .encode()))
    a = rng.integers(-128, 128, size=(M, K), dtype=np.int8)
    w = rng.integers(-128, 128, size=(N, K), dtype=np.int8)
    ep = _make_epilogue(ep_name, N, spec, rng)
    sim_out, sim_stats = _run_backend("simulator", a, w, ep, vt, spec)
    pal_out, pal_stats = _run_backend("pallas", a, w, ep, vt, spec)
    ref = matmul_reference(a, w, epilogue=ep, spec=spec)
    np.testing.assert_array_equal(sim_out, ref)
    np.testing.assert_array_equal(pal_out, ref)
    assert sim_stats.backend == "simulator"
    assert pal_stats.backend == "pallas"
    # both engines executed the same stream: identical MAC counts
    assert sim_stats.gemm_macs == pal_stats.gemm_macs > 0


def test_checker_diffs_dram_images():
    spec = hwspec.pynq()
    rng = np.random.default_rng(7)
    a = rng.integers(-128, 128, size=(64, 96), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 96), dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_matmul(rt, a, w, epilogue=Epilogue(shift=3),
                           virtual_threads=2)
    report = CrossBackendChecker().check_runtime(rt)
    assert report.matches, f"{report.mismatched_bytes} bytes differ"
    assert {r.backend for r in report.runs} == {"simulator", "pallas"}
    # adopted image stays readable through the usual helper
    got = read_matmul_result(rt, plan)
    np.testing.assert_array_equal(
        got, matmul_reference(a, w, epilogue=Epilogue(shift=3), spec=spec))
    # per-clone reads agree too
    for run in report.runs:
        np.testing.assert_array_equal(
            read_matmul_result(rt, plan, device=run.device), got)


def test_vector_binop_cross_backend_and_balanced():
    """Listing-1 path: exact on both engines, and the fixed dependence
    protocol leaves every token FIFO drained even across chunks."""
    spec = hwspec.pynq().replace(acc_buff_bytes=4 * 1024,
                                 out_buff_bytes=4 * 1024)
    rng = np.random.default_rng(3)
    n = 600                       # > acc_depth//2 elements => multiple chunks
    a = rng.integers(-64, 64, size=n, dtype=np.int32)
    b = rng.integers(-63, 63, size=n, dtype=np.int32)
    want = (a + b).astype(np.int8)
    for backend in ("simulator", "pallas"):
        rt = Runtime(spec)
        c_addr, shape = schedule_vector_binop(rt, a, b, op=AluOp.ADD)
        assert shape[0] > spec.acc_depth // 2   # really multi-chunk
        rt.validate_stream(require_net_zero=True)  # no dangling s2c token
        rt.synchronize(backend=backend)
        got = read_vector_result(rt, c_addr, shape, n)
        np.testing.assert_array_equal(got, want, err_msg=backend)


def test_vector_binop_composes_after_matmul():
    """The net-zero token check is scoped to the binop's own stream suffix:
    scheduling it after a matmul (whose protocol legitimately leaves
    trailing WAR tokens) must not raise, and the composed stream still
    validates and executes on both engines."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(9)
    a = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    va = rng.integers(-64, 64, size=100, dtype=np.int32)
    vb = rng.integers(-63, 63, size=100, dtype=np.int32)
    for backend in ("simulator", "pallas"):
        rt = Runtime(spec)
        schedule_matmul(rt, a, w, virtual_threads=2)
        c_addr, shape = schedule_vector_binop(rt, va, vb, op=AluOp.ADD)
        rt.synchronize(backend=backend)   # no ValueError, runs to FINISH
        got = read_vector_result(rt, c_addr, shape, 100)
        np.testing.assert_array_equal(got, (va + vb).astype(np.int8),
                                      err_msg=backend)


def test_relu_folds_into_clip_pass():
    """relu=True with a clip emits no extra ALU pass (MAX 0 + MAX -128
    was a no-op pair) and still matches the oracle."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)

    def n_alu_insns(ep):
        rt = Runtime(spec)
        schedule_matmul(rt, a, w, epilogue=ep, virtual_threads=1)
        return sum(isinstance(i, AluInsn) for i in rt.stream)

    assert Epilogue(relu=True).n_alu_passes == Epilogue().n_alu_passes == 2
    assert n_alu_insns(Epilogue(relu=True)) == n_alu_insns(Epilogue())
    # relu without a clip still needs its own pass
    assert Epilogue(relu=True, clip_lo=None).n_alu_passes == 1
    # folded lower bound: relu dominates a negative clip_lo
    assert Epilogue(relu=True, clip_lo=-4).folded_clip_lo == 0
    assert Epilogue(relu=True, clip_lo=5).folded_clip_lo == 5


def test_out_load_over_pending_tile_matches_simulator():
    """Hand-built stream: a LOAD into OUT SRAM lands *between* a GEMM and
    its STORE.  The loaded bytes must win over the GEMM's write-through
    mirror on both engines (forces the Pallas engine to resolve the lazy
    tile before the OUT load executes)."""
    spec = hwspec.pynq()
    rng = np.random.default_rng(13)
    a = rng.integers(-128, 128, size=(spec.batch, spec.block_in),
                     dtype=np.int8)
    w = rng.integers(-128, 128, size=(spec.block_out, spec.block_in),
                     dtype=np.int8)
    injected = rng.integers(-128, 128,
                            size=(1, spec.batch, spec.block_out),
                            dtype=np.int8)
    from repro.core.isa import COMPUTE_Q, LOAD_Q, MemId, STORE_Q
    outs = {}
    for backend in ("simulator", "pallas"):
        rt = Runtime(spec)
        a_addr = rt.copy_to_device(a, align=spec.inp_elem_bytes)
        w_addr = rt.copy_to_device(w, align=spec.wgt_elem_bytes)
        o_addr = rt.copy_to_device(injected, align=spec.out_elem_bytes)
        c_addr = rt.buffer_alloc(spec.out_elem_bytes,
                                 align=spec.out_elem_bytes)
        rt.load_buffer_2d(MemId.INP, 0, rt.to_elem_addr(a_addr, MemId.INP),
                          1, 1, 1)
        rt.load_buffer_2d(MemId.WGT, 0, rt.to_elem_addr(w_addr, MemId.WGT),
                          1, 1, 1)
        rt.dep_push(LOAD_Q, COMPUTE_Q)
        rt.dep_pop(LOAD_Q, COMPUTE_Q)

        def reset(b):
            b.push(dst=0, src=0)

        def gemm(b):
            b.push(dst=0, src=0, wgt=0)

        rt.push_gemm(rt.uop_kernel(reset, key="t.rst"), reset=True)
        rt.push_gemm(rt.uop_kernel(gemm, key="t.mm"))
        # overwrite the out mirror AFTER the gemm, BEFORE the store
        rt.load_buffer_2d(MemId.OUT, 0, rt.to_elem_addr(o_addr, MemId.OUT),
                          1, 1, 1)
        rt.dep_push(COMPUTE_Q, STORE_Q)
        rt.dep_pop(COMPUTE_Q, STORE_Q)
        rt.store_buffer_2d(0, rt.to_elem_addr(c_addr, MemId.OUT), 1, 1, 1)
        rt.synchronize(backend=backend)
        outs[backend] = rt.copy_from_device(
            c_addr, spec.out_elem_bytes, np.int8,
            (spec.batch, spec.block_out))
    np.testing.assert_array_equal(outs["simulator"], injected[0])
    np.testing.assert_array_equal(outs["pallas"], injected[0])


def test_backend_resolution():
    assert isinstance(resolve_backend(None), SimulatorBackend)
    assert isinstance(resolve_backend("simulator"), SimulatorBackend)
    assert isinstance(resolve_backend("pallas"), PallasBackend)
    inst = PallasBackend()
    assert resolve_backend(inst) is inst
    with pytest.raises(ValueError):
        resolve_backend("fpga")


def test_pallas_backend_reports_wall_time_and_bytes():
    spec = hwspec.pynq()
    rng = np.random.default_rng(11)
    a = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    w = rng.integers(-128, 128, size=(32, 32), dtype=np.int8)
    stats = {}
    for backend in ("simulator", "pallas"):
        rt = Runtime(spec)
        schedule_matmul(rt, a, w, virtual_threads=2)
        stats[backend] = rt.synchronize(backend=backend)
    for s in stats.values():
        assert isinstance(s, RunStats)
        assert s.wall_time_s > 0
    # identical stream => identical DMA traffic on both engines
    assert stats["simulator"].dram_rd_bytes == stats["pallas"].dram_rd_bytes
    assert stats["simulator"].dram_wr_bytes == stats["pallas"].dram_wr_bytes


def test_decode_cache_is_a_bounded_lru_with_counted_evictions():
    """The process-wide decoded-stream cache holds at most
    set_decode_cache_cap entries, evicts least-recently-HIT first, and
    every eviction is counted — unbounded growth under a many-program
    serving mix is a regression, silent eviction is too."""
    from repro.core.backend import decode_cache_info, set_decode_cache_cap

    class _FakeIsa:
        insn_words = 2

        def decode_stream(self, raw):
            return [("decoded", raw.tobytes())]

    spec = hwspec.pynq()
    eng = PallasBackend()
    isa = _FakeIsa()

    def raw(i):
        return np.full((1, 2), 7_000_000 + i, dtype=np.uint64)

    base = decode_cache_info()
    old_cap = base["cap"]
    try:
        set_decode_cache_cap(3)
        assert decode_cache_info()["size"] <= 3
        start = decode_cache_info()["evictions"]
        # fill: 3 distinct streams fit (anything older gets trimmed)
        for i in range(3):
            _, ev = eng._decode_cached(spec, isa, raw(i))
        filled = decode_cache_info()
        assert filled["size"] == 3 and filled["cap"] == 3
        # hit stream 0 to refresh its recency, then insert a 4th:
        # stream 1 (now the LRU) must be the one evicted
        hit, ev = eng._decode_cached(spec, isa, raw(0))
        assert ev == 0 and hit == [("decoded", raw(0).tobytes())]
        _, ev = eng._decode_cached(spec, isa, raw(3))
        assert ev == 1, "insert over cap must evict exactly one entry"
        _, ev = eng._decode_cached(spec, isa, raw(0))
        assert ev == 0, "recently-hit stream must have survived"
        _, ev = eng._decode_cached(spec, isa, raw(1))
        assert ev == 1, "LRU stream must have been evicted"
        assert decode_cache_info()["evictions"] >= start + 2
        # shrinking the cap trims immediately and counts the trims
        trimmed = set_decode_cache_cap(1)
        assert trimmed == 2 and decode_cache_info()["size"] == 1
        # cap 0 disables retention: nothing is kept, nothing grows
        set_decode_cache_cap(0)
        _, _ = eng._decode_cached(spec, isa, raw(4))
        assert decode_cache_info()["size"] == 0
        with pytest.raises(ValueError):
            set_decode_cache_cap(-1)
    finally:
        set_decode_cache_cap(old_cap)


def test_decode_evictions_flow_into_runstats_merge():
    """RunStats carries per-call decode_evictions and merged() sums it —
    the serving loop's visibility into cache churn."""
    a = RunStats(decode_evictions=2)
    b = RunStats(decode_evictions=1)
    assert RunStats.merged([a, b]).decode_evictions == 3
