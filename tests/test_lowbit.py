"""Sub-byte weight path: packed storage, LUT-GEMM kernel, quantize fixes.

Covers the lowbit tentpole end to end — layout pack/unpack round trips
(int4/int2/int1, odd widths, padding tails), packed TensorMeta storage
through both engines, the T-MAC LUT kernel vs the dense GEMM, per-shape
kernel selection, the VtaLinear bits= knob — plus failing-before /
passing-after regressions for the three quantize.py bugs the path sits
on top of (hard-coded int8 clip, overflow-before-clip, empty-input
percentile crash).
"""
import numpy as np
import pytest

from repro.core import hwspec, layout
from repro.core import quantize as q
from repro.core.backend import PallasBackend, SimulatorBackend
from repro.core.program import Program, TensorMeta
from repro.core.scheduler import Epilogue

RNG = np.random.default_rng(20260808)


# ----------------------------------------------------------------------
# layout: bit-packing round trips
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4])
@pytest.mark.parametrize("shape", [(1,), (7,), (8,), (9,), (3, 5),
                                   (2, 16), (4, 31), (2, 3, 13)])
def test_pack_bits_roundtrip(bits, shape):
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    a = RNG.integers(qmin, qmax + 1, size=shape).astype(np.int8)
    packed = layout.pack_bits(a, bits)
    assert packed.dtype == np.uint8
    ppb = 8 // bits
    assert packed.shape[-1] == -(-shape[-1] // ppb)
    out = layout.unpack_bits(packed, bits, shape[-1])
    np.testing.assert_array_equal(out, a)


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_pack_bits_extremes_and_tail(bits):
    """Boundary values survive sign extension; the padding tail decodes
    as zeros and is dropped."""
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    a = np.array([qmin, qmax, 0, -1] * 3 + [qmin], np.int8)  # odd length
    packed = layout.pack_bits(a, bits)
    np.testing.assert_array_equal(layout.unpack_bits(packed, bits, a.size), a)
    # the tail bits beyond a.size are zero fields
    full = layout.unpack_bits(packed, bits, packed.size * (8 // bits))
    assert (full[a.size:] == 0).all()


def test_pack_bits_rejects_out_of_range():
    with pytest.raises(ValueError, match="outside int4 range"):
        layout.pack_bits(np.array([8], np.int8), 4)
    with pytest.raises(ValueError, match="outside int2 range"):
        layout.pack_bits(np.array([-3], np.int8), 2)


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_pack_wgt_elems_roundtrip(bits):
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    blocked = RNG.integers(qmin, qmax + 1, size=(3, 2, 16, 16)).astype(np.int8)
    packed = layout.pack_wgt_elems(blocked, bits)
    assert packed.shape == (3, 2, 16 * 16 * bits // 8)
    out = layout.unpack_wgt_elems(packed, bits, 16, 16)
    np.testing.assert_array_equal(out, blocked)


@pytest.mark.parametrize("bits", [1, 2, 4])
@pytest.mark.parametrize("kind,shape", [("wgt", (19, 37)),
                                        ("cwgt", (5, 9, 3, 3))])
def test_tensormeta_packed_roundtrip(bits, kind, shape):
    """Weight metas on a sub-byte spec store uint8 packed bytes (8/bits
    smaller) and unpack back to the exact logical tensor — including
    non-multiple-of-block shapes whose padding lives inside the packed
    elements."""
    spec = hwspec.lowbit(bits)
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    meta = TensorMeta(kind=kind, shape=shape, dtype="int8")
    w = RNG.integers(qmin, qmax + 1, size=shape).astype(np.int8)
    packed = meta.pack(w, spec)
    assert packed.dtype == np.uint8
    spec8 = hwspec.pynq()
    assert meta.nbytes(spec) * 8 == meta.nbytes(spec8) * bits
    assert meta.elem_bytes(spec) == spec.wgt_elem_bytes
    np.testing.assert_array_equal(meta.unpack(packed, spec), w)


def test_pack_rejects_weights_wider_than_spec():
    """int8-valued weights on an int4 spec fail loudly instead of
    silently corrupting the packed image."""
    spec = hwspec.lowbit(4)
    meta = TensorMeta(kind="wgt", shape=(16, 16), dtype="int8")
    w = np.full((16, 16), 100, np.int8)
    with pytest.raises(ValueError, match="outside int4 range"):
        meta.pack(w, spec)


def test_hwspec_validates_wgt_bits():
    with pytest.raises(ValueError, match="wgt_bits"):
        hwspec.pynq().replace(wgt_bits=3)
    # lowbit keeps the WGT SRAM depth (and so the uop budget) fixed
    for bits in (1, 2, 4):
        s = hwspec.lowbit(bits)
        assert s.wgt_packed
        assert s.wgt_depth == hwspec.pynq().wgt_depth
        assert s.wgt_elem_bytes == hwspec.pynq().wgt_elem_bytes * bits // 8


# ----------------------------------------------------------------------
# quantize.py regressions (each failed before its PR-8 fix)
# ----------------------------------------------------------------------
def test_quantize_per_channel_respects_bits():
    """Regression: quantize_per_channel hard-coded np.clip(q, -128, 127),
    so values beyond the calibrated range came back outside the int4
    range (silent int8-range saturation) and the packed path rejects
    them.  With bits=4 the clip lands on the correct qmin/qmax."""
    w = RNG.normal(size=(8, 32)).astype(np.float32)
    scales = q.per_channel_scales(w, axis=0, bits=4)
    # production weights drift past the calibration range (3x outliers):
    # before the fix these quantized to ~21, inside [-128, 127] but far
    # outside int4
    q4 = q.quantize_per_channel(3.0 * w, scales, axis=0, bits=4)
    assert q4.dtype == np.int8
    assert q4.min() >= -8 and q4.max() <= 7
    # and the in-range round trip is unaffected
    q4_in = q.quantize_per_channel(w, scales, axis=0, bits=4)
    np.testing.assert_allclose(
        q4_in.astype(np.float64) * scales.astype(np.float64)[:, None],
        w, atol=float(scales.max()))
    # int4 quantized values feed the packed layout without a range error
    layout.pack_bits(q4, 4)


def test_quantize_bias_clips_before_the_cast():
    """Regression: np.round(...).astype(np.int64).clip(...) — a float64
    beyond int64 range overflows IN THE CAST (wrapping to INT64_MIN),
    so a huge positive bias came back as -2^31 instead of saturating at
    +2^31-1.  The clip must happen in the float domain."""
    bias = np.array([1.0, -1.0, 0.5], np.float64)
    with np.errstate(invalid="ignore"):
        out = q.quantize_bias(bias, sx=1e-20, sw=1e-20)  # ratio ~ 1e40
    assert out.dtype == np.int32
    assert out[0] == (1 << 31) - 1          # saturates, keeps its sign
    assert out[1] == -(1 << 31)
    assert out[2] == (1 << 31) - 1
    # sane ratios are untouched
    np.testing.assert_array_equal(
        q.quantize_bias(np.array([2.0, -3.0]), sx=0.5, sw=0.5),
        np.array([8, -12], np.int32))


def test_calibrate_empty_input_both_branches():
    """Regression: the max branch was guarded by a.max(initial=0.0) but
    the percentile branch crashed on size-0 input."""
    empty = np.zeros((0, 4), np.float32)
    qp_max = q.calibrate(empty)                      # was already safe
    qp_pct = q.calibrate(empty, percentile=99.0)     # used to raise
    assert qp_max.scale > 0 and qp_pct.scale > 0
    assert qp_max.scale == qp_pct.scale
    # non-empty percentile path still calibrates below the max
    x = np.concatenate([np.ones(99), [100.0]])
    assert q.calibrate(x, percentile=90.0).scale < q.calibrate(x).scale


# ----------------------------------------------------------------------
# LUT-GEMM kernel vs the dense GEMM (bit-exact by construction)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4])
@pytest.mark.parametrize("group", [2, 4, 8])
def test_lut_gemm_matches_dense(bits, group):
    import jax.numpy as jnp

    from repro.kernels.lut_gemm import lut_gemm
    from repro.kernels.vta_gemm import vta_gemm

    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    for (M, K, N) in [(1, 32, 16), (4, 144, 130), (18, 96, 64)]:
        a = RNG.integers(-128, 128, size=(M, K)).astype(np.int8)
        w = RNG.integers(qmin, qmax + 1, size=(K, N)).astype(np.int8)
        for ep, sh in [("none", 0), ("requant", 5)]:
            got = np.asarray(lut_gemm(
                jnp.asarray(a), jnp.asarray(w), bits=bits, group=group,
                epilogue=ep, shift=sh, use_pallas=True))
            want = np.asarray(vta_gemm(jnp.asarray(a), jnp.asarray(w),
                                       epilogue=ep, shift=sh))
            np.testing.assert_array_equal(
                got, want, err_msg=f"bits={bits} group={group} "
                                   f"shape={(M, K, N)} ep={ep}")


def test_lut_gemm_ref_is_dense():
    import jax.numpy as jnp

    from repro.kernels.lut_gemm import lut_gemm
    a = RNG.integers(-128, 128, size=(3, 32)).astype(np.int8)
    w = RNG.integers(-8, 8, size=(32, 16)).astype(np.int8)
    got = np.asarray(lut_gemm(jnp.asarray(a), jnp.asarray(w), bits=4))
    np.testing.assert_array_equal(
        got, a.astype(np.int64) @ w.astype(np.int64))


# ----------------------------------------------------------------------
# end-to-end: packed programs on both engines
# ----------------------------------------------------------------------
def _matmul_program(spec, w, m):
    p = Program(spec)
    x = p.input("x", (m, w.shape[1]))
    c = p.matmul(x, p.constant("w", w), epilogue=Epilogue(shift=5),
                 name="mm")
    p.output(c)
    return p.compile(use_cache=False)


@pytest.mark.parametrize("bits", [1, 2, 4])
def test_packed_program_bit_exact_both_engines(bits):
    spec = hwspec.lowbit(bits)
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    w = RNG.integers(qmin, qmax + 1, size=(56, 72)).astype(np.int8)
    x = RNG.integers(-128, 128, size=(5, 72)).astype(np.int8)
    want = np.clip((x.astype(np.int64) @ w.T.astype(np.int64)) >> 5,
                   -128, 127).astype(np.int8)
    compiled = _matmul_program(spec, w, 5)
    for be in (SimulatorBackend(), PallasBackend()):
        got = compiled(backend=be, x=x)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"bits={bits} {be.name}")


def test_packed_constants_shrink_dram():
    """The acceptance bar: staged constant-weight bytes shrink >= 2x at
    int4 (8/bits in general), and the whole DRAM image is smaller, so
    DevicePool trimmed clones get proportionally cheaper."""
    c8 = _matmul_program(
        hwspec.pynq(),
        RNG.integers(-128, 128, size=(128, 256)).astype(np.int8), 4)
    sizes = {8: c8.const_bytes}
    for bits in (4, 2, 1):
        qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
        w = RNG.integers(qmin, qmax + 1, size=(128, 256)).astype(np.int8)
        cb = _matmul_program(hwspec.lowbit(bits), w, 4)
        sizes[bits] = cb.const_bytes
        assert cb.const_bytes * 8 == c8.const_bytes * bits
        assert f"wgt int{bits} packed" in cb.describe()
        assert cb.device.dram._next < c8.device.dram._next
    assert sizes[4] * 2 == sizes[8]          # >= 2x at int4


def test_lut_selected_for_decode_shapes_only():
    """Per-shape kernel selection: decode-shaped (few-row) launches on a
    sub-byte spec route through the LUT kernel; use_lut=False pins the
    dense kernel; int8 specs never use it."""
    spec = hwspec.lowbit(4)
    w = RNG.integers(-8, 8, size=(128, 128)).astype(np.int8)
    x = RNG.integers(-128, 128, size=(2, 128)).astype(np.int8)
    compiled = _matmul_program(spec, w, 2)
    want = np.clip((x.astype(np.int64) @ w.T.astype(np.int64)) >> 5,
                   -128, 127).astype(np.int8)

    got = compiled(backend=PallasBackend(), x=x)
    np.testing.assert_array_equal(got, want)
    assert sum(s.lut_launches for s in compiled.last_stats) >= 1

    got = compiled(backend=PallasBackend(use_lut=False), x=x)
    np.testing.assert_array_equal(got, want)
    assert sum(s.lut_launches for s in compiled.last_stats) == 0

    # int8 spec: auto never selects the LUT kernel
    c8 = _matmul_program(hwspec.pynq(), w, 2)
    c8(backend=PallasBackend(), x=x)
    assert sum(s.lut_launches for s in c8.last_stats) == 0


def test_persistent_image_roundtrip_packed():
    """Persistent-image save/restore moves RAW packed bytes (the session
    state contract is storage-level, not logical-level)."""
    spec = hwspec.lowbit(4)
    w = RNG.integers(-8, 8, size=(32, 32)).astype(np.int8)
    compiled = _matmul_program(spec, w, 2)
    nid = compiled.input_ids["w"]
    got = compiled._read(nid)
    np.testing.assert_array_equal(got, w)


# ----------------------------------------------------------------------
# VtaLinear bits= knob
# ----------------------------------------------------------------------
def test_vta_linear_int4():
    from repro.models.quantized import VtaLinear

    rng = np.random.default_rng(7)
    w = rng.normal(size=(96, 80)).astype(np.float32) * 0.1
    x = rng.normal(size=(2, 96)).astype(np.float32)

    lin4 = VtaLinear(w, bits=4)
    assert lin4.spec.wgt_bits == 4
    assert lin4.w_q.min() >= -8 and lin4.w_q.max() <= 7
    y4 = lin4(x)
    # both engines agree bit-exactly on the quantized program, so the
    # dequantized outputs match exactly too
    y4_sim = lin4(x, backend=SimulatorBackend())
    np.testing.assert_array_equal(y4, y4_sim)
    # int4 output tracks the int8 path's dequant reference within the
    # coarser quantization error (16x fewer levels)
    y8 = VtaLinear(w, bits=8)(x)
    ref = x @ w
    err4 = np.abs(y4 - ref).max()
    err8 = np.abs(y8 - ref).max()
    assert err4 < 16 * max(err8, 1e-3) + 0.5
    # the compiled program stages packed constants at half the int8 size
    compiled = next(iter(lin4._programs.values()))
    assert "wgt int4 packed" in compiled.describe()
    lin8 = VtaLinear(w, bits=8)
    lin8(x)
    c8 = next(iter(lin8._programs.values()))
    assert compiled.const_bytes * 2 == c8.const_bytes
