"""Cross-backend differential fuzzer: random Program graphs, one encoded
stream, bit-exact DRAM images on both engines — in BOTH fence modes.

The flexibility the conv-lowering modes buy (direct / im2col / via_matmul,
batch-blocked specs, mixed epilogues) has to be paid for with systematic
cross-configuration testing: every random graph is compiled twice
(``fence_mode="buffer"`` and the ``"barrier"`` baseline), each
accelerator segment is executed by ``CrossBackendChecker`` on cloned
devices (SimulatorBackend as the oracle, PallasBackend as the fast path)
with host steps run in between for heterogeneous ``cpu_only`` splits, and
the resulting DRAM images must match byte for byte per mode.  The two
modes' outputs are then byte-diffed against each other and against a
pure-numpy graph evaluator, so a bug that corrupted both engines — or
both fence modes — identically would still be caught.

Determinism: the generator is seeded numpy (no external dependency), so
the CI run is reproducible — override with REPRO_FUZZ_SEED / bound the
work with REPRO_FUZZ_GRAPHS.  REPRO_FUZZ_SPEC=tpu_like switches every
graph onto the MXU-shaped template instance (the nightly job's
configuration; CI keeps the fast pynq-scale mix).  When hypothesis is
installed an additional property-based pass explores the same generator
space.
"""
import os

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.backend import CrossBackendChecker
from repro.core.compiler import AccelStep, CpuStep
from repro.core.conv import (ConvShape, conv1x1_eligible,
                             conv_im2col_eligible, conv2d_reference)
from repro.core.isa import AluOp
from repro.core.program import Program
from repro.core.scheduler import Epilogue, matmul_reference

FUZZ_SEED = int(os.environ.get("REPRO_FUZZ_SEED", "20260802"))
# every graph now compiles+runs in BOTH fence modes (2 compile units per
# graph); the default keeps tier-1 wall time near the pre-fence baseline
# while the dedicated CI fuzz job pins REPRO_FUZZ_GRAPHS=56 (>= 50-graph
# acceptance criterion).  Keep each graph tiny so the eager simulator
# side stays fast.
FUZZ_GRAPHS = int(os.environ.get("REPRO_FUZZ_GRAPHS", "36"))
# "" = pynq-scale mix (CI); "tpu_like" = MXU-shaped template (nightly)
FUZZ_SPEC = os.environ.get("REPRO_FUZZ_SPEC", "")
# fuzz FLAVOR: "" = the cross-backend sweep below; "pool" = random
# graphs served through a DevicePool with randomized submit order and
# pool size, byte-diffed against serial execution; "persistent" = random
# STATEFUL graphs (Program.persistent buffers mutated by host ops)
# driven >=3 consecutive calls per engine and byte-diffed against a
# stateful numpy reference AND across engines, whole DRAM images
# included (the nightly job runs all three).  Small always-on pool and
# persistent sweeps keep tier-1 coverage.
FUZZ_FLAVOR = os.environ.get("REPRO_FUZZ_FLAVOR", "")
POOL_GRAPHS = int(os.environ.get("REPRO_FUZZ_POOL_GRAPHS",
                                 "24" if FUZZ_FLAVOR == "pool" else "6"))
PERSIST_GRAPHS = int(os.environ.get(
    "REPRO_FUZZ_PERSIST_GRAPHS",
    "24" if FUZZ_FLAVOR == "persistent" else "6"))
# "sched" = random graphs routed through the continuous-batching
# Scheduler (core.sched) with randomized admission window / gang width /
# queue cap / backpressure policy; survivors byte-diffed against serial,
# typed Shed outcomes accounted exactly (nightly flavor; a small
# always-on sweep keeps tier-1 coverage).
SCHED_GRAPHS = int(os.environ.get(
    "REPRO_FUZZ_SCHED_GRAPHS",
    "24" if FUZZ_FLAVOR == "sched" else "4"))
# "lowbit" = random graphs on packed sub-byte weight specs
# (hwspec.lowbit(4|2|1)): weights constrained to the b-bit range, the
# staged/packed DRAM bytes byte-diffed against the numpy packed
# reference (layout.pack_bits), both engines cross-checked, and the
# Pallas LUT-GEMM vs dense kernel A/B'd on the same stream.
LOWBIT_GRAPHS = int(os.environ.get(
    "REPRO_FUZZ_LOWBIT_GRAPHS",
    "24" if FUZZ_FLAVOR == "lowbit" else "6"))
# "chaos" = random graphs served through a self-healing DevicePool while
# a seeded FaultPlan injects slot kills, DRAM bit flips, and gang delays:
# survivors must be byte-identical to fault-free serial execution, every
# loss must surface a typed error (SlotDied after retry exhaustion /
# PoolClosed), and the pool's fault log must account for every fired
# fault (nightly flavor; a small always-on sweep keeps tier-1 coverage).
CHAOS_GRAPHS = int(os.environ.get(
    "REPRO_FUZZ_CHAOS_GRAPHS",
    "24" if FUZZ_FLAVOR == "chaos" else "4"))

_VEC_OPS = (AluOp.ADD, AluOp.MIN, AluOp.MAX, AluOp.MUL)


# ----------------------------------------------------------------------
# random graph generation
# ----------------------------------------------------------------------
def _rand_epilogue(rng, n_out, spec):
    """Mixed epilogues: requant shifts, relu, clip/no-clip (int8 wrap),
    per-channel bias."""
    kind = rng.integers(0, 5)
    kw = {}
    if kind == 1:
        kw = dict(shift=int(rng.integers(1, 7)))
    elif kind == 2:
        kw = dict(shift=int(rng.integers(0, 7)), relu=True)
    elif kind == 3:
        kw = dict(clip_lo=None, clip_hi=None)          # wraparound store
    elif kind == 4:
        nb = -(-n_out // spec.block_out)
        bias = rng.integers(-1000, 1000, size=nb * spec.block_out,
                            dtype=np.int32)
        blocked = np.repeat(bias.reshape(nb, 1, spec.block_out),
                            spec.batch, axis=1)
        kw = dict(bias_blocked=blocked, shift=int(rng.integers(0, 6)),
                  relu=bool(rng.integers(0, 2)))
    return Epilogue(**kw)


def _rand_conv_shape(rng, spec, n=None, ic=None, h=None, w=None):
    kh = int(rng.integers(1, 4))
    kw = int(rng.integers(1, 4))
    stride = int(rng.integers(1, 3))
    pad = int(rng.integers(0, 2))
    if h is None:
        h = int(rng.integers(max(3, kh), 9))
    if w is None:
        w = h
    # keep the output non-empty
    kh = min(kh, h + 2 * pad)
    kw = min(kw, w + 2 * pad)
    return ConvShape(
        n=n if n is not None else int(rng.integers(1, 2 * spec.batch + 1)),
        h=h, w=w,
        ic=ic if ic is not None else int(rng.integers(1, 34)),
        oc=int(rng.integers(1, 34)), kh=kh, kw=kw, stride=stride, pad=pad)


def _rand_lowering(rng, shape, spec):
    modes = ["direct", None]
    if conv_im2col_eligible(shape):
        modes.append("im2col")
    if conv1x1_eligible(shape, spec):
        modes.append("via_matmul")
    return modes[int(rng.integers(0, len(modes)))]


def _rand_spec(rng):
    if FUZZ_SPEC == "tpu_like":
        return hwspec.tpu_like()
    return hwspec.pynq() if rng.integers(0, 4) else \
        hwspec.HardwareSpec(batch=2)


def build_random_program(rng):
    """One random graph + its input feeds (flavors: dependent matmul
    chains, dependent conv chains with mixed lowerings, independent op
    triples, single convs, heterogeneous cpu_only splits)."""
    spec = _rand_spec(rng)
    vt = int(rng.integers(1, 3))
    p = Program(spec, virtual_threads=vt)
    feeds = {}

    def feed(name, shape, dtype=np.int8, lo=-64, hi=64):
        feeds[name] = rng.integers(lo, hi, size=shape, dtype=dtype)
        return p.input(name, shape, dtype="int8" if dtype == np.int8
                       else "int32")

    flavor = rng.integers(0, 5)
    if flavor == 0:                      # matmul chain (join barriers)
        depth = int(rng.integers(1, 4))
        m = int(rng.integers(1, 41))
        k = int(rng.integers(1, 41))
        t = feed("x", (m, k))
        for i in range(depth):
            n = int(rng.integers(1, 41))
            w = feed(f"w{i}", (n, k))
            t = p.matmul(t, w, epilogue=_rand_epilogue(rng, n, spec),
                         name=f"mm{i}")
            k = n
    elif flavor == 1:                    # conv chain, mixed lowerings
        depth = int(rng.integers(1, 3))
        s = _rand_conv_shape(rng, spec)
        t = feed("x", (s.n, s.ic, s.h, s.w))
        for i in range(depth):
            w = feed(f"k{i}", (s.oc, s.ic, s.kh, s.kw), lo=-16, hi=16)
            t = p.conv2d(t, w, s, epilogue=_rand_epilogue(rng, s.oc, spec),
                         lowering=_rand_lowering(rng, s, spec),
                         name=f"cv{i}")
            if i + 1 < depth:
                s = _rand_conv_shape(rng, spec, n=s.n, ic=s.oc,
                                     h=s.oh, w=s.ow)
    elif flavor == 2:                    # independent ops (SRAM liveness)
        m, k, n = (int(rng.integers(1, 33)) for _ in range(3))
        mm = p.matmul(feed("a", (m, k)), feed("w", (n, k)),
                      epilogue=_rand_epilogue(rng, n, spec), name="mm")
        s = _rand_conv_shape(rng, spec)
        cv = p.conv2d(feed("x", (s.n, s.ic, s.h, s.w)),
                      feed("kc", (s.oc, s.ic, s.kh, s.kw), lo=-16, hi=16),
                      s, epilogue=_rand_epilogue(rng, s.oc, spec),
                      lowering=_rand_lowering(rng, s, spec), name="cv")
        ln = int(rng.integers(1, 300))
        vec = p.vector_binop(
            feed("va", (ln,), np.int32, -2 ** 20, 2 ** 20),
            feed("vb", (ln,), np.int32, -2 ** 20, 2 ** 20),
            op=_VEC_OPS[int(rng.integers(0, len(_VEC_OPS)))], name="vec")
        for r in (mm, cv, vec):
            p.output(r)
    elif flavor == 3:                    # single conv, any shape/mode
        s = _rand_conv_shape(rng, spec)
        p.conv2d(feed("x", (s.n, s.ic, s.h, s.w)),
                 feed("k", (s.oc, s.ic, s.kh, s.kw), lo=-16, hi=16),
                 s, epilogue=_rand_epilogue(rng, s.oc, spec),
                 lowering=_rand_lowering(rng, s, spec), name="cv")
    else:                                # heterogeneous cpu_only split
        depth = 3
        cpu_pos = int(rng.integers(0, depth))
        s = _rand_conv_shape(rng, spec)
        t = feed("x", (s.n, s.ic, s.h, s.w))
        for i in range(depth):
            w = feed(f"k{i}", (s.oc, s.ic, s.kh, s.kw), lo=-16, hi=16)
            cpu = i == cpu_pos
            t = p.conv2d(t, w, s, epilogue=_rand_epilogue(rng, s.oc, spec),
                         cpu_only=cpu,
                         lowering=None if cpu
                         else _rand_lowering(rng, s, spec),
                         name=f"hc{i}")
            if i + 1 < depth:
                s = _rand_conv_shape(rng, spec, n=s.n, ic=s.oc,
                                     h=s.oh, w=s.ow)
    return p, feeds


# ----------------------------------------------------------------------
# numpy graph evaluator (independent of both engines)
# ----------------------------------------------------------------------
def evaluate_reference(p: Program, feeds):
    vals = {}
    for n in p.nodes:
        if n.op == "input":
            vals[n.idx] = feeds[n.name]
        elif n.op == "cpu":
            vals[n.idx] = n.fn(*(vals[i] for i in n.inputs))
        elif n.op == "matmul":
            a, w = (vals[i] for i in n.inputs)
            vals[n.idx] = matmul_reference(a, w, epilogue=n.epilogue,
                                           spec=p.spec)
        elif n.op == "conv2d":
            x, w = (vals[i] for i in n.inputs)
            vals[n.idx] = conv2d_reference(x, w, n.conv, epilogue=n.epilogue)
        elif n.op == "vbinop":
            a, b = (vals[i].astype(np.int64) for i in n.inputs)
            r = {AluOp.ADD: a + b, AluOp.MIN: np.minimum(a, b),
                 AluOp.MAX: np.maximum(a, b), AluOp.MUL: a * b}[n.alu_op]
            vals[n.idx] = r.astype(np.int32).astype(np.int8)
        else:
            raise ValueError(n.op)
    return vals


def cross_check(compiled, feeds):
    """Run every accelerator segment through CrossBackendChecker (cloned
    devices, byte-diffed DRAM), executing host steps in between
    (heterogeneous cpu_only splits), and return the output tensors read
    from the adopted simulator image."""
    for name, arr in feeds.items():
        compiled._write(compiled.input_ids[name], arr)
    checker = CrossBackendChecker()
    for step in compiled.steps:
        if isinstance(step, CpuStep):
            node = compiled.nodes[step.node_id]
            args = [compiled._read(i) for i in node.inputs]
            compiled._write(step.node_id, node.fn(*args))
            continue
        assert isinstance(step, AccelStep)
        report = checker.run(compiled.spec, compiled.device, step.stream)
        assert report.matches, (
            f"{report.mismatched_bytes} DRAM bytes differ between "
            f"simulator and pallas")
        compiled.device.copy_from(report.device_for("simulator"))
    return {compiled.nodes[i].name: compiled._read(i)
            for i in compiled.output_ids}


def _run_one(seed: int) -> None:
    rng = np.random.default_rng(seed)
    p, feeds = build_random_program(rng)
    refs = evaluate_reference(p, feeds)
    outs = {}
    for fence_mode in ("buffer", "barrier"):
        compiled = p.compile(use_cache=False, fence_mode=fence_mode)
        outs[fence_mode] = cross_check(compiled, feeds)
        for i in compiled.output_ids:
            name = p.nodes[i].name
            np.testing.assert_array_equal(
                outs[fence_mode][name], refs[i],
                err_msg=f"seed={seed} fence_mode={fence_mode} node={name} "
                        f"({compiled.describe()})")
    for name in outs["buffer"]:
        np.testing.assert_array_equal(
            outs["buffer"][name], outs["barrier"][name],
            err_msg=f"seed={seed} node={name}: fenced stream diverged "
                    f"from the barrier baseline")


# ----------------------------------------------------------------------
# pool flavor: random graphs served concurrently through a DevicePool,
# byte-diffed against serial single-device execution
# ----------------------------------------------------------------------
def _run_one_pool(seed: int) -> None:
    from repro.core.serve import DevicePool

    rng = np.random.default_rng(seed)
    p, feeds = build_random_program(rng)
    fence_mode = ("buffer", "barrier")[int(rng.integers(0, 2))]
    compiled = p.compile(use_cache=False, fence_mode=fence_mode)
    backend = ("simulator", "pallas")[int(rng.integers(0, 2))]
    pool_size = int(rng.integers(1, 5))
    policy = ("round_robin", "least_loaded")[int(rng.integers(0, 2))]
    n_requests = int(rng.integers(2, 3 + 2 * pool_size))

    # fresh per-request feeds with the same shapes/dtypes (permuted
    # content keeps ranges valid for every node kind)
    def permute(feed):
        return {k: rng.permutation(v.ravel()).reshape(v.shape)
                for k, v in feed.items()}
    requests = [permute(feeds) for _ in range(n_requests)]
    serial = [compiled(backend=backend, **r) for r in requests]
    refs = [evaluate_reference(p, r) for r in requests]

    ctx = (f"seed={seed} fence_mode={fence_mode} backend={backend} "
           f"pool={pool_size}/{policy} ({compiled.describe()})")
    with DevicePool(compiled, size=pool_size, backend=backend,
                    policy=policy) as pool:
        order = rng.permutation(n_requests)              # submit order
        futs = {int(i): pool.submit(**requests[i]) for i in order}
        for i in rng.permutation(n_requests):            # wait order
            got = futs[int(i)].wait(timeout=600)
            want = serial[int(i)]
            if not isinstance(got, dict):
                got = {"out": got}
                want = {"out": want}
            for name in got:
                np.testing.assert_array_equal(
                    got[name], want[name],
                    err_msg=f"{ctx} req={i} node={name}: pooled "
                            "execution diverged from serial")
        for i, ref in enumerate(refs):
            got = futs[i].wait()
            outs = got if isinstance(got, dict) else \
                {p.nodes[compiled.output_ids[0]].name: got}
            for nid in compiled.output_ids:
                np.testing.assert_array_equal(
                    outs[p.nodes[nid].name], ref[nid],
                    err_msg=f"{ctx} req={i}: pooled execution diverged "
                            "from the numpy reference")


# ----------------------------------------------------------------------
# sched flavor: random graphs through the continuous-batching scheduler
# under randomized admission/backpressure configs; every survivor is
# byte-diffed against serial execution and every loss is a typed Shed
# ----------------------------------------------------------------------
def _run_one_sched(seed: int) -> None:
    from repro.core.program import compile_multi
    from repro.core.sched import QueueFull, SchedConfig, Scheduler, Shed
    from repro.core.serve import DevicePool

    rng = np.random.default_rng(seed)
    p, feeds = build_random_program(rng)
    backend = ("simulator", "pallas")[int(rng.integers(0, 2))]
    pool_size = int(rng.integers(1, 5))
    multi = bool(rng.integers(0, 3) == 0)   # 1/3: two co-staged programs
    if multi:
        p2, feeds2 = build_random_program(rng)
        progs = compile_multi([p, p2])
        graphs = [(p, feeds), (p2, feeds2)]
    else:
        progs = [p.compile(use_cache=False)]
        graphs = [(p, feeds)]
    n_requests = int(rng.integers(2, 4 + 2 * pool_size))
    cfg = SchedConfig(
        window_us=float(rng.choice([200.0, 2000.0, 50000.0])),
        gang_width=(None if rng.integers(0, 2)
                    else int(rng.integers(1, pool_size + 1))),
        queue_cap=int(rng.integers(1, n_requests + 2)),
        policy=("reject", "shed_oldest")[int(rng.integers(0, 2))],
        pipeline_depth=int(rng.integers(1, 3)))

    def permute(feed):
        return {k: rng.permutation(v.ravel()).reshape(v.shape)
                for k, v in feed.items()}

    picks = [int(rng.integers(0, len(progs))) for _ in range(n_requests)]
    requests = [permute(graphs[pi][1]) for pi in picks]
    serial = [progs[pi](backend=backend, **r)
              for pi, r in zip(picks, requests)]

    ctx = (f"seed={seed} backend={backend} pool={pool_size} "
           f"multi={multi} cfg={cfg}")
    with DevicePool(progs, size=pool_size, backend=backend) as pool:
        sched = Scheduler(pool, cfg)
        futs = []
        for i in range(n_requests):
            try:
                futs.append((i, sched.submit(program=picks[i],
                                             **requests[i])))
            except QueueFull:
                assert cfg.policy == "reject", \
                    f"{ctx}: QueueFull under policy={cfg.policy}"
        assert futs, f"{ctx}: every submit rejected (cap >= 1)"
        survivors, shed = 0, 0
        for i, f in futs:
            try:
                got = f.wait(timeout=600)
            except Shed:
                shed += 1
                assert cfg.policy == "shed_oldest", \
                    f"{ctx}: Shed under policy={cfg.policy}"
                continue
            survivors += 1
            want = serial[i]
            if not isinstance(got, dict):
                got, want = {"out": got}, {"out": want}
            for name in got:
                np.testing.assert_array_equal(
                    got[name], want[name],
                    err_msg=f"{ctx} req={i} node={name}: windowed "
                            "execution diverged from serial")
        assert survivors >= 1, f"{ctx}: no request survived"
        stats = sched.stats()
        assert sum(s.completed for s in stats) == survivors, ctx
        assert sum(s.shed for s in stats) == shed, ctx
        assert sum(s.failed for s in stats) == 0, ctx
        sched.close()


# ----------------------------------------------------------------------
# chaos flavor: random graphs through a self-healing DevicePool under a
# seeded FaultPlan (kills / bit flips / delays); every survivor is
# byte-diffed against fault-free serial execution, every loss is typed,
# and the fault log must reconcile with the plan's fired entries
# ----------------------------------------------------------------------
def _run_one_chaos(seed: int) -> None:
    from repro.core.chaos import FaultPlan
    from repro.core.serve import DevicePool, SlotDied, PoolClosed

    rng = np.random.default_rng(seed)
    p, feeds = build_random_program(rng)
    compiled = p.compile(use_cache=False)
    backend = ("simulator", "pallas")[int(rng.integers(0, 2))]
    pool_size = int(rng.integers(2, 5))
    n_requests = int(rng.integers(4, 5 + 2 * pool_size))

    def permute(feed):
        return {k: rng.permutation(v.ravel()).reshape(v.shape)
                for k, v in feed.items()}
    requests = [permute(feeds) for _ in range(n_requests)]
    serial = [compiled(backend=backend, **r) for r in requests]

    plan = FaultPlan.random(
        seed=seed, n_gangs=4 * n_requests, slots=pool_size,
        rate=float(rng.choice([0.1, 0.2, 0.3])), max_delay_s=0.01)
    ctx = (f"seed={seed} backend={backend} pool={pool_size} "
           f"{plan.describe()} ({compiled.describe()})")
    survivors, losses = 0, 0
    with DevicePool(compiled, size=pool_size, backend=backend,
                    max_respawns=8, retries=3, retry_backoff_s=0.01,
                    integrity=True, fault_plan=plan) as pool:
        futs = [pool.submit(**r) for r in requests]
        for i, f in enumerate(futs):
            try:
                got = f.wait(timeout=600)   # a hang here is a bug
            except (SlotDied, PoolClosed) as e:
                losses += 1                 # typed, accounted loss
                assert getattr(e, "attempts", 1) >= 1, ctx
                continue
            survivors += 1
            want = serial[i]
            if not isinstance(got, dict):
                got, want = {"out": got}, {"out": want}
            for name in got:
                np.testing.assert_array_equal(
                    got[name], want[name],
                    err_msg=f"{ctx} req={i} node={name}: execution under "
                            "fault injection diverged from fault-free "
                            "serial")
        assert survivors + losses == n_requests, ctx
        assert len(pool.fault_log) == len(plan.fired), \
            f"{ctx}: fault log ({len(pool.fault_log)}) does not " \
            f"reconcile with fired faults ({len(plan.fired)})"
        # respawn math: every death is either respawned or leaves the
        # slot dead (respawn cap), never silent
        for s in pool.slots:
            assert s.stats.respawns <= s.stats.deaths, ctx
            assert s.dead == (s.stats.deaths > s.stats.respawns), ctx


# ----------------------------------------------------------------------
# lowbit flavor: random graphs on packed sub-byte weight specs; the
# packed DRAM image is byte-diffed against the numpy packed reference
# and the LUT-GEMM kernel is A/B'd against the dense kernel per graph
# ----------------------------------------------------------------------
def build_random_lowbit_program(rng):
    """Random graph on an int4/int2/int1-weight template: every weight
    tensor (matmul and conv, constant and per-call input) carries values
    in the b-bit two's-complement range; activations stay full int8."""
    bits = int(rng.choice([4, 4, 2, 1]))
    base = hwspec.pynq() if rng.integers(0, 4) else \
        hwspec.HardwareSpec(batch=2)
    spec = hwspec.lowbit(bits, base)
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    p = Program(spec, virtual_threads=int(rng.integers(1, 3)))
    feeds = {}
    consts = {}

    def feed(name, shape, lo=-64, hi=64):
        feeds[name] = rng.integers(lo, hi, size=shape, dtype=np.int8)
        return p.input(name, shape)

    def wfeed(name, shape):
        w = rng.integers(qmin, qmax + 1, size=shape, dtype=np.int8)
        if rng.integers(0, 2):          # constant: staged packed at compile
            consts[name] = w
            return p.constant(name, w)
        feeds[name] = w                 # input: staged packed per call
        return p.input(name, shape)

    flavor = rng.integers(0, 3)
    if flavor == 0:                      # matmul chain
        depth = int(rng.integers(1, 4))
        m = int(rng.integers(1, 41))
        k = int(rng.integers(1, 41))
        t = feed("x", (m, k))
        for i in range(depth):
            n = int(rng.integers(1, 41))
            t = p.matmul(t, wfeed(f"w{i}", (n, k)),
                         epilogue=_rand_epilogue(rng, n, spec),
                         name=f"mm{i}")
            k = n
    elif flavor == 1:                    # single conv, any lowering
        s = _rand_conv_shape(rng, spec)
        p.conv2d(feed("x", (s.n, s.ic, s.h, s.w)),
                 wfeed("k", (s.oc, s.ic, s.kh, s.kw)),
                 s, epilogue=_rand_epilogue(rng, s.oc, spec),
                 lowering=_rand_lowering(rng, s, spec), name="cv")
    else:                                # independent matmul + conv
        m, k, n = (int(rng.integers(1, 33)) for _ in range(3))
        mm = p.matmul(feed("a", (m, k)), wfeed("w", (n, k)),
                      epilogue=_rand_epilogue(rng, n, spec), name="mm")
        s = _rand_conv_shape(rng, spec)
        cv = p.conv2d(feed("x", (s.n, s.ic, s.h, s.w)),
                      wfeed("kc", (s.oc, s.ic, s.kh, s.kw)),
                      s, epilogue=_rand_epilogue(rng, s.oc, spec),
                      lowering=_rand_lowering(rng, s, spec), name="cv")
        for r in (mm, cv):
            p.output(r)
    return p, feeds, consts


def _check_packed_image(compiled, weights):
    """Byte-diff every sub-byte weight buffer in DRAM against the numpy
    packed reference (TensorMeta.pack -> layout.pack_bits)."""
    from repro.core import layout as _layout  # noqa: F401  (reference path)
    for name, w in weights.items():
        nid = compiled.input_ids[name]
        meta = compiled.nodes[nid].meta
        if meta.kind not in ("wgt", "cwgt"):
            continue
        raw = compiled.device.dram.read(compiled.addrs[nid],
                                        meta.nbytes(compiled.spec))
        want = meta.pack(w, compiled.spec)
        assert want.dtype == np.uint8, "sub-byte weights must store packed"
        np.testing.assert_array_equal(
            raw, want.reshape(-1),
            err_msg=f"{name}: packed DRAM bytes diverge from the numpy "
                    "packed reference")


def _run_one_lowbit(seed: int) -> None:
    from repro.core.backend import PallasBackend

    rng = np.random.default_rng(seed)
    p, feeds, consts = build_random_lowbit_program(rng)
    refs = evaluate_reference(p, {**feeds, **consts})
    outs = {}
    for fence_mode in ("buffer", "barrier"):
        compiled = p.compile(use_cache=False, fence_mode=fence_mode)
        outs[fence_mode] = cross_check(compiled, feeds)
        _check_packed_image(compiled, {**feeds, **consts})
        for i in compiled.output_ids:
            name = p.nodes[i].name
            np.testing.assert_array_equal(
                outs[fence_mode][name], refs[i],
                err_msg=f"seed={seed} fence_mode={fence_mode} node={name} "
                        f"({compiled.describe()})")
    for name in outs["buffer"]:
        np.testing.assert_array_equal(
            outs["buffer"][name], outs["barrier"][name],
            err_msg=f"seed={seed} node={name}: fenced stream diverged "
                    f"from the barrier baseline")
    # kernel A/B on the Pallas engine: the T-MAC LUT path and the dense
    # MXU path must both reproduce the numpy reference bit-exactly
    compiled = p.compile(use_cache=False)
    for use_lut in (True, False):
        got = compiled(backend=PallasBackend(use_lut=use_lut), **feeds)
        if not isinstance(got, dict):
            got = {p.nodes[compiled.output_ids[0]].name: got}
        for i in compiled.output_ids:
            name = p.nodes[i].name
            np.testing.assert_array_equal(
                got[name], refs[i],
                err_msg=f"seed={seed} use_lut={use_lut} node={name}: "
                        "kernel A/B diverged from the numpy reference")


# ----------------------------------------------------------------------
# persistent flavor: random stateful graphs run >=3 consecutive calls,
# byte-diffed against a stateful numpy reference and across engines
# ----------------------------------------------------------------------
def _state_variant(rng):
    """One of three in-place state mutations (accumulate / roll-in /
    decay-accumulate) — all pure, deterministic numpy."""
    kind = int(rng.integers(0, 3))

    def accum(h, s):
        ns = np.clip(s.astype(np.int32) + h.astype(np.int32),
                     -128, 127).astype(np.int8)
        return ns, ns

    def roll(h, s):
        ns = np.roll(s, 1, axis=0)
        ns = ns.copy()
        ns[0] = h[0]
        out = np.clip(ns.astype(np.int32) + h.astype(np.int32),
                      -128, 127).astype(np.int8)
        return out, ns

    def decay(h, s):
        ns = np.clip((s.astype(np.int32) >> 1) + h.astype(np.int32),
                     -128, 127).astype(np.int8)
        return ns, ns

    fn = (accum, roll, decay)[kind]
    return fn, f"fuzz.state.{fn.__name__}"


def build_random_persistent_program(rng):
    """Random stateful graph: accel matmul feeds a host op that mutates a
    persistent state buffer in place; optionally a second matmul consumes
    the host output (accelerator reads data derived from cross-call
    state).  Returns (program, make_feeds)."""
    spec = _rand_spec(rng)
    p = Program(spec, virtual_threads=int(rng.integers(1, 3)))
    m = int(rng.integers(1, 2 * spec.batch + 1))
    k = int(rng.integers(1, 33))
    n = int(rng.integers(1, 33))
    shapes = {"x": (m, k), "w0": (n, k)}
    x = p.input("x", (m, k))
    w0 = p.input("w0", (n, k))
    h = p.matmul(x, w0, epilogue=Epilogue(shift=int(rng.integers(1, 6))),
                 name="h")
    s_init = rng.integers(-64, 64, size=(m, n), dtype=np.int8)
    s = p.persistent("state", (m, n), init=s_init)
    fn, key = _state_variant(rng)
    t = p.host(fn, h, s, shape=(m, n), kind="mat", key=key,
               updates=(s,), name="mut")
    if rng.integers(0, 2):
        n2 = int(rng.integers(1, 33))
        shapes["w1"] = (n2, n)
        t = p.matmul(t, p.input("w1", (n2, n)),
                     epilogue=_rand_epilogue(rng, n2, spec), name="mm1")
    p.output(t)

    def make_feeds():
        return {name: rng.integers(-64, 64, size=shp, dtype=np.int8)
                for name, shp in shapes.items()}
    return p, make_feeds


def evaluate_reference_stateful(p: Program, calls):
    """Numpy oracle over a sequence of calls: persistent buffers carry
    across calls, host updates are applied in graph order.  Returns
    (per-call output dicts, final persistent state by node id)."""
    state = {nx.idx: np.array(nx.const) for nx in p.nodes if nx.persistent}
    outs = []
    for feeds in calls:
        vals = {}
        for nd in p.nodes:
            if nd.op == "input":
                vals[nd.idx] = state[nd.idx] if nd.persistent \
                    else feeds[nd.name]
            elif nd.op == "cpu":
                res = nd.fn(*(vals[i] for i in nd.inputs))
                if nd.updates:
                    out, *upd = res
                    for nid, arr in zip(nd.updates, upd):
                        state[nid] = arr
                else:
                    out = res
                vals[nd.idx] = out
            elif nd.op == "matmul":
                a, w = (vals[i] for i in nd.inputs)
                vals[nd.idx] = matmul_reference(a, w, epilogue=nd.epilogue,
                                                spec=p.spec)
            else:
                raise ValueError(nd.op)
        outs.append({i: vals[i] for i in p._outputs})
    return outs, state


def _run_one_persistent(seed: int) -> None:
    rng = np.random.default_rng(seed)
    p, make_feeds = build_random_persistent_program(rng)
    n_calls = int(rng.integers(3, 6))
    calls = [make_feeds() for _ in range(n_calls)]
    refs, ref_state = evaluate_reference_stateful(p, calls)
    for fence_mode in ("buffer", "barrier"):
        compiled = p.compile(use_cache=False, fence_mode=fence_mode)
        ctx = f"seed={seed} fence_mode={fence_mode}"
        devs = {eng: compiled.device.clone(trim=True)
                for eng in ("simulator", "pallas")}
        for eng, dev in devs.items():
            for ci, feeds in enumerate(calls):
                res = compiled.run_on(dev, backend=eng, inputs=feeds)
                outs = res.outputs if isinstance(res.outputs, dict) else \
                    {p.nodes[compiled.output_ids[0]].name: res.outputs}
                for nid in compiled.output_ids:
                    np.testing.assert_array_equal(
                        outs[p.nodes[nid].name], refs[ci][nid],
                        err_msg=f"{ctx} eng={eng} call={ci}: stateful "
                                "output diverged from numpy reference")
            for nid in compiled.persistent_ids:
                np.testing.assert_array_equal(
                    compiled._read(nid, device=dev), ref_state[nid],
                    err_msg=f"{ctx} eng={eng}: final persistent state "
                            "diverged from numpy reference")
        # byte-identical WHOLE DRAM images after the same call sequence:
        # stream staging, constants, arena recycling, persistent state
        np.testing.assert_array_equal(
            devs["simulator"].dram.mem, devs["pallas"].dram.mem,
            err_msg=f"{ctx}: engines diverged somewhere in the DRAM "
                    "image after the stateful call sequence")


# ----------------------------------------------------------------------
# the deterministic CI sweep (>= 50 graphs, fixed seed)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("idx", range(FUZZ_GRAPHS))
def test_fuzz_cross_backend(idx):
    if FUZZ_FLAVOR == "pool":
        _run_one_pool(FUZZ_SEED + idx)
    elif FUZZ_FLAVOR == "persistent":
        _run_one_persistent(FUZZ_SEED + idx)
    elif FUZZ_FLAVOR == "sched":
        _run_one_sched(FUZZ_SEED + idx)
    elif FUZZ_FLAVOR == "lowbit":
        _run_one_lowbit(FUZZ_SEED + idx)
    elif FUZZ_FLAVOR == "chaos":
        _run_one_chaos(FUZZ_SEED + idx)
    else:
        _run_one(FUZZ_SEED + idx)


@pytest.mark.parametrize("idx", range(POOL_GRAPHS))
def test_fuzz_pool(idx):
    """Always-on pooled sweep (smaller than the main grid); the nightly
    REPRO_FUZZ_FLAVOR=pool job widens it and flips the main grid over to
    the pool flavor too."""
    _run_one_pool(FUZZ_SEED + 7919 + idx)


@pytest.mark.parametrize("idx", range(PERSIST_GRAPHS))
def test_fuzz_persistent(idx):
    """Always-on stateful sweep; the nightly REPRO_FUZZ_FLAVOR=persistent
    job widens it and flips the main grid over too."""
    _run_one_persistent(FUZZ_SEED + 104729 + idx)


@pytest.mark.parametrize("idx", range(LOWBIT_GRAPHS))
def test_fuzz_lowbit(idx):
    """Always-on sub-byte weight sweep (packed DRAM bytes byte-diffed
    against the numpy packed reference; LUT vs dense kernel A/B); the
    nightly REPRO_FUZZ_FLAVOR=lowbit job widens it and flips the main
    grid over too."""
    _run_one_lowbit(FUZZ_SEED + 15485863 + idx)


@pytest.mark.parametrize("idx", range(CHAOS_GRAPHS))
def test_fuzz_chaos(idx):
    """Always-on self-healing sweep (seeded fault injection; survivors
    byte-diffed against fault-free serial, losses typed); the nightly
    REPRO_FUZZ_FLAVOR=chaos job widens it and flips the main grid over
    too."""
    _run_one_chaos(FUZZ_SEED + 2750159 + idx)


@pytest.mark.parametrize("idx", range(SCHED_GRAPHS))
def test_fuzz_sched(idx):
    """Always-on continuous-batching sweep; the nightly
    REPRO_FUZZ_FLAVOR=sched job widens it and flips the main grid over
    too."""
    _run_one_sched(FUZZ_SEED + 1299709 + idx)


# optional hypothesis pass over the same generator space
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None, derandomize=True,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_fuzz_cross_backend_hypothesis(seed):
        _run_one(seed)
except ImportError:                                        # pragma: no cover
    pass
