"""Distributed semantics under a forced multi-device host: gradient
compression, sharding rules, MoE expert parallelism equivalence, and the
HLO analyzer's trip-count handling.  Runs in a subprocess with 8 virtual
devices so the main test process keeps its single-device jax config."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_compressed_allreduce_matches_fp32_mean():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.compression import compressed_mean
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64, 32)), jnp.float32)
        e = jnp.zeros_like(g)
        mean, err = compressed_mean(g, e, mesh, axis="data")
        ref = jnp.mean(g, axis=0)
        rel = float(jnp.max(jnp.abs(mean - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 0.05, rel
        # error feedback: residual is bounded by one quant step
        step = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(err))) <= step * 1.01
        print("OK", rel)
    """)
    assert "OK" in out


def test_error_feedback_reduces_bias_over_steps():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.compression import compressed_mean
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        # constant tiny gradient below one quant step: without error
        # feedback it would vanish forever; with it, it accumulates
        g = jnp.asarray(np.full((8, 16, 16), 1e-4), jnp.float32) + \
            jnp.asarray(rng.normal(size=(8, 16, 16)) * 1.0, jnp.float32)
        e = jnp.zeros_like(g)
        acc = jnp.zeros((16, 16), jnp.float32)
        ref = jnp.zeros((16, 16), jnp.float32)
        for _ in range(50):
            m, e = compressed_mean(g, e, mesh, axis="data")
            acc = acc + m
            ref = ref + jnp.mean(g, axis=0)
        rel = float(jnp.linalg.norm(acc - ref) / jnp.linalg.norm(ref))
        assert rel < 0.02, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_moe_ep_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import meshctx
        from repro.models.config import ModelConfig, ShardingConfig
        from repro.models import moe as M
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                          moe_experts=8, moe_top_k=2, moe_d_ff=64,
                          dtype="float32",
                          moe_capacity_factor=8.0,  # no drops -> exact
                          sharding=ShardingConfig(enabled=True,
                                                  data_axes=("data",),
                                                  model_axis="model"))
        p = M.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        # single device reference (no mesh)
        y_ref, aux_ref = M.moe_apply(p, cfg, x)
        # EP over 2x4 mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with meshctx.use_mesh(mesh):
            y_ep, aux_ep = jax.jit(lambda p, x: M.moe_apply(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(y_ep - y_ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_param_sharding_rules():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.configs import get_arch, reduced
        from repro.distributed.sharding import param_specs
        from repro.models import transformer as T
        from repro.models.config import ShardingConfig
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = reduced(get_arch("phi3.5-moe-42b-a6.6b").model).replace(
            d_model=64, moe_experts=8,
            sharding=ShardingConfig(enabled=True, data_axes=("data",),
                                    model_axis="model",
                                    fsdp_axes=("data",)))
        shapes = jax.eval_shape(lambda: T.init_params(
            jax.random.PRNGKey(0), cfg))
        specs = param_specs(shapes, cfg, mesh, fsdp=True)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {"/".join(str(getattr(k, "key", k)) for k in kp): v
             for kp, v in flat}
        moe_wi = [v for k, v in d.items() if "moe/wi" in k][0]
        assert moe_wi[1] == "model", moe_wi   # experts on model axis
        emb = [v for k, v in d.items() if "embed/tokens" in k][0]
        assert emb[0] == "model", emb         # vocab on model axis
        norms = [v for k, v in d.items() if "ln1/scale" in k]
        assert all(all(e is None for e in v) for v in norms)
        print("OK")
    """)
    assert "OK" in out


def test_hlo_analyzer_trip_counts():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import analyze
        def scanned(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
        comp = jax.jit(scanned).lower(x, ws).compile()
        st = analyze(comp.as_text())
        expected = 12 * 2 * 128 ** 3
        assert abs(st.dot_flops - expected) / expected < 1e-6, st.dot_flops
        assert 12 in st.while_trip_counts
        print("OK")
    """)
    assert "OK" in out


def test_moe_fused_ep_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import meshctx
        from repro.models.config import ModelConfig, ShardingConfig
        from repro.models import moe as M

        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                          moe_experts=8, moe_top_k=2, moe_d_ff=64,
                          n_shared_experts=1,
                          dtype="float32", moe_capacity_factor=8.0,
                          moe_fused_ep=True,
                          sharding=ShardingConfig(enabled=True,
                                                  data_axes=("data",),
                                                  model_axis="model"))
        p = M.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        y_ref, aux_ref = M.moe_apply(p, cfg.replace(moe_fused_ep=False), x)
        mesh = make_mesh((2, 4), ("data", "model"))
        with meshctx.use_mesh(mesh):
            y_ep, aux_ep = jax.jit(lambda p, x: M.moe_apply(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(y_ep - y_ref)))
        aerr = abs(float(aux_ep) - float(aux_ref))
        assert err < 1e-4, err
        assert aerr < 1e-4, (float(aux_ep), float(aux_ref))
        # gradients flow through the fused path
        g = jax.grad(lambda p: jnp.sum(M.moe_apply(p, cfg, x)[0]**2))(p)
        with meshctx.use_mesh(mesh):
            g2 = jax.jit(jax.grad(
                lambda p: jnp.sum(M.moe_apply(p, cfg, x)[0]**2)))(p)
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g2)))
        assert gerr < 1e-2, gerr
        print("OK", err, gerr)
    """)
    assert "OK" in out


def test_moe_expert_2d_matches_single_device():
    """2-D resident-expert serving path (E:model, d:data) must be exact."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed import meshctx
        from repro.models.config import ModelConfig, ShardingConfig
        from repro.models import moe as M

        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                          moe_experts=8, moe_top_k=2, moe_d_ff=64,
                          dtype="float32", moe_capacity_factor=8.0,
                          moe_expert_2d=True,
                          sharding=ShardingConfig(enabled=True,
                                                  data_axes=("data",),
                                                  model_axis="model"))
        p = M.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        y_ref, _ = M.moe_apply(p, cfg.replace(moe_expert_2d=False), x)
        mesh = make_mesh((2, 4), ("data", "model"))
        with meshctx.use_mesh(mesh):
            y_2d, _ = jax.jit(lambda p, x: M.moe_apply(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(y_2d - y_ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out
