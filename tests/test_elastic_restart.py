"""Elastic restart: checkpoint written under one mesh restores onto a
DIFFERENT mesh (the node-loss recovery path), bitwise-identical logical
values, resharded placement.  Runs in an 8-virtual-device subprocess."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_checkpoint_restores_across_mesh_shapes():
    out = _run("""
        import tempfile, numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.distributed.fault_tolerance import plan_elastic_restart

        # train mesh: 4 data x 2 model; params sharded
        mesh_a = make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
        w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 5, {"w": w_a}, extra={"step": 5})

            # lose half the machines: replan to a 2x2 mesh, keep TP whole
            plan = plan_elastic_restart(n_devices=4, model_parallel=2,
                                        target_batch=32)
            assert plan.mesh_shape == (2, 2)
            mesh_b = make_mesh(plan.mesh_shape, plan.axis_names)
            sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
            restored, extra = restore_checkpoint(
                d, 5, {"w": jnp.zeros_like(w)}, shardings=sh_b)
            assert extra["step"] == 5
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
            # placement really is on the new mesh
            assert restored["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)
    assert "OK" in out


def test_trainer_continues_on_smaller_mesh():
    """Full loop: train sharded on mesh A, checkpoint, restore into a
    Trainer on mesh B (fewer devices), keep training — loss stays sane."""
    out = _run("""
        import tempfile
        import jax, jax.numpy as jnp
        from repro.configs import get_arch, reduced
        from repro.launch.mesh import make_mesh
        from repro.launch.train import Trainer

        cfg0 = reduced(get_arch("olmo-1b").model).replace(max_seq=64)
        with tempfile.TemporaryDirectory() as d:
            mesh_a = make_mesh((4, 2), ("data", "model"))
            cfg_a = cfg0.replace(sharding=cfg0.sharding.__class__(
                enabled=True, data_axes=("data",), model_axis="model"))
            tr = Trainer(cfg_a, seq_len=64, global_batch=8, ckpt_dir=d,
                         peak_lr=3e-3, seed=1, mesh=mesh_a)
            h0 = tr.train(8, log_every=1000, ckpt_every=8)

            mesh_b = make_mesh((2, 2), ("data", "model"))
            tr2 = Trainer(cfg_a, seq_len=64, global_batch=8, ckpt_dir=d,
                          peak_lr=3e-3, seed=1, mesh=mesh_b)
            assert tr2.maybe_restore(), "restore failed"
            assert tr2.step == 8
            h1 = tr2.train(4, log_every=1000)
            assert h1["loss"][0] < h0["loss"][0] + 0.5  # no blow-up
        print("OK")
    """)
    assert "OK" in out
