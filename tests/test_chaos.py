"""Self-healing serving plane regression suite.

Every recovery path is exercised against the same oracle discipline as
the rest of the differential suite: whatever survives a fault must be
BYTE-EXACT against a fault-free serial run of the same compiled
artifact, and whatever is lost must fail with a typed error — never a
hang, never silently-wrong bytes.

Covers (ISSUE 9):
  * slot respawn from the pristine staged image + death/respawn stats,
    post-respawn outputs byte-diffed vs fault-free serial;
  * session checkpoint/restore replaying to the correct step on both
    engines x both fence modes;
  * stateless request retry (transparent success, exhaustion surfacing
    the ORIGINAL typed error with the attempt count);
  * segment watchdog (fires on a hung host fn; never fires on the
    slowest legitimate gang — the TimingModel false-positive guard);
  * DRAM integrity checksums + restage-from-pristine under injected
    bit-flips, and the seeded FaultPlan that scripts all of the above;
  * the satellites: atomic session swap under kill, parked-deadline vs
    respawn ordering in the Scheduler, PoolFuture.wait(timeout=)
    raising typed WaitTimeout.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.chaos import FAULT_KINDS, Fault, FaultPlan
from repro.core.program import Program
from repro.core.sched import DeadlineExpired, SchedConfig, Scheduler
from repro.core.scheduler import Epilogue, matmul_reference
from repro.core.serve import (DevicePool, PoolClosed, SlotDied,
                              WaitTimeout, WatchdogConfig,
                              WatchdogTimeout)

BACKENDS = ("simulator", "pallas")
_EP = Epilogue(shift=6, relu=True)


def _mlp(rng, m=16, d=32, layers=2):
    ws = [rng.integers(-64, 64, size=(d, d), dtype=np.int8)
          for _ in range(layers)]
    p = Program()
    t = p.input("x", (m, d))
    for i, w in enumerate(ws):
        t = p.matmul(t, p.constant(f"w{i}", w), epilogue=_EP)

    def make():
        return {"x": rng.integers(-64, 64, size=(m, d), dtype=np.int8)}

    def ref(feed):
        r = feed["x"]
        for w in ws:
            r = matmul_reference(r, w, _EP)
        return r

    return p, make, ref


def _hostful(rng, hostfn, m=16, d=32):
    """matmul -> host -> matmul: a request that can be caught INSIDE
    its host stage (the deterministic mid-flight kill hook)."""
    w1 = rng.integers(-64, 64, size=(d, d), dtype=np.int8)
    w2 = rng.integers(-64, 64, size=(d, d), dtype=np.int8)
    p = Program()
    x = p.input("x", (m, d))
    t = p.matmul(x, p.constant("w1", w1), epilogue=_EP)
    t = p.host(hostfn, t, shape=(m, d), kind="mat")
    p.output(p.matmul(t, p.constant("w2", w2), epilogue=_EP))

    def make():
        return {"x": rng.integers(-64, 64, size=(m, d), dtype=np.int8)}

    def ref(feed):
        a = matmul_reference(feed["x"], w1, _EP)
        return matmul_reference(np.asarray(hostfn(a)), w2, _EP)

    return p, make, ref


def _accumulator(m=8, k=32):
    """Stateful decode-shaped program: each call accumulates into a
    persistent buffer, so the session's step count is byte-visible."""
    p = Program(hwspec.pynq())
    x = p.input("x", (m, k))
    w = p.constant("w", np.random.default_rng(0).integers(
        -8, 8, (k, k), dtype=np.int8))
    h = p.matmul(x, w, epilogue=Epilogue(shift=5), name="h")
    state = p.persistent("state", (m, k))

    def accum(hv, sv):
        ns = np.clip(sv.astype(np.int32) + hv, -128, 127).astype(np.int8)
        return ns, ns

    p.output(p.host(accum, h, state, shape=(m, k), kind="mat",
                    updates=(state,)))
    return p


# ----------------------------------------------------------------------
# FaultPlan
# ----------------------------------------------------------------------
def test_fault_plan_seeded_and_consumed_once():
    a = FaultPlan.random(seed=11, n_gangs=300, slots=4, rate=0.25)
    b = FaultPlan.random(seed=11, n_gangs=300, slots=4, rate=0.25)
    assert [f for f in a.faults] == [f for f in b.faults]  # deterministic
    assert len(a) > 0
    assert all(f.kind in FAULT_KINDS for f in a.faults)
    assert all(f.gang != 0 for f in a.faults)   # gang 0 always clean
    g = a.faults[0].gang
    took = a.take(g)
    assert took and a.take(g) == []             # consume-once
    with pytest.raises(ValueError, match="not in"):
        Fault(kind="meteor", gang=1)
    with pytest.raises(ValueError, match="rate"):
        FaultPlan.random(seed=1, n_gangs=10, slots=2, rate=1.5)


# ----------------------------------------------------------------------
# slot respawn
# ----------------------------------------------------------------------
def test_respawn_then_byte_exact():
    """A killed slot respawns from the pristine image and every
    post-recovery output byte-matches the fault-free serial run."""
    rng = np.random.default_rng(21)
    p, make, ref = _mlp(rng)
    c = p.compile(use_cache=False)
    feeds = [make() for _ in range(6)]
    serial = [c(backend="simulator", **f) for f in feeds]
    with DevicePool(c, size=2, backend="simulator",
                    max_respawns=2) as pool:
        assert pool.kill_slot(0) == 0
        st = pool.slots[0].stats
        assert not pool.slots[0].dead       # rebuilt, back in rotation
        assert (st.deaths, st.respawns) == (1, 1)
        futs = [pool.submit(**f) for f in feeds]
        for fu, want, feed in zip(futs, serial, feeds):
            got = fu.wait(timeout=120)
            np.testing.assert_array_equal(got, want)
            np.testing.assert_array_equal(got, ref(feed))
        # both slots served: the respawned one is genuinely alive
        assert all(s.stats.calls > 0 for s in pool.slots)
        assert "1 death(s)/1 respawn(s)" in pool.describe()


def test_respawn_cap_is_honored():
    """Past max_respawns the slot stays dead; respawn_slot() is the
    explicit ops override."""
    rng = np.random.default_rng(22)
    p, make, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    with DevicePool(c, size=2, backend="simulator",
                    max_respawns=1) as pool:
        pool.kill_slot(0)
        assert not pool.slots[0].dead       # 1st death: respawned
        pool.kill_slot(0)
        assert pool.slots[0].dead           # cap reached: stays dead
        assert pool.slots[0].stats.deaths == 2
        assert pool.slots[0].stats.respawns == 1
        assert pool.respawn_slot(0)         # ops override ignores cap
        assert not pool.slots[0].dead
        assert not pool.respawn_slot(0)     # alive: no-op
        pool.submit(**make()).wait(timeout=120)


# ----------------------------------------------------------------------
# stateless retry
# ----------------------------------------------------------------------
def test_retry_survives_mid_flight_kill_byte_exact():
    """A stateless request killed INSIDE its host stage retries on the
    respawned pool and succeeds byte-exactly; the future records the
    attempt count."""
    entered, release = threading.Event(), threading.Event()

    def blocker(a):
        entered.set()
        release.wait(timeout=60)
        return np.ascontiguousarray(a[::-1])

    rng = np.random.default_rng(23)
    p, make, ref = _hostful(rng, blocker)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=2, backend="simulator", max_respawns=4,
                      retries=2, retry_backoff_s=0.01)
    try:
        feed = make()
        f = pool.submit(**feed)
        assert entered.wait(timeout=60), "request never reached host"
        victim = next(s.id for s in pool.slots
                      if s.active is not None or s.queue)
        release.set()
        pool.kill_slot(victim)
        got = f.wait(timeout=120)           # transparent recovery
        assert f.attempts == 2
        np.testing.assert_array_equal(got, ref(feed))
    finally:
        release.set()
        pool.close()


def test_retry_exhaustion_surfaces_original_error_and_attempts():
    """When every slot is gone the ORIGINAL typed error surfaces, with
    the attempt count on both the error and the future."""
    rng = np.random.default_rng(24)
    p, make, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=1, backend="simulator", retries=2,
                      retry_backoff_s=0.05)  # no respawn: retry starves
    try:
        f = pool.submit(**make())
        pool.kill_slot(0)
        with pytest.raises(SlotDied, match=r"request #\d+") as ei:
            f.wait(timeout=120)
        assert f.attempts >= 2              # it did try again
        assert ei.value.attempts == f.attempts
    finally:
        pool.close()


def test_stateful_slot_resident_submits_never_retry():
    """Sessionless submits of a PERSISTENT program mutate implicit
    per-slot state — a replay would double-advance it, so they must
    fail typed instead of retrying."""
    c = _accumulator().compile(use_cache=False)
    pool = DevicePool(c, size=1, backend="simulator", retries=3,
                      retry_backoff_s=0.01)
    try:
        x = np.ones((8, 32), np.int8)
        pool.submit(x=x).wait(timeout=120)
        f = pool.submit(x=x)
        pool.kill_slot(0)
        with pytest.raises(SlotDied):
            f.wait(timeout=120)
        assert f.attempts == 1              # never re-submitted
    finally:
        pool.close()


# ----------------------------------------------------------------------
# session checkpoint / restore
# ----------------------------------------------------------------------
@pytest.mark.parametrize("fence_mode", ("buffer", "barrier"))
@pytest.mark.parametrize("backend", BACKENDS)
def test_session_restore_replays_to_correct_step(backend, fence_mode):
    """Kill a session's slot mid-conversation: the session restores its
    last checkpoint onto the respawned slot, replays to the correct
    step, and the final state byte-matches a fault-free serial run —
    on both engines x both fence modes."""
    c = _accumulator().compile(use_cache=False, fence_mode=fence_mode)
    x = np.ones((8, 32), np.int8)
    # fault-free serial oracle: 6 calls on a fresh clone
    dev = c.device.clone(trim=True)
    serial = [c.run_on(dev, backend=backend, inputs={"x": x}).outputs
              for _ in range(6)]
    pool = DevicePool(c, size=2, backend=backend, max_respawns=2,
                      checkpoint_every=1)
    try:
        s = pool.session(slot=0)
        for i in range(4):
            got = s.submit(x=x).wait(timeout=120)
            np.testing.assert_array_equal(got, serial[i])
        pool.kill_slot(0)
        assert not pool.slots[0].dead
        assert s.stats.restores == 1
        assert s.stats.restored_from_step == 4   # replayed steps VISIBLE
        assert s.calls == 4
        for i in range(4, 6):                    # conversation continues
            got = s.submit(x=x).wait(timeout=120)
            np.testing.assert_array_equal(got, serial[i])
        # the accumulator's state buffer holds exactly the last output
        np.testing.assert_array_equal(s.state("state"), serial[5])
    finally:
        pool.close()


def test_session_checkpoint_interval_rolls_back_unsnapshotted_steps():
    """checkpoint_every=2 with a kill after 3 calls restores step 2 —
    the replayed step is visible via restored_from_step, and re-running
    it reconverges with the serial oracle."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    dev = c.device.clone(trim=True)
    serial = [c.run_on(dev, backend="simulator",
                       inputs={"x": x}).outputs for _ in range(4)]
    pool = DevicePool(c, size=1, backend="simulator", max_respawns=2,
                      checkpoint_every=2)
    try:
        s = pool.session(slot=0)
        for i in range(3):
            s.submit(x=x).wait(timeout=120)
        assert s.stats.checkpoints == 1 and s.stats.checkpoint_step == 2
        pool.kill_slot(0)
        assert s.calls == 2                      # rolled back to ckpt
        assert s.stats.restored_from_step == 2
        got = s.submit(x=x).wait(timeout=120)    # replays step 3
        np.testing.assert_array_equal(got, serial[2])
        got = s.submit(x=x).wait(timeout=120)
        np.testing.assert_array_equal(got, serial[3])
    finally:
        pool.close()


def test_session_without_checkpoint_is_lost_typed():
    """No checkpoint to fall back on: the session is marked lost and
    every later submit fails typed — never silently-wrong state."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    pool = DevicePool(c, size=1, backend="simulator", max_respawns=2)
    try:
        s = pool.session(slot=0)
        s.submit(x=x).wait(timeout=120)
        pool.kill_slot(0)
        with pytest.raises(SlotDied, match="lost"):
            s.submit(x=x)
        # a VIRGIN session (never ran) survives the same death
        pool2_sess = pool.session(slot=0)
        pool2_sess.submit(x=x).wait(timeout=120)
        assert pool2_sess.calls == 1
    finally:
        pool.close()


def test_rehome_when_respawn_cap_exhausted():
    """A checkpointed session whose slot stays dead (cap exhausted) is
    re-homed to a survivor and keeps serving from its snapshot."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    dev = c.device.clone(trim=True)
    serial = [c.run_on(dev, backend="simulator",
                       inputs={"x": x}).outputs for _ in range(3)]
    pool = DevicePool(c, size=2, backend="simulator", max_respawns=0,
                      checkpoint_every=1)
    try:
        s = pool.session(slot=0)
        for i in range(2):
            s.submit(x=x).wait(timeout=120)
        pool.kill_slot(0)
        assert pool.slots[0].dead               # no respawn budget
        assert s.slot_id == 1                   # re-homed to survivor
        assert s.stats.rehomes == 1
        got = s.submit(x=x).wait(timeout=120)
        np.testing.assert_array_equal(got, serial[2])
    finally:
        pool.close()


# ----------------------------------------------------------------------
# satellite: atomic session swap under kill
# ----------------------------------------------------------------------
def test_kill_during_session_swap_never_half_swaps():
    """Kill the slot while a session swap-out/swap-in is IN PROGRESS:
    the swap completes atomically under the slot lock before the
    respawn replaces the device, so the swapped-out session's host
    image is complete and it keeps serving byte-exactly."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    dev = c.device.clone(trim=True)
    serial = [c.run_on(dev, backend="simulator",
                       inputs={"x": x}).outputs for _ in range(3)]
    pool = DevicePool(c, size=1, backend="simulator", max_respawns=4,
                      checkpoint_every=1)
    try:
        s1 = pool.session(slot=0)
        s2 = pool.session(slot=0)
        for _ in range(2):
            s1.submit(x=x).wait(timeout=120)    # s1 resident, 2 steps

        # instrument the swap: persistent_image (the swap-OUT of s1)
        # signals mid-swap and stalls until the killer has fired
        in_swap, killed = threading.Event(), threading.Event()
        orig = type(c).persistent_image

        def slow_image(self, device=None):
            if device is not None:              # slot swap path only
                in_swap.set()
                killed.wait(timeout=60)
                time.sleep(0.05)                # let kill_slot block
            return orig(self, device=device)

        type(c).persistent_image = slow_image
        try:
            f2 = s2.submit(x=x)                 # forces s2 swap-in
            assert in_swap.wait(timeout=60), "swap never started"
            t = threading.Thread(target=pool.kill_slot, args=(0,))
            t.start()
            killed.set()
            t.join(timeout=60)
            assert not t.is_alive()
        finally:
            type(c).persistent_image = orig
        # s2's request died with the slot (it never ran a step)...
        with pytest.raises(SlotDied):
            f2.wait(timeout=120)
        # ...but s1 was swapped out COMPLETELY before the respawn: its
        # image replays byte-exactly on the rebuilt slot
        got = s1.submit(x=x).wait(timeout=120)
        assert s1.calls == 3
        np.testing.assert_array_equal(got, serial[2])
    finally:
        pool.close()


# ----------------------------------------------------------------------
# satellite: Scheduler parked-deadline vs respawn race
# ----------------------------------------------------------------------
def test_parked_deadline_expires_when_respawn_never_arrives():
    """A session request parked for a dead slot counts down its
    deadline and fails DeadlineExpired — NOT SlotDied — when no respawn
    arrives (ordering 1: deadline first)."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    pool = DevicePool(c, size=2, backend="simulator", max_respawns=0,
                      checkpoint_every=1)
    sched = Scheduler(pool, SchedConfig(window_us=200.0, gang_width=1))
    try:
        ss = sched.session(slot=0)
        ss.submit(x=x).wait(timeout=120)
        # with max_respawns=0 a kill re-homes the session to a survivor,
        # so kill BOTH slots: nothing can serve it, and the parked
        # request must fail on ITS deadline, typed DeadlineExpired — not
        # a premature SlotDied
        pool.kill_slot(1)
        pool.kill_slot(0)
        fut = ss.submit(deadline_us=200_000.0, x=x)
        with pytest.raises(DeadlineExpired, match="deadline lapsed"):
            fut.wait(timeout=120)
    finally:
        sched.close()
        pool.close()


def test_parked_request_survives_when_respawn_arrives_first():
    """Ordering 2: the respawn lands before the deadline — the parked
    request is released to the revived slot and completes."""
    c = _accumulator().compile(use_cache=False)
    x = np.ones((8, 32), np.int8)
    dev = c.device.clone(trim=True)
    serial = [c.run_on(dev, backend="simulator",
                       inputs={"x": x}).outputs for _ in range(2)]
    pool = DevicePool(c, size=1, backend="simulator", max_respawns=0,
                      checkpoint_every=1)
    sched = Scheduler(pool, SchedConfig(window_us=200.0, gang_width=1))
    try:
        ss = sched.session(slot=0)
        got = ss.submit(x=x).wait(timeout=120)
        np.testing.assert_array_equal(got, serial[0])
        pool.kill_slot(0)                   # only slot: nothing to
        assert pool.slots[0].dead           # rehome to, session keeps
        fut = ss.submit(deadline_us=30e6, x=x)   # its checkpoint
        assert not fut.done()               # parked: slot is down
        assert pool.respawn_slot(0)         # respawn wins the race
        got = fut.wait(timeout=120)
        np.testing.assert_array_equal(got, serial[1])
    finally:
        sched.close()
        pool.close()


def test_scheduler_retunes_width_to_surviving_slots():
    """Gang widths re-tune to the surviving slot count when a slot dies
    past its respawn budget (full-width releases must not stall waiting
    for a width the pool can no longer co-schedule), and tune back up
    after an explicit respawn."""
    rng = np.random.default_rng(27)
    p, make, ref = _mlp(rng)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=4, backend="simulator")
    sched = Scheduler(pool, SchedConfig(window_us=300.0, gang_width=4))
    try:
        assert sched.gang_widths == [4]
        pool.kill_slot(3)                   # terminal: no respawn budget
        # full batches must still release at the degraded width instead
        # of stalling forever at 4
        feeds = [make() for _ in range(6)]
        futs = [sched.submit(**f) for f in feeds]
        for fu, feed in zip(futs, feeds):
            np.testing.assert_array_equal(fu.wait(timeout=120),
                                          ref(feed))
        assert sched.gang_widths == [3]
        assert pool.respawn_slot(3)         # ops revival
        feeds = [make() for _ in range(4)]
        futs = [sched.submit(**f) for f in feeds]
        for fu, feed in zip(futs, feeds):
            np.testing.assert_array_equal(fu.wait(timeout=120),
                                          ref(feed))
        assert sched.gang_widths == [4]     # tuned back up
    finally:
        sched.close()
        pool.close()


# ----------------------------------------------------------------------
# segment watchdog
# ----------------------------------------------------------------------
def test_watchdog_kills_hung_host_fn_and_pool_recovers():
    """A host fn that never returns trips the watchdog: the slot is
    killed (typed WatchdogTimeout at the future), respawned, and the
    pool keeps serving other programs' requests."""
    hung = threading.Event()
    unhang = threading.Event()

    def hang(a):
        hung.set()
        unhang.wait(timeout=120)            # far past the deadline
        return a

    rng = np.random.default_rng(28)
    p, make, _ = _hostful(rng, hang)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=2, backend="simulator", max_respawns=2,
                      watchdog=WatchdogConfig(mult=2.0, floor_s=0.3,
                                              poll_s=0.05))
    try:
        f = pool.submit(**make())
        assert hung.wait(timeout=60)
        with pytest.raises(WatchdogTimeout, match="watchdog deadline"):
            f.wait(timeout=120)
        assert sum(s.stats.watchdog_kills for s in pool.slots) >= 1
        assert "watchdog kill" in pool.describe()
    finally:
        unhang.set()
        pool.close(timeout=10)


def test_watchdog_never_fires_on_slowest_legitimate_gang():
    """False-positive guard: gangs priced by the TimingModel get a
    budget the SLOWEST legitimate execution stays well inside — a full
    serving sweep under an armed watchdog ends with zero kills."""
    rng = np.random.default_rng(29)
    p, make, ref = _mlp(rng, m=16, d=32, layers=3)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=4, backend="pallas",
                      watchdog=WatchdogConfig())    # default budget
    try:
        feeds = [make() for _ in range(12)]
        futs = [pool.submit(**f) for f in feeds]
        for fu, feed in zip(futs, feeds):
            np.testing.assert_array_equal(fu.wait(timeout=300),
                                          ref(feed))
        assert sum(s.stats.watchdog_kills for s in pool.slots) == 0
        assert sum(s.stats.deaths for s in pool.slots) == 0
    finally:
        pool.close()


# ----------------------------------------------------------------------
# DRAM integrity
# ----------------------------------------------------------------------
def test_injected_bit_flip_detected_and_restaged_byte_exact():
    """A scripted constant-region bit-flip is caught by the pre-gang
    checksum and restaged from the pristine image: every output still
    byte-matches the fault-free serial run."""
    rng = np.random.default_rng(30)
    p, make, ref = _mlp(rng)
    c = p.compile(use_cache=False)
    feeds = [make() for _ in range(8)]
    serial = [c(backend="simulator", **f) for f in feeds]
    plan = FaultPlan(faults=[Fault(kind="flip", gang=1, slot=0, byte=77),
                             Fault(kind="flip", gang=3, slot=1,
                                   byte=1 << 20)])
    pool = DevicePool(c, size=2, backend="simulator", integrity=True,
                      fault_plan=plan)
    try:
        futs = [pool.submit(**f) for f in feeds]
        for fu, want in zip(futs, serial):
            np.testing.assert_array_equal(fu.wait(timeout=120), want)
        assert plan.fired_counts().get("flip", 0) == 2
        assert sum(s.stats.integrity_restages for s in pool.slots) >= 1
        assert pool.verify_integrity() == []    # clean after repair
    finally:
        pool.close()


def test_verify_integrity_audit_and_repair_modes():
    """Manual corruption: the audit reports it; repair=False raises
    typed; repair=True restages and a re-audit is clean."""
    from repro.core.serve import IntegrityError
    rng = np.random.default_rng(31)
    p, make, ref = _mlp(rng)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=2, backend="simulator", integrity=True)
    try:
        feed = make()
        pool.submit(**feed).wait(timeout=120)
        name, addr, nbytes = c.integrity_regions()[0]
        pool.slots[0].device.dram.mem[addr] ^= 0xFF
        with pytest.raises(IntegrityError, match="constant region"):
            pool.verify_integrity(repair=False)
        findings = pool.verify_integrity()      # repair
        assert findings and "slot0" in findings[0]
        assert pool.verify_integrity() == []
        np.testing.assert_array_equal(
            pool.submit(**feed).wait(timeout=120), ref(feed))
    finally:
        pool.close()


# ----------------------------------------------------------------------
# chaos gauntlet: seeded FaultPlan, survivors byte-exact, losses typed
# ----------------------------------------------------------------------
def test_chaos_gauntlet_survivors_byte_exact_losses_typed():
    """Seeded kills+flips+delays at a high per-gang rate: every
    surviving request byte-matches the fault-free serial run, every
    loss is typed, and no wait() ever hangs."""
    rng = np.random.default_rng(32)
    p, make, ref = _mlp(rng)
    c = p.compile(use_cache=False)
    feeds = [make() for _ in range(24)]
    serial = [c(backend="simulator", **f) for f in feeds]
    plan = FaultPlan.random(seed=99, n_gangs=200, slots=3, rate=0.25,
                            max_delay_s=0.005)
    pool = DevicePool(c, size=3, backend="simulator", max_respawns=8,
                      retries=3, retry_backoff_s=0.01, integrity=True,
                      fault_plan=plan)
    survivors = losses = 0
    try:
        futs = [pool.submit(**f) for f in feeds]
        for fu, want in zip(futs, serial):
            try:
                got = fu.wait(timeout=300)      # bounded: never hangs
            except (SlotDied, PoolClosed, WatchdogTimeout):
                losses += 1                     # typed, accounted
                continue
            survivors += 1
            np.testing.assert_array_equal(got, want)
        assert survivors > 0
        # reconciliation: whatever fired is on the record
        assert len(pool.fault_log) == len(plan.fired)
    finally:
        pool.close()


# ----------------------------------------------------------------------
# satellite: PoolFuture.wait(timeout=) -> typed WaitTimeout
# ----------------------------------------------------------------------
def test_pool_future_wait_timeout_typed():
    entered, release = threading.Event(), threading.Event()

    def blocker(a):
        entered.set()
        release.wait(timeout=60)
        return a

    rng = np.random.default_rng(33)
    p, make, _ = _hostful(rng, blocker)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=1, backend="simulator")
    try:
        f = pool.submit(**make())
        assert entered.wait(timeout=60)
        with pytest.raises(WaitTimeout, match=rf"request #{f.seq}"):
            f.wait(timeout=0.05)
        assert isinstance(WaitTimeout("x"), TimeoutError)  # catchable
        release.set()
        f.wait(timeout=120)                 # still completes after
    finally:
        release.set()
        pool.close()


def test_sched_future_wait_timeout_typed():
    entered, release = threading.Event(), threading.Event()

    def blocker(a):
        entered.set()
        release.wait(timeout=60)
        return a

    rng = np.random.default_rng(34)
    p, make, _ = _hostful(rng, blocker)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=1, backend="simulator")
    sched = Scheduler(pool, SchedConfig(window_us=100.0, gang_width=1))
    try:
        f = sched.submit(**make())
        assert entered.wait(timeout=60)
        with pytest.raises(WaitTimeout, match="not done within"):
            f.wait(timeout=0.05)
        release.set()
        f.wait(timeout=120)
    finally:
        release.set()
        sched.close()
        pool.close()
