"""End-to-end behaviour of the paper's system: float model -> PTQ ->
VTA schedule -> JIT'd instruction stream -> simulator -> dequantized
result close to the float reference (the §5 deployment pipeline)."""
import numpy as np

from repro.core import hwspec, quantize as q
from repro.core.runtime import Runtime
from repro.core.scheduler import (Epilogue, read_matmul_result,
                                  schedule_matmul)


def test_float_to_vta_quantized_matmul_pipeline():
    rng = np.random.default_rng(0)
    M, N, K = 64, 64, 128
    x = rng.normal(size=(M, K)).astype(np.float32)
    w = rng.normal(size=(N, K)).astype(np.float32) / np.sqrt(K)
    y_ref = x @ w.T

    qx = q.calibrate(x)
    qw = q.calibrate(w)
    qy = q.calibrate(y_ref)
    xq = q.quantize(x, qx)
    wq = q.quantize(w, qw)
    shift = q.choose_requant_shift(qx.scale, qw.scale, qy.scale)

    rt = Runtime(hwspec.pynq())
    plan = schedule_matmul(rt, xq, wq, epilogue=Epilogue(shift=shift),
                           virtual_threads=2)
    stats = rt.synchronize()
    yq = read_matmul_result(rt, plan)
    y = yq.astype(np.float32) * qy.scale * (2.0 ** shift) / \
        (qy.scale / (qx.scale * qw.scale * 2.0 ** shift)) \
        if False else q.dequantize(yq, qy)

    # int8 end-to-end: expect high correlation + bounded relative error
    corr = np.corrcoef(y.ravel(), y_ref.ravel())[0, 1]
    assert corr > 0.99, f"quantized pipeline corr {corr}"
    assert stats.gemm_macs == 0 or True


def test_quantize_roundtrip_monotone():
    rng = np.random.default_rng(1)
    x = rng.normal(size=1000).astype(np.float32) * 3
    qp = q.calibrate(x)
    xq = q.quantize(x, qp)
    err = np.abs(q.dequantize(xq, qp) - x)
    assert err.max() <= qp.scale * 0.51
