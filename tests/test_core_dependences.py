"""Dependence-token correctness: the Fig. 5 argument, as executable tests.

(1) results must be invariant to instruction latency (any timing model);
(2) stripping WAR tokens from a double-buffered stream corrupts results
    or deadlocks — dependences are load-bearing, not decorative;
(3) net-negative token balance is rejected by the runtime validator.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hwspec
from repro.core.isa import DepFlags, FinishInsn, Insn, Opcode, route_queue
from repro.core.runtime import Runtime
from repro.core.scheduler import matmul_reference, read_matmul_result, \
    schedule_matmul
from repro.core.simulator import (DeadlockError, RunStats, Simulator,
                                  TimingModel, run_program)


class JitterTiming(TimingModel):
    """Random (but deterministic per-seed) per-instruction latencies."""

    def __init__(self, spec, seed):
        super().__init__(spec)
        self.rng = np.random.default_rng(seed)

    def latency(self, insn, spec):
        return int(self.rng.integers(1, 1000))


def _schedule(vt, seed=0, M=64, N=64, K=256):
    spec = hwspec.pynq()
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=(M, K), dtype=np.int8)
    w = rng.integers(-128, 128, size=(N, K), dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_matmul(rt, a, w, virtual_threads=vt)
    return rt, plan, a, w


@given(seed=st.integers(0, 2**16), vt=st.sampled_from([1, 2]))
@settings(max_examples=12, deadline=None)
def test_result_invariant_under_latency_jitter(seed, vt):
    """With correct tokens, ANY latency assignment yields the same result —
    the defining property of a correctly synchronized decoupled
    access-execute stream."""
    rt, plan, a, w = _schedule(vt, seed=seed % 7)
    rt.synchronize(timing=JitterTiming(rt.spec, seed))
    got = read_matmul_result(rt, plan)
    np.testing.assert_array_equal(got, matmul_reference(a, w))


def _strip_flags(insns, which):
    out = []
    for i in insns:
        d = i.dep
        nd = DepFlags(
            pop_prev=d.pop_prev and "pop_prev" not in which,
            pop_next=d.pop_next and "pop_next" not in which,
            push_prev=d.push_prev and "push_prev" not in which,
            push_next=d.push_next and "push_next" not in which)
        i.dep = nd
        out.append(i)
    return out


def test_stripping_war_tokens_corrupts_or_deadlocks():
    """Fig. 5: without WAR dependences a producer can overwrite SRAM before
    the consumer reads it.  We strip the c2l WAR edge (compute->load
    push_prev / load pop_next) and expect wrong results."""
    rt, plan, a, w = _schedule(vt=2, M=256, N=64, K=512)
    stripped = _strip_flags(rt.stream, {"push_prev", "pop_next"})
    stripped.append(FinishInsn(dep=DepFlags()))
    stream = rt.isa.encode_stream(stripped)
    # slow compute, fast loads => loads of iteration k+1 overwrite inputs
    class SlowCompute(TimingModel):
        def latency(self, insn, spec):
            from repro.core.isa import GemmInsn
            return 10_000 if isinstance(insn, GemmInsn) else 1
    run_program(rt.spec, rt.device, stream, timing=SlowCompute(rt.spec))
    got = read_matmul_result(rt, plan)
    want = matmul_reference(a, w)
    assert not np.array_equal(got, want), \
        "stripping WAR tokens should corrupt a double-buffered schedule"


def test_stripping_raw_tokens_corrupts():
    """Without RAW tokens the compute module runs ahead of the loader."""
    rt, plan, a, w = _schedule(vt=2)
    stripped = _strip_flags(rt.stream, {"push_next", "pop_prev"})
    stripped.append(FinishInsn(dep=DepFlags()))
    stream = rt.isa.encode_stream(stripped)
    class SlowLoad(TimingModel):
        def latency(self, insn, spec):
            from repro.core.isa import LoadStoreInsn
            return 10_000 if (isinstance(insn, LoadStoreInsn)
                              and insn.opcode == Opcode.LOAD) else 1
    run_program(rt.spec, rt.device, stream, timing=SlowLoad(rt.spec))
    got = read_matmul_result(rt, plan)
    assert not np.array_equal(got, matmul_reference(a, w))


def test_validator_rejects_negative_balance():
    spec = hwspec.pynq()
    rt = Runtime(spec)
    from repro.core.isa import MemId
    rt.dep_pop(2, 3)  # pending pop with no matching push
    rt.store_buffer_2d(0, 0, 1, 1, 1)
    with pytest.raises(ValueError):
        rt.validate_stream()


def test_deadlock_detection():
    """A pop with no pending producer must be detected, not hang."""
    spec = hwspec.pynq()
    rt = Runtime(spec)
    rt.dep_pop(2, 3)
    rt.store_buffer_2d(0, 0, 1, 1, 1)
    stream = rt.isa.encode_stream(rt.stream + [FinishInsn(dep=DepFlags())])
    with pytest.raises(DeadlockError):
        run_program(spec, rt.device, stream)


def test_tokens_actually_flow():
    rt, plan, a, w = _schedule(vt=2)
    stats = rt.synchronize()
    assert stats.tokens_pushed > 0
    assert stats.modules["load"].insn_count > 0
    assert stats.modules["store"].insn_count > 0
