"""Concurrency differential suite for the async serving subsystem.

Every pooled/async execution is byte-diffed against serial single-device
execution of the SAME compiled artifact on the same inputs — the
simulator pool is the concurrency oracle, the pallas pool is the ganged
fast path, and both must agree with the synchronous ``CompiledProgram``
call bit for bit: interleaved submits, out-of-order waits, pool sizes
1/2/4, both engines, both fence modes, plus a >=64-submit stress run
under a hard deadline.  The per-slot invariants the PR converts from
single-device invariants are asserted directly: zero per-call DRAM
growth per slot (trimmed clones make allocation an ERROR), and
request-local RunStats that two concurrent pooled calls can never
cross-contaminate.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.backend import PallasBackend
from repro.core.conv import ConvShape, conv2d_reference
from repro.core.program import Program
from repro.core.scheduler import Epilogue, matmul_reference
from repro.core.serve import (BatchServer, DevicePool, PoolClosed,
                              serve_batch)

BACKENDS = ("simulator", "pallas")


def _mlp(rng, layers=2, m=32, d=64, constants=True):
    """Small serving-shaped program (constant weights) + a request
    generator + the numpy reference."""
    ws = [rng.integers(-128, 128, size=(d, d), dtype=np.int8)
          for _ in range(layers)]
    ep = Epilogue(shift=6, relu=True)
    p = Program()
    t = p.input("x", (m, d))
    for i, w in enumerate(ws):
        wref = p.constant(f"w{i}", w) if constants \
            else p.input(f"w{i}", w.shape)
        t = p.matmul(t, wref, epilogue=ep)

    def make_request():
        x = rng.integers(-128, 128, size=(m, d), dtype=np.int8)
        feed = {"x": x}
        if not constants:
            feed.update({f"w{i}": w for i, w in enumerate(ws)})
        return feed

    def reference(feed):
        r = feed["x"]
        for w in ws:
            r = matmul_reference(r, w, ep)
        return r

    return p, make_request, reference


def _hetero_conv(rng):
    """conv -> cpu_only conv -> conv: exercises host steps between
    accelerator segments inside the pool scheduler."""
    s = ConvShape(n=1, h=8, w=8, ic=16, oc=16, kh=3, kw=3, stride=1, pad=1)
    ks = [rng.integers(-8, 8, size=(16, 16, 3, 3), dtype=np.int8)
          for _ in range(3)]
    ep = Epilogue(shift=5, relu=True)
    p = Program()
    t = p.input("x", (1, 16, 8, 8))
    for i, k in enumerate(ks):
        t = p.conv2d(t, p.constant(f"k{i}", k), s, epilogue=ep,
                     cpu_only=(i == 1))

    def make_request():
        return {"x": rng.integers(-64, 64, size=(1, 16, 8, 8),
                                  dtype=np.int8)}

    def reference(feed):
        r = feed["x"]
        for k in ks:
            r = conv2d_reference(r, k, s, epilogue=ep)
        return r

    return p, make_request, reference


# ----------------------------------------------------------------------
# the differential grid: pool sizes x engines x fence modes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("fence_mode", ("buffer", "barrier"))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("size", (1, 2, 4))
def test_pool_matches_serial(size, backend, fence_mode):
    rng = np.random.default_rng(100 * size + len(backend) + len(fence_mode))
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False, fence_mode=fence_mode)
    feeds = [make_request() for _ in range(3 * size)]
    # serial single-device execution of the same inputs — the oracle
    serial = [c(backend=backend, **f) for f in feeds]
    with DevicePool(c, size=size, backend=backend) as pool:
        futs = [pool.submit(**f) for f in feeds]        # interleaved
        # out-of-order waits: last submitted, first waited
        for f, feed, want in reversed(list(zip(futs, feeds, serial))):
            got = f.wait(timeout=120)
            np.testing.assert_array_equal(got, want)
            np.testing.assert_array_equal(got, reference(feed))


def test_pool_dram_image_matches_serial_byte_for_byte():
    """Stronger than output equality: after serving, a slot's trimmed
    DRAM image equals the serial device's allocated image byte for byte
    (same addresses, same data — the clone IS the device)."""
    rng = np.random.default_rng(7)
    p, make_request, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    feed = make_request()
    with DevicePool(c, size=2, backend="pallas") as pool:
        futs = [pool.submit(**feed) for _ in range(2)]   # same feed, both
        [f.wait(timeout=120) for f in futs]
        c(backend="pallas", **feed)                      # serial, after
        used = min(s.device.dram.size for s in pool.slots)
        for slot in pool.slots:
            assert np.array_equal(slot.device.dram.mem[:used],
                                  c.device.dram.mem[:used]), \
                f"slot {slot.id} DRAM image diverged from serial device"


def test_pool_heterogeneous_cpu_steps_overlap():
    """Host segments (cpu_only conv) run through the pool's host worker
    and stay byte-exact vs the serial heterogeneous execution."""
    rng = np.random.default_rng(11)
    p, make_request, reference = _hetero_conv(rng)
    c = p.compile(use_cache=False)
    feeds = [make_request() for _ in range(6)]
    serial = [c(backend="pallas", **f) for f in feeds]
    with DevicePool(c, size=2, backend="pallas") as pool:
        futs = [pool.submit(**f) for f in feeds]
        for f, feed, want in zip(futs, feeds, serial):
            got = f.wait(timeout=240)
            np.testing.assert_array_equal(got, want)
            np.testing.assert_array_equal(got, reference(feed))
        stats = pool.slot_stats()
        assert sum(s.cpu_steps for s in stats) == len(feeds)
        assert sum(s.accel_steps for s in stats) == 2 * len(feeds)


def test_pool_gangs_and_stays_exact_with_per_request_weights():
    """Non-constant weights break the shared-W row-concat optimization;
    the gang must fall back to vmap lanes and stay bit-exact."""
    rng = np.random.default_rng(13)
    p, make_request, reference = _mlp(rng, constants=False)
    c = p.compile(use_cache=False)
    feeds = [make_request() for _ in range(8)]
    with DevicePool(c, size=4, backend="pallas") as pool:
        futs = [pool.submit(**f) for f in feeds]
        for f, feed in zip(futs, feeds):
            np.testing.assert_array_equal(f.wait(timeout=240),
                                          reference(feed))
        assert any(s.ganged_steps for s in pool.slot_stats())


# ----------------------------------------------------------------------
# stress: >= 64 concurrent submits under a deadline
# ----------------------------------------------------------------------
@pytest.mark.timeout(240)
def test_stress_64_concurrent_submits_under_deadline():
    rng = np.random.default_rng(17)
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False)
    feeds = [make_request() for _ in range(64)]
    with DevicePool(c, size=4, backend="pallas",
                    policy="least_loaded") as pool:
        pool.submit(**feeds[0]).wait(timeout=120)        # warm jit caches
        t0 = time.perf_counter()
        # submits race in from 4 producer threads (interleaved arrival)
        futs = [None] * len(feeds)

        def producer(lo):
            for i in range(lo, len(feeds), 4):
                futs[i] = pool.submit(**feeds[i])
        threads = [threading.Thread(target=producer, args=(lo,))
                   for lo in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i in rng.permutation(len(feeds)):            # random wait order
            np.testing.assert_array_equal(futs[i].wait(timeout=120),
                                          reference(feeds[i]))
        elapsed = time.perf_counter() - t0
    assert elapsed < 120, f"64 pooled requests took {elapsed:.1f}s"


# ----------------------------------------------------------------------
# per-slot invariants
# ----------------------------------------------------------------------
def test_zero_per_call_dram_growth_per_slot_and_alloc_is_an_error():
    rng = np.random.default_rng(19)
    p, make_request, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    with DevicePool(c, size=2, backend="pallas") as pool:
        [pool.submit(**make_request()) for _ in range(4)]
        pool.drain(timeout=120)
        marks = [s.device.dram._next for s in pool.slots]
        [pool.submit(**make_request()) for _ in range(8)]
        pool.drain(timeout=120)
        assert [s.device.dram._next for s in pool.slots] == marks, \
            "pooled serving grew a slot's DRAM image"
        # trimmed slot clones turn any allocation into a loud error
        with pytest.raises(MemoryError):
            pool.slots[0].device.dram.alloc(64)


def test_runstats_are_request_local_no_cross_contamination():
    """Satellite bugfix lock-in: two pooled calls must never share a
    RunStats object or leak each other's counters.  Requests with
    different staging sizes run concurrently; each future's stats must
    carry exactly its own staging bytes and segment counts."""
    rng = np.random.default_rng(23)
    p_small, req_small, _ = _mlp(rng, layers=2)
    c = p_small.compile(use_cache=False)
    with DevicePool(c, size=2, backend="pallas") as pool:
        futs = [pool.submit(**req_small()) for _ in range(10)]
        [f.wait(timeout=120) for f in futs]
        seen = set()
        for f in futs:
            assert len(f.stats) == 1                 # one accel segment
            (st,) = f.stats
            assert id(st) not in seen, "RunStats object shared!"
            seen.add(id(st))
            assert st.staging_bytes_per_call == f.staging_bytes > 0
            assert st.n_buffer_fences == 1 and st.n_join_barriers == 0
            assert st.backend == "pallas"
    # the synchronous path serializes fully under the artifact's lock
    # (one shared device image): hammering __call__ from 6 threads must
    # produce each thread's OWN result, not an interleaved one
    p2, req2, ref2 = _mlp(np.random.default_rng(24))
    c2 = p2.compile(use_cache=False)
    before = c2.calls
    feeds = [req2() for _ in range(6)]
    results = [None] * len(feeds)
    errs = []

    def hammer(i):
        try:
            results[i] = c2(backend="simulator", **feeds[i])
        except Exception as e:                       # pragma: no cover
            errs.append(e)
    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(len(feeds))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert c2.calls == before + len(feeds)
    for got, feed in zip(results, feeds):
        np.testing.assert_array_equal(got, ref2(feed))


def test_pool_stats_count_gangs_and_slots_serve_evenly_round_robin():
    rng = np.random.default_rng(29)
    p, make_request, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    with DevicePool(c, size=4, backend="pallas",
                    policy="round_robin") as pool:
        futs = [pool.submit(**make_request()) for _ in range(16)]
        [f.wait(timeout=120) for f in futs]
        stats = pool.slot_stats()
        assert [s.calls for s in stats] == [4, 4, 4, 4]
        assert any(s.ganged_steps for s in stats)
        gang_sizes = {st.gang_size for f in futs for st in f.stats}
        assert max(gang_sizes) > 1, "no request ever ran ganged"
        d = pool.describe()
        assert "pool[4 slots" in d and "slot3:" in d


def test_least_loaded_policy_balances_uneven_queues():
    rng = np.random.default_rng(31)
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False)
    with DevicePool(c, size=2, backend="simulator",
                    policy="least_loaded") as pool:
        feeds = [make_request() for _ in range(8)]
        futs = [pool.submit(**f) for f in feeds]
        for f, feed in zip(futs, feeds):
            np.testing.assert_array_equal(f.wait(timeout=240),
                                          reference(feed))
        calls = sorted(s.calls for s in pool.slot_stats())
        assert sum(calls) == 8 and calls[0] >= 2, calls


# ----------------------------------------------------------------------
# API edges
# ----------------------------------------------------------------------
def test_batch_server_gathers_in_submission_order():
    rng = np.random.default_rng(37)
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False)
    feeds = [make_request() for _ in range(9)]
    outs = serve_batch(c, feeds, size=3, backend="pallas")
    assert len(outs) == len(feeds)
    for o, feed in zip(outs, feeds):
        np.testing.assert_array_equal(o, reference(feed))


def test_closed_pool_rejects_submits_but_finishes_inflight():
    rng = np.random.default_rng(41)
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False)
    pool = DevicePool(c, size=2, backend="simulator")
    feed = make_request()
    fut = pool.submit(**feed)
    pool.close()
    np.testing.assert_array_equal(fut.wait(timeout=120), reference(feed))
    with pytest.raises(PoolClosed):
        pool.submit(**feed)


def test_bad_inputs_fail_fast_in_submit_and_bad_pool_args_raise():
    rng = np.random.default_rng(43)
    p, make_request, _ = _mlp(rng)
    c = p.compile(use_cache=False)
    with pytest.raises(ValueError, match="policy"):
        DevicePool(c, size=2, policy="wat")
    with pytest.raises(ValueError, match="size"):
        DevicePool(c, size=0)
    with DevicePool(c, size=1, backend="simulator") as pool:
        with pytest.raises(ValueError, match="mismatch"):
            pool.submit(nope=np.zeros((32, 64), np.int8))
        # a request failing inside the scheduler surfaces on ITS future
        bad = dict(make_request())
        bad["x"] = np.zeros((1, 1), np.int8)         # wrong shape
        fut = pool.submit(**bad)
        with pytest.raises(ValueError, match="expected shape"):
            fut.wait(timeout=120)
        ok = make_request()
        np.testing.assert_array_equal(
            pool.submit(**ok).wait(timeout=120),
            c(backend="simulator", **ok))


def test_gang_execute_respects_batch_tiles_ab_switch():
    """The A/B switch still works through the pool: batch_tiles=False
    resolves one launch per tile yet stays byte-exact."""
    rng = np.random.default_rng(47)
    p, make_request, reference = _mlp(rng)
    c = p.compile(use_cache=False)
    eng = PallasBackend(batch_tiles=False)
    feeds = [make_request() for _ in range(4)]
    with DevicePool(c, size=2, backend=eng) as pool:
        futs = [pool.submit(**f) for f in feeds]
        for f, feed in zip(futs, feeds):
            np.testing.assert_array_equal(f.wait(timeout=240),
                                          reference(feed))
