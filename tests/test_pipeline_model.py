"""Cycle-level pipeline model: latency hiding, roofline placement, and
runtime uop-cache behavior."""
import numpy as np
import pytest

from repro.core import hwspec
from repro.core.conv import ConvShape
from repro.core.pipeline_model import (conv_roofline_point,
                                       hardware_roofline,
                                       matmul_roofline_point)
from repro.core.runtime import Runtime, UopBuilder
from repro.core.scheduler import schedule_matmul
from repro.core.simulator import TimingModel


def test_roofline_bounds_achieved_gops():
    """No configuration may exceed the roofline."""
    spec = hwspec.pynq()
    for vt in (1, 2):
        p = matmul_roofline_point(spec, 256, 256, 256, "mm", vt)
        assert p.gops <= p.roofline_gops * 1.001
        assert 0.0 <= p.utilization <= 1.0


def test_latency_hiding_improves_bandwidth_bound_layer():
    """A low-intensity (bandwidth-ish) conv benefits from virtual threads."""
    spec = hwspec.pynq()
    shape = ConvShape(n=1, h=28, w=28, ic=64, oc=64, kh=1, kw=1,
                      stride=1, pad=0)
    p1 = conv_roofline_point(spec, shape, "c", 1)
    p2 = conv_roofline_point(spec, shape, "c", 2)
    assert p2.total_cycles < p1.total_cycles
    assert p2.utilization > p1.utilization


def test_bandwidth_scaling_shifts_roofline():
    """Double DRAM bandwidth must not hurt, and helps bandwidth-bound
    workloads more than compute-bound ones."""
    slow = hwspec.pynq().replace(dram_rd_bytes_per_cycle=4.0,
                                 dram_wr_bytes_per_cycle=4.0)
    fast = hwspec.pynq().replace(dram_rd_bytes_per_cycle=16.0,
                                 dram_wr_bytes_per_cycle=16.0)
    shape = ConvShape(n=1, h=28, w=28, ic=64, oc=64, kh=1, kw=1,
                      stride=1, pad=0)   # low intensity
    c_slow = conv_roofline_point(slow, shape, "c", 2).total_cycles
    c_fast = conv_roofline_point(fast, shape, "c", 2).total_cycles
    assert c_fast < c_slow


def test_gemm_latency_model_counts_uops():
    spec = hwspec.pynq()
    rt = Runtime(spec)

    def build(b: UopBuilder):
        b.loop_begin(4, 1, 1)
        b.loop_begin(8, 4, 0)
        for kk in range(3):
            b.push(0, kk, kk)
        b.loop_end(); b.loop_end()

    kern = rt.uop_kernel(build, key="t")
    insn_idx = rt.push_gemm(kern)
    insn = rt.stream[insn_idx]
    tm = TimingModel(spec)
    assert tm.latency(insn, spec) == 4 * 8 * 3  # one matmul per cycle


def test_uop_cache_lru_reload():
    """Evicted kernels must be re-loaded into uop SRAM on reuse."""
    spec = hwspec.pynq().replace(uop_buff_bytes=64)  # 16 uops only
    rt = Runtime(spec)

    def mk(tag, n):
        def build(b: UopBuilder):
            b.loop_begin(1, 0, 0)
            for i in range(n):
                b.push(i, 0, 0)
            b.loop_end()
        return rt.uop_kernel(build, key=tag)

    k1, k2 = mk("k1", 10), mk("k2", 10)
    rt.push_gemm(k1)            # load k1
    rt.push_gemm(k2)            # wraps: evicts k1, loads k2
    rt.push_gemm(k1)            # must re-load k1
    uop_loads = [i for i in rt.stream
                 if getattr(i, "memory_type", None) is not None
                 and i.memory_type.name == "UOP"]
    assert len(uop_loads) == 3


def test_stats_dram_accounting():
    spec = hwspec.pynq()
    rng = np.random.default_rng(0)
    a = rng.integers(-8, 8, size=(64, 64), dtype=np.int8)
    w = rng.integers(-8, 8, size=(64, 64), dtype=np.int8)
    rt = Runtime(spec)
    schedule_matmul(rt, a, w, virtual_threads=1)
    stats = rt.synchronize(timing=TimingModel(spec))
    assert stats.gemm_macs == 64 ** 3
    assert stats.dram_rd_bytes >= 2 * 64 * 64   # at least one pass each
    assert stats.dram_wr_bytes >= 64 * 64
