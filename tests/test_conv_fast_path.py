"""General conv2d on the Pallas fast path: zero eager-GEMM iterations.

The tentpole claim of the conv-lowering generalization: ResNet C2/C4-style
kh*kw>1 layers — not just pointwise 1x1s — execute on ``PallasBackend``
entirely through coalesced ``vta_gemm`` tiles, bit-exact against the
numpy oracle, with the eager per-uop loop never taken.  The
``RunStats.eager_*`` counters (and the ``assert_fast_path`` helper) are
the proof; a ``mock.patch`` on the simulator's eager methods double-checks
the counters aren't lying.
"""
from unittest import mock

import numpy as np
import pytest

from repro.core import hwspec
from repro.core.backend import PallasBackend, assert_fast_path
from repro.core.conv import (ConvShape, conv2d_reference, read_conv_result,
                             schedule_conv2d)
from repro.core.program import Program
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue
from repro.core.simulator import Simulator

# channel-scaled C2 (56x56 s1) and C4 (56x56 s2) — full spatial extent,
# real 3x3 kernels; channels trimmed so the numpy oracle stays quick
C2_LIKE = ConvShape(n=1, h=56, w=56, ic=32, oc=32, kh=3, kw=3,
                    stride=1, pad=1)
C4_LIKE = ConvShape(n=1, h=56, w=56, ic=32, oc=64, kh=3, kw=3,
                    stride=2, pad=1)


def _run_pallas(shape, ep=None, lowering=None, spec=None, backend=None):
    spec = spec or hwspec.pynq()
    rng = np.random.default_rng(shape.h * shape.ic + shape.oc)
    x = rng.integers(-64, 64, size=(shape.n, shape.ic, shape.h, shape.w),
                     dtype=np.int8)
    w = rng.integers(-16, 16, size=(shape.oc, shape.ic, shape.kh, shape.kw),
                     dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_conv2d(rt, x, w, shape, epilogue=ep, lowering=lowering)
    stats = rt.synchronize(backend=backend or "pallas")
    got = read_conv_result(rt, plan)
    want = conv2d_reference(x, w, shape, epilogue=ep)
    np.testing.assert_array_equal(got, want)
    return stats


@pytest.mark.parametrize("shape", [C2_LIKE, C4_LIKE],
                         ids=["C2-like", "C4-like"])
def test_resnet_conv_layers_take_zero_eager_gemms(shape):
    stats = _run_pallas(shape, ep=Epilogue(shift=6, relu=True))
    assert stats.eager_gemm_insns == 0
    assert stats.eager_alu_insns == 0
    assert stats.coalesced_gemm_insns > 0
    assert_fast_path(stats)


def test_counters_agree_with_eager_entry_points():
    """Belt and braces: with the eager simulator methods mocked to raise,
    a C2-like layer still executes (so eager_* == 0 is not a counting
    bug)."""
    with mock.patch.object(Simulator, "_do_gemm",
                           side_effect=AssertionError("eager GEMM taken")), \
         mock.patch.object(Simulator, "_do_alu",
                           side_effect=AssertionError("eager ALU taken")):
        _run_pallas(C2_LIKE, ep=Epilogue(shift=6, relu=True))


@pytest.mark.parametrize("lowering", ["direct", "im2col"])
def test_bias_epilogue_stays_on_fast_path(lowering):
    spec = hwspec.pynq()
    shape = ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                      stride=1, pad=1)
    rng = np.random.default_rng(3)
    bias = rng.integers(-500, 500, size=shape.oc, dtype=np.int32)
    bb = np.repeat(bias.reshape(-1, 1, spec.block_out), spec.batch, axis=1)
    stats = _run_pallas(shape, ep=Epilogue(bias_blocked=bb, shift=5,
                                           relu=True), lowering=lowering)
    assert_fast_path(stats)


def test_batch_blocked_1x1_via_matmul_fast_path():
    """The generalized transposed lowering: batch>1 template instances put
    image blocks in the tensor-register rows and still hit the GEMM fast
    path (the old spec.batch==1 restriction is gone)."""
    spec = hwspec.HardwareSpec(batch=2)
    shape = ConvShape(n=5, h=6, w=6, ic=32, oc=32, kh=1, kw=1,
                      stride=1, pad=0)
    stats = _run_pallas(shape, ep=Epilogue(shift=4, relu=True),
                        lowering="via_matmul", spec=spec)
    assert stats.eager_gemm_insns == 0
    assert stats.coalesced_gemm_insns > 0


def test_batch_blocked_direct_conv_fast_path():
    spec = hwspec.HardwareSpec(batch=2)
    shape = ConvShape(n=4, h=8, w=8, ic=16, oc=32, kh=3, kw=3,
                      stride=1, pad=1)
    stats = _run_pallas(shape, ep=Epilogue(shift=3), spec=spec)
    assert_fast_path(stats)


def test_subgrid_coalescing_switch_reverts_to_eager():
    """coalesce_subgrids=False is the pre-generalization A/B baseline:
    direct-conv GEMMs land in the eager loop again (and the result is
    still bit-exact — the eager path is the correctness net)."""
    shape = ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                      stride=1, pad=1)
    stats = _run_pallas(shape, ep=Epilogue(shift=5),
                        backend=PallasBackend(coalesce_subgrids=False))
    assert stats.eager_gemm_insns > 0
    assert stats.coalesced_gemm_insns == 0
    with pytest.raises(AssertionError, match="eager"):
        assert_fast_path(stats)


def test_program_conv_chain_fast_path_counters():
    """Whole-graph check: a direct 3x3 -> 1x1 chain through the Program
    JIT reports zero eager hits across every accelerator segment."""
    spec = hwspec.pynq()
    s2 = ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                   stride=1, pad=1)
    s3 = ConvShape(n=1, h=14, w=14, ic=32, oc=48, kh=1, kw=1,
                   stride=1, pad=0)
    rng = np.random.default_rng(9)
    x = rng.integers(-64, 64, size=(1, 32, 14, 14), dtype=np.int8)
    k2 = rng.integers(-8, 8, size=(32, 32, 3, 3), dtype=np.int8)
    k3 = rng.integers(-8, 8, size=(48, 32, 1, 1), dtype=np.int8)
    ep = Epilogue(shift=5, relu=True)
    p = Program(spec)
    t = p.conv2d(p.input("x", x.shape), p.input("k2", k2.shape), s2,
                 epilogue=ep)
    p.conv2d(t, p.input("k3", k3.shape), s3, epilogue=ep)
    c = p.compile(use_cache=False)
    got = c(backend="pallas", x=x, k2=k2, k3=k3)
    ref = conv2d_reference(conv2d_reference(x, k2, s2, epilogue=ep),
                           k3, s3, epilogue=ep)
    np.testing.assert_array_equal(got, ref)
    assert sum(s.eager_gemm_insns for s in c.last_stats) == 0
    assert_fast_path(c.last_stats)
