"""conv2d lowering vs numpy oracle + ResNet layer configs."""
import numpy as np
import pytest

from repro.core import hwspec
from repro.core.conv import (ConvShape, conv2d_reference, read_conv_result,
                             schedule_conv2d)
from repro.core.runtime import Runtime
from repro.core.scheduler import Epilogue
from repro.core.simulator import TimingModel
from repro.core.workloads import layer_by_name


def _run_conv(shape: ConvShape, vt=2, epilogue=None, seed=0):
    spec = hwspec.pynq()
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=(shape.n, shape.ic, shape.h, shape.w),
                     dtype=np.int8)
    w = rng.integers(-128, 128, size=(shape.oc, shape.ic, shape.kh, shape.kw),
                     dtype=np.int8)
    rt = Runtime(spec)
    plan = schedule_conv2d(rt, x, w, shape, epilogue=epilogue,
                           virtual_threads=vt)
    rt.synchronize()
    got = read_conv_result(rt, plan)
    want = conv2d_reference(x, w, shape, epilogue=epilogue)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("vt", [1, 2])
def test_conv_3x3(vt):
    _run_conv(ConvShape(n=1, h=14, w=14, ic=32, oc=32, kh=3, kw=3,
                        stride=1, pad=1), vt=vt)


def test_conv_1x1_stride2():
    _run_conv(ConvShape(n=1, h=14, w=14, ic=32, oc=64, kh=1, kw=1,
                        stride=2, pad=0))


def test_conv_3x3_stride2_with_epilogue():
    spec = hwspec.pynq()
    oc = 32
    rng = np.random.default_rng(3)
    bias = rng.integers(-500, 500, size=oc, dtype=np.int32)
    ocb = oc // spec.block_out
    bias_blocked = np.repeat(bias.reshape(ocb, 1, spec.block_out),
                             spec.batch, axis=1)
    ep = Epilogue(bias_blocked=bias_blocked, shift=5, relu=True)
    _run_conv(ConvShape(n=1, h=14, w=14, ic=32, oc=oc, kh=3, kw=3,
                        stride=2, pad=1), epilogue=ep)


def test_conv_edge_tiles_nondivisible():
    # OH=7 with small SRAM tiles exercises oht_c < oht edge handling
    _run_conv(ConvShape(n=1, h=7, w=7, ic=64, oc=64, kh=3, kw=3,
                        stride=1, pad=1))


def test_resnet_c9_exact_and_hiding():
    layer = layer_by_name("C9")
    s = layer.shape
    small = ConvShape(n=1, h=s.h, w=s.w, ic=s.ic, oc=s.oc, kh=s.kh,
                      kw=s.kw, stride=s.stride, pad=s.pad)
    _run_conv(small, vt=2)


def test_conv_virtual_threading_hides_latency():
    spec = hwspec.pynq()
    shape = ConvShape(n=1, h=28, w=28, ic=128, oc=128, kh=3, kw=3,
                      stride=1, pad=1)
    rng = np.random.default_rng(0)
    x = rng.integers(-16, 16, size=(1, shape.ic, shape.h, shape.w), dtype=np.int8)
    w = rng.integers(-16, 16, size=(shape.oc, shape.ic, 3, 3), dtype=np.int8)
    util = {}
    for vt in (1, 2):
        rt = Runtime(spec)
        schedule_conv2d(rt, x, w, shape, virtual_threads=vt)
        stats = rt.synchronize(timing=TimingModel(spec))
        util[vt] = stats.compute_utilization
    assert util[2] > util[1]
