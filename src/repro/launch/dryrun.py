import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod or 2x16x16 multi-pod);
  2. materializes parameter/optimizer/cache ShapeDtypeStructs (eval_shape
     — zero allocation) with the arch's sharding rules;
  3. jit-lowers and *compiles* the train_step / prefill / decode_step for
     that shape — sharding mismatches, unsupported collectives, or
     OOM-at-compile surface here as hard failures;
  4. records memory_analysis(), cost_analysis(), and the trip-count-aware
     HLO statistics (dot FLOPs, HBM bytes, per-class collective wire
     bytes) into experiments/dryrun/<cell>.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch, input_specs, list_archs
from repro.configs.base import ArchSpec, ShapeSpec, for_shape
from repro.distributed import meshctx
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        named_shardings, opt_state_specs,
                                        param_specs)
from repro.launch import hlo_analysis
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models import transformer as T
from repro.models.config import ShardingConfig
from repro.models.quantized import quantized_param_shapes
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer

# v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _sharding_config(mesh, dp_over_model: bool = False) -> ShardingConfig:
    data = data_axes_of(mesh)
    if dp_over_model:
        data = data + ("model",)
    return ShardingConfig(enabled=True, data_axes=data, model_axis="model",
                          fsdp_axes=data)


def build_train_step(cfg, optimizer: str):
    opt_init, opt_update = make_optimizer(optimizer)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            return T.forward_train(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_schedule(step, 2000, 100_000, 3e-4)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return opt_init, train_step


def _mem_report(compiled) -> Dict[str, float]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = float(getattr(ma, k))
        out["total_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    except Exception as e:   # backend without memory analysis
        out["error"] = str(e)
    return out


def _cost_report(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "optimal_seconds")}
    except Exception as e:
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quantized: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             verbose: bool = True) -> Dict[str, Any]:
    spec: ArchSpec = get_arch(arch)
    shape: ShapeSpec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    sc = _sharding_config(mesh, dp_over_model=getattr(spec, "dp_over_model", False))
    cfg = for_shape(spec, shape, sharding=sc, quantized=quantized)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind, "quantized": quantized,
            "n_devices": n_dev, "optimizer": spec.optimizer,
            "fsdp": spec.fsdp, "overrides": overrides or {}}
    t0 = time.time()

    with meshctx.use_mesh(mesh):
        params_shapes = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        p_specs = param_specs(params_shapes, cfg, mesh, fsdp=spec.fsdp)
        p_shard = named_shardings(p_specs, mesh)
        batch_sds = input_specs(cfg, shape)
        repl = NamedSharding(mesh, P())

        if shape.kind == "train":
            opt_init, train_step = build_train_step(cfg, spec.optimizer)
            opt_shapes = jax.eval_shape(opt_init, params_shapes)
            o_specs = opt_state_specs(opt_shapes, p_specs, params_shapes)
            o_shard = named_shardings(o_specs, mesh)
            b_specs = batch_specs(batch_sds, cfg, mesh)
            b_shard = {k: NamedSharding(mesh, s) for k, s in b_specs.items()}
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(train_step,
                             in_shardings=(p_shard, o_shard, b_shard, repl),
                             out_shardings=(p_shard, o_shard, repl),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_shapes, opt_shapes, batch_sds,
                                   step_sds)
        else:
            max_len = shape.seq_len
            caches_shapes = jax.eval_shape(
                lambda: T.init_caches(cfg, shape.global_batch, max_len,
                                      jnp.bfloat16))
            c_specs = cache_specs(caches_shapes, cfg, mesh)
            c_shard = named_shardings(c_specs, mesh)
            if quantized:
                params_shapes = quantized_param_shapes(params_shapes)
                p_specs = param_specs(params_shapes, cfg, mesh,
                                      fsdp=spec.fsdp)
                p_shard = named_shardings(p_specs, mesh)
            if shape.kind == "prefill":
                def prefill_step(params, batch, caches):
                    return T.prefill(params, cfg, batch, caches)
                b_specs = batch_specs(batch_sds, cfg, mesh)
                b_shard = {k: NamedSharding(mesh, s)
                           for k, s in b_specs.items()}
                jitted = jax.jit(prefill_step,
                                 in_shardings=(p_shard, b_shard, c_shard),
                                 out_shardings=(repl, c_shard),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_shapes, batch_sds,
                                       caches_shapes)
            else:  # decode
                def decode(params, caches, token, pos):
                    return T.decode_step(params, cfg, caches, token, pos)
                tok_sds = batch_sds["token"]
                pos_sds = batch_sds["pos"]
                tok_spec = batch_specs({"token": tok_sds}, cfg, mesh)["token"]
                jitted = jax.jit(
                    decode,
                    in_shardings=(p_shard, c_shard,
                                  NamedSharding(mesh, tok_spec), repl),
                    out_shardings=(repl, c_shard),
                    donate_argnums=(1,))
                lowered = jitted.lower(params_shapes, caches_shapes,
                                       tok_sds, pos_sds)

        cell["lower_seconds"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        cell["compile_seconds"] = time.time() - t1

        cell["memory"] = _mem_report(compiled)
        cell["xla_cost"] = _cost_report(compiled)
        t2 = time.time()
        stats = hlo_analysis.analyze(compiled.as_text(), total_devices=n_dev)
        cell["analyze_seconds"] = time.time() - t2
        cell["hlo"] = {
            "dot_flops_per_device": stats.dot_flops,
            "memory_bytes_per_device": stats.memory_bytes,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "while_trip_counts": stats.while_trip_counts[:64],
        }

        # ---- roofline terms (seconds) ----
        comp_t = stats.dot_flops / PEAK_FLOPS
        mem_t = stats.memory_bytes / HBM_BW
        coll_t = stats.total_collective_bytes / ICI_BW
        dominant = max((("compute", comp_t), ("memory", mem_t),
                        ("collective", coll_t)), key=lambda kv: kv[1])[0]
        m = cfg
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * m.n_active_params * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * m.n_active_params * tokens
        else:
            tokens = shape.global_batch * 1
            model_flops = 2.0 * m.n_active_params * tokens
        hlo_total = stats.dot_flops * n_dev
        cell["roofline"] = {
            "compute_term_s": comp_t,
            "memory_term_s": mem_t,
            "collective_term_s": coll_t,
            "dominant": dominant,
            "model_flops_total": model_flops,
            "hlo_flops_total": hlo_total,
            "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
            "roofline_fraction": (
                max(comp_t, 0.0) / max(comp_t, mem_t, coll_t)
                if max(comp_t, mem_t, coll_t) > 0 else 0.0),
        }
        cell["n_params"] = m.n_params
        cell["n_active_params"] = m.n_active_params
    if verbose:
        r = cell["roofline"]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}"
              f"{' int8' if quantized else ''}: "
              f"compile={cell['compile_seconds']:.1f}s "
              f"compute={r['compute_term_s']*1e3:.2f}ms "
              f"memory={r['memory_term_s']*1e3:.2f}ms "
              f"collective={r['collective_term_s']*1e3:.2f}ms "
              f"dominant={r['dominant']} "
              f"useful={r['useful_flops_ratio']:.2f} "
              f"mem/dev={cell['memory'].get('total_bytes_per_device', 0)/2**30:.2f}GiB",
              flush=True)
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quantized", action="store_true",
                    help="int8 PTQ weights on serve cells (VTA path)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf loop)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi_pod": [True],
              "both": [False, True]}[args.mesh]
    overrides = json.loads(args.override) if args.override else None

    if args.all:
        todo = []
        for a in list_archs():
            for s in get_arch(a).shapes:
                todo.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                cell = run_cell(arch, shape, mp, quantized=args.quantized,
                                overrides=overrides)
                tag = ("__int8" if args.quantized else "") + \
                    (f"__{args.tag}" if args.tag else "")
                name = (f"{arch}__{shape}__"
                        f"{'multi' if mp else 'single'}{tag}.json")
                with open(os.path.join(args.out, name), "w") as f:
                    json.dump(cell, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, mp, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILED CELLS:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(todo) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
