"""Batched serving driver: continuous-batching decode loop.

Implements the inference side of the stack: prefill new requests into
free cache slots, run batched decode steps, emit tokens, retire finished
sequences.  The int8 path (`--quantized`) runs projections through the
VTA GEMM semantics — the paper's PTQ deployment applied to LM serving.

Usage:
  python -m repro.launch.serve --arch llama3.2-3b --reduced \\
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.quantized import quantize_params


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch engine with slot recycling (continuous batching)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.dtype = dtype
        self.caches = T.init_caches(cfg, batch_slots, max_len, dtype)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
        self._prefill1 = jax.jit(
            lambda p, b, c: T.prefill(p, cfg, b, c))

    # -- single-slot prefill: runs the prompt with batch=1 caches then
    #    copies the slot in (slot-granular continuous batching) ----------
    def add_request(self, req: Request) -> bool:
        try:
            slot = self.slot_req.index(None)
        except ValueError:
            return False
        tmp_caches = T.init_caches(self.cfg, 1, self.max_len, self.dtype)
        logits, tmp_caches = self._prefill1(
            self.params, {"tokens": jnp.asarray(req.prompt[None, :])},
            tmp_caches)
        # splice the prefilled slot into the batch caches
        def splice(batch_c, one_c):
            if not hasattr(batch_c, "shape"):
                return batch_c
            # per-layer stacked caches: batch dim is axis 1
            return batch_c.at[:, slot:slot + 1].set(one_c)
        self.caches = jax.tree.map(splice, self.caches, tmp_caches)
        first = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(first)
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        return True

    def step(self, greedy: bool = True) -> None:
        """One batched decode step across all active slots."""
        if all(r is None for r in self.slot_req):
            return
        tokens = np.zeros((self.B, 1), np.int32)
        for s, r in enumerate(self.slot_req):
            if r is not None and r.out_tokens:
                tokens[s, 0] = r.out_tokens[-1]
        pos = jnp.int32(int(max(self.slot_pos)))  # uniform step position
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens), pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            r.out_tokens.append(int(nxt[s]))
            self.slot_pos[s] += 1
            if len(r.out_tokens) >= r.max_new:
                r.done = True
                self.slot_req[s] = None

    def run(self, requests: List[Request]) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while pending or any(r is not None for r in self.slot_req):
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--quantized", action="store_true",
                    help="serve int8 PTQ weights through the VTA GEMM path")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = reduce_cfg(spec.model) if args.reduced else spec.model
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.quantized:
        params = quantize_params(params)
        print("serving with int8 PTQ weights (VTA datapath)")
    engine = ServeEngine(cfg, params, batch_slots=args.slots)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=16
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out_tokens[:10]}...")


if __name__ == "__main__":
    main()
