"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import and only then builds the mesh.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _axis_type_kwargs(n: int) -> dict:
    """`jax.sharding.AxisType` only exists on jax >= 0.5; older releases
    (0.4.x) default every axis to Auto, so omitting the kwarg is
    equivalent there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic restarts use this after replanning)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def data_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
