"""Production training driver.

Wires together: config registry -> sharded init -> data pipeline ->
jit'd train step (donated params/opt) -> async checkpointing -> straggler
watchdog -> elastic restart hooks.  On this CPU container it runs real
training for the reduced configs (examples/train_lm.py) and serves as the
launcher template for the production mesh (same code path the dry-run
lowers).

Usage:
  python -m repro.launch.train --arch olmo-1b --steps 200 --reduced \\
      --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_arch, reduced as reduce_cfg
from repro.data import DataConfig, SyntheticLMDataset
from repro.distributed import meshctx
from repro.distributed.fault_tolerance import StepWatchdog
from repro.distributed.sharding import (batch_specs, named_shardings,
                                        opt_state_specs, param_specs)
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShardingConfig
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer


def build_train_step(cfg: ModelConfig, optimizer: str, peak_lr: float = 3e-4,
                     warmup: int = 100, total_steps: int = 10_000):
    opt_init, opt_update = make_optimizer(optimizer)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            return T.forward_train(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_schedule(step, warmup, total_steps, peak_lr)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        return params, opt_state, dict(metrics, grad_norm=gnorm, lr=lr)

    return opt_init, train_step


class Trainer:
    """Single-process trainer; the multi-host variant changes only the
    data sharding + jax.distributed.initialize bootstrap."""

    def __init__(self, cfg: ModelConfig, optimizer: str = "adamw",
                 seq_len: int = 128, global_batch: int = 8,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 mesh=None, fsdp: bool = False, peak_lr: float = 3e-4):
        self.cfg = cfg
        self.mesh = mesh
        self.watchdog = StepWatchdog()
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.data = SyntheticLMDataset(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=seed))
        opt_init, step_fn = build_train_step(cfg, optimizer, peak_lr=peak_lr)
        key = jax.random.PRNGKey(seed)

        if mesh is not None:
            meshctx.set_mesh(mesh)
            p_shapes = jax.eval_shape(lambda: T.init_params(key, cfg))
            p_specs = param_specs(p_shapes, cfg, mesh, fsdp=fsdp)
            p_shard = named_shardings(p_specs, mesh)
            self.params = jax.jit(
                lambda: T.init_params(key, cfg), out_shardings=p_shard)()
            o_shapes = jax.eval_shape(opt_init, p_shapes)
            o_specs = opt_state_specs(o_shapes, p_specs, p_shapes)
            o_shard = named_shardings(o_specs, mesh)
            self.opt_state = jax.jit(opt_init, out_shardings=o_shard)(
                self.params)
            self.p_shard, self.o_shard = p_shard, o_shard
            self.step_fn = jax.jit(step_fn,
                                   in_shardings=(p_shard, o_shard, None, None),
                                   out_shardings=(p_shard, o_shard, None),
                                   donate_argnums=(0, 1))
        else:
            self.params = T.init_params(key, cfg)
            self.opt_state = opt_init(self.params)
            self.p_shard = self.o_shard = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step = 0

    # ------------------------------------------------------------------
    def maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        s = latest_step(self.ckpt.ckpt_dir)
        if s is None:
            return False
        tree = {"params": self.params, "opt_state": self.opt_state}
        shard = ({"params": self.p_shard, "opt_state": self.o_shard}
                 if self.p_shard is not None else None)
        restored, extra = restore_checkpoint(self.ckpt.ckpt_dir, s, tree,
                                             shardings=shard)
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        self.step = int(extra.get("step", s))
        return True

    def train(self, steps: int, log_every: int = 10,
              ckpt_every: int = 200) -> Dict[str, list]:
        history = {"loss": [], "step": []}
        for _ in range(steps):
            batch_np = self.data.batch(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            self.watchdog.start_step()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, jnp.int32(self.step))
            loss = float(metrics["loss"])
            self.watchdog.end_step(self.step)
            if self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            history["loss"].append(loss)
            history["step"].append(self.step)
            self.step += 1
            if self.ckpt and self.step % ckpt_every == 0:
                self.ckpt.save(self.step,
                               {"params": self.params,
                                "opt_state": self.opt_state},
                               extra={"step": self.step})
        if self.ckpt:
            self.ckpt.save(self.step, {"params": self.params,
                                       "opt_state": self.opt_state},
                           extra={"step": self.step})
            self.ckpt.wait()
        return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = reduce_cfg(spec.model) if args.reduced else spec.model
    cfg = cfg.replace(max_seq=max(cfg.max_seq, args.seq_len))
    tr = Trainer(cfg, optimizer=spec.optimizer, seq_len=args.seq_len,
                 global_batch=args.batch, ckpt_dir=args.ckpt_dir,
                 peak_lr=args.lr)
    if tr.maybe_restore():
        print(f"restored from step {tr.step}")
    hist = tr.train(args.steps)
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(start {hist['loss'][0]:.4f})")


if __name__ == "__main__":
    main()
