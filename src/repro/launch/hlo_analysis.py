"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

`compiled.cost_analysis()` visits each while-loop body ONCE, so a model
scanned over L layers under-reports FLOPs/bytes/collectives by ~L x
(verified empirically in tests).  This module re-derives the three
roofline inputs from `compiled.as_text()`:

  * dot_flops          — 2 * prod(result dims) * prod(contracted dims),
                         summed over every `dot` op, multiplied through
                         while-loop trip counts (parsed from the loop
                         condition's comparison constant);
  * memory_bytes       — sum of (operands + result) bytes over top-level
                         ops (fusion bodies excluded: a fusion's operands/
                         results approximate its real HBM traffic);
  * collective wire bytes per op class, converted to per-device link
    traffic with ring-algorithm factors:
        all-gather:          result * (n-1)/n
        reduce-scatter:      result * (n-1)
        all-reduce:          2 * result * (n-1)/n
        all-to-all:          result * (n-1)/n
        collective-permute:  result

All shapes in post-SPMD HLO are per-device shards, so totals are
per-device; multiply by chip count for cluster totals.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f8e4m3fn|f8e5m2|c64|c128)"
                       r"\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # %name -> type


_NAME_RE = re.compile(r"^%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
# header: "%name (params...) -> result {"   (params may nest parens)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def _parse_op_line(line: str):
    """Manual parse: tuple result types contain parens and /*index=N*/
    comments, so naive regexes drop exactly the interesting ops (while,
    big fusions).  Returns (name, result_type, opcode, args) or None."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    m = _NAME_RE.match(s)
    if not m:
        return None
    name = m.group(1)
    rest = s[m.end():]
    if rest.startswith("("):          # tuple type: balanced-paren group
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        rtype, rest2 = rest[:end], rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, rest2 = rest[:sp], rest[sp:]
    m2 = _OPCODE_RE.match(rest2)
    if not m2:
        return None
    opcode = m2.group(1)
    args_start = rest2[m2.end():]
    depth = 1
    args = []
    for ch in args_start:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args.append(ch)
    return name, rtype, opcode, "".join(args)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_RE.match(line.strip())
            if m:
                current = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, rtype, opcode, args = parsed
        operands = re.findall(r"%([\w.\-]+)", args)
        op = Op(name=name, opcode=opcode, result_type=rtype.strip(),
                operands=operands, line=line)
        current.ops.append(op)
        current.symbols[name] = rtype.strip()
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """lax.scan conds compare the counter against a constant: take the
    largest s32 constant in the condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and "s32" in op.result_type:
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return total_devices


@dataclass
class HloStats:
    dot_flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_counts: Dict[str, int] = field(
        default_factory=lambda: {c: 0 for c in _COLLECTIVES})
    while_trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def analyze(text: str, total_devices: int = 1) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    # computations reachable only as fusion bodies: exclude from the walk
    fusion_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m:
                    fusion_bodies.add(m.group(1))

    def op_flops(op: Op, comp: Computation) -> float:
        if op.opcode not in ("dot", "convolution"):
            return 0.0
        out_elems = 1
        for d in _shape_dims(op.result_type):
            out_elems *= d
        if op.opcode == "dot":
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
            contracted = 1
            if m and op.operands:
                lhs_type = comp.symbols.get(op.operands[0], "")
                lhs_dims = _shape_dims(lhs_type)
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(lhs_dims):
                        contracted *= lhs_dims[i]
            return 2.0 * out_elems * contracted
        # convolution: 2 * out * (kernel spatial * in_features)
        if op.operands and len(op.operands) >= 2:
            k_dims = _shape_dims(comp.symbols.get(op.operands[1], ""))
            k = 1
            for d in k_dims[:-1]:
                k *= d
            return 2.0 * out_elems * k
        return 0.0

    def _fusion_body_param_bytes(body: Computation) -> Dict[int, float]:
        """Per-parameter effective read bytes inside a fusion body: a param
        consumed only via dynamic-slice reads just the slice (the scan
        weight-slice pattern), not the whole stacked array."""
        param_idx: Dict[str, int] = {}
        for bop in body.ops:
            if bop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", bop.line)
                if m:
                    param_idx[bop.name] = int(m.group(1))
        users: Dict[str, List[Op]] = {}
        for bop in body.ops:
            for o in bop.operands:
                if o in param_idx:
                    users.setdefault(o, []).append(bop)
        out: Dict[int, float] = {}
        for pname, idx in param_idx.items():
            ulist = users.get(pname, [])
            if ulist and all(u.opcode == "dynamic-slice" for u in ulist):
                out[idx] = float(sum(_shape_bytes(u.result_type)
                                     for u in ulist))
        return out

    def op_mem_bytes(op: Op, comp: Computation) -> float:
        if op.opcode in _SKIP_MEM or op.opcode.endswith("-done") \
                or op.opcode == "while":
            return 0.0   # while state moves via in-place aliasing
        # scan-state ops: only the touched slice moves, not the buffer
        if op.opcode == "dynamic-slice":
            return 2.0 * _shape_bytes(op.result_type)
        if op.opcode == "dynamic-update-slice":
            upd = (comp.symbols.get(op.operands[1], "")
                   if len(op.operands) > 1 else "")
            return 2.0 * _shape_bytes(upd)
        if op.opcode == "fusion":
            total = 0.0
            m = re.search(r"calls=%?([\w.\-]+)", op.line)
            body = comps.get(m.group(1)) if m else None
            sliced = _fusion_body_param_bytes(body) if body else {}
            for i, o in enumerate(op.operands):
                if i in sliced:
                    total += sliced[i]
                else:
                    total += _shape_bytes(comp.symbols.get(o, ""))
            # DUS-rooted fusion writes only the update slice (aliased buf)
            root = body.ops[-1] if body and body.ops else None
            if root is not None and root.opcode == "dynamic-update-slice" \
                    and len(root.operands) > 1:
                total += _shape_bytes(body.symbols.get(root.operands[1], ""))
            else:
                total += _shape_bytes(op.result_type)
            return total
        if op.opcode in ("gather",):
            total = _shape_bytes(op.result_type) * 2.0
            if len(op.operands) > 1:
                total += _shape_bytes(comp.symbols.get(op.operands[1], ""))
            return total
        if op.opcode in ("scatter",):
            total = _shape_bytes(op.result_type)
            for o in op.operands[1:]:
                total += _shape_bytes(comp.symbols.get(o, ""))
            return total
        total = _shape_bytes(op.result_type)
        for o in op.operands:
            t = comp.symbols.get(o)
            if t:
                total += _shape_bytes(t)
        return float(total)

    visited_stack = set()

    def walk(comp_name: str, mult: float) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.add(comp_name)
        for op in comp.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in _COLLECTIVES:
                n = _group_size(op.line, total_devices)
                rb = _shape_bytes(op.result_type)
                if base == "all-gather":
                    wire = rb * (n - 1) / max(1, n)
                elif base == "reduce-scatter":
                    wire = rb * (n - 1)
                elif base == "all-reduce":
                    wire = 2.0 * rb * (n - 1) / max(1, n)
                elif base == "all-to-all":
                    wire = rb * (n - 1) / max(1, n)
                else:  # collective-permute
                    wire = float(rb)
                stats.collective_bytes[base] += mult * wire
                stats.collective_counts[base] += int(mult)
            stats.dot_flops += mult * op_flops(op, comp)
            stats.memory_bytes += mult * op_mem_bytes(op, comp)
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m:   # count dots inside the fusion body (flops only)
                    body = comps.get(m.group(1))
                    if body:
                        for bop in body.ops:
                            stats.dot_flops += mult * op_flops(bop, body)
            elif op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                trips = 1
                if mc and mc.group(1) in comps:
                    trips = _trip_count(comps[mc.group(1)])
                stats.while_trip_counts.append(trips)
                if mb:
                    walk(mb.group(1), mult * trips)
            elif op.opcode == "conditional":
                for m in re.finditer(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)([^,}]+)", op.line):
                    walk(m.group(1).strip().lstrip("%"), mult)
            elif op.opcode in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    walk(m.group(1), mult)
            elif op.opcode == "custom-call":
                m = re.search(r"called_computations=\{%?([\w.\-]+)\}", op.line)
                if m:
                    walk(m.group(1), mult)
        visited_stack.discard(comp_name)

    if entry:
        walk(entry, 1.0)
    return stats
