"""xLSTM blocks: mLSTM (matrix memory, parallelizable — lowered onto the
chunked GLA core with a denominator channel) and sLSTM (scalar memory,
strictly recurrent — lax.scan over time).

mLSTM recurrence (per head):
    C_t = f_t C_{t-1} + i_t v_t k_t^T          (matrix memory)
    n_t = f_t n_{t-1} + i_t k_t                (normalizer)
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
Implemented by appending a constant-1 channel to v so that the GLA state
carries (C | n) jointly — one scan, exact semantics.

The 7:1 mLSTM:sLSTM interleave of xlstm-1.3b is expressed through
ModelConfig.block_pattern (slstm_every=8).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear_apply, linear_init, norm_apply, norm_init
from .ssm import chunked_gla, gla_step

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# mLSTM block
# ----------------------------------------------------------------------
def _qk_dim(cfg) -> int:
    """mLSTM uses a narrower q/k dim than the value dim (official xLSTM
    does the same): the matrix memory is (N_qk x P_v) per head — with
    N_qk == P_v == 1024 the per-chunk states alone would be hundreds of
    GiB at trillion-token batch sizes."""
    dh_v = (2 * cfg.d_model) // cfg.n_heads
    return max(64, dh_v // 4)


def mlstm_init(key, cfg) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    nqk = _qk_dim(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "up_x": linear_init(ks[0], d, 2 * d, dt),
        "up_z": linear_init(ks[1], d, 2 * d, dt),
        "wq": linear_init(ks[2], 2 * d, H * nqk, dt),
        "wk": linear_init(ks[3], 2 * d, H * nqk, dt),
        "wv": linear_init(ks[4], 2 * d, 2 * d, dt),
        "w_if": linear_init(ks[5], 2 * d, 2 * H, dt),   # input+forget gates
        "down": linear_init(ks[6], 2 * d, d, dt),
        "norm": norm_init(cfg, 2 * d),
    }


def _mlstm_qkvg(p: Params, cfg, xu: jax.Array):
    B, S, d2 = xu.shape
    H = cfg.n_heads
    dh = d2 // H
    nqk = _qk_dim(cfg)
    q = linear_apply(p["wq"], xu, cfg).reshape(B, S, H, nqk)
    k = linear_apply(p["wk"], xu, cfg).reshape(B, S, H, nqk) / math.sqrt(nqk)
    v = linear_apply(p["wv"], xu, cfg).reshape(B, S, H, dh)
    gates = linear_apply(p["w_if"], xu, cfg).astype(jnp.float32)
    i_gate = jnp.exp(-jax.nn.softplus(-gates[..., :H]))       # sigmoid, (B,S,H)
    log_f = -jax.nn.softplus(-gates[..., H:])                 # log sigmoid
    return q, k, v, i_gate, log_f


def _mlstm_out(p: Params, cfg, y: jax.Array, den: jax.Array, z: jax.Array,
               B: int, S: int) -> jax.Array:
    H = cfg.n_heads
    y = y / jnp.maximum(jnp.abs(den), 1.0)                    # normalizer
    y = y.reshape(B, S, 2 * cfg.d_model).astype(z.dtype)
    y = norm_apply(cfg, p["norm"], y) * jax.nn.silu(z)
    return linear_apply(p["down"], y, cfg)


def mlstm_train(p: Params, cfg, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    xu = linear_apply(p["up_x"], x, cfg)
    z = linear_apply(p["up_z"], x, cfg)
    q, k, v, i_gate, log_f = _mlstm_qkvg(p, cfg, xu)
    # denominator channel: v' = [i*v | i*1]
    vi = jnp.concatenate([v * i_gate[..., None],
                          i_gate[..., None].astype(v.dtype)], axis=-1)
    y_all, _ = chunked_gla(q, k, vi, log_f, chunk=512)
    y, den = y_all[..., :-1], y_all[..., -1:]
    return _mlstm_out(p, cfg, y, den, z, B, S)


def init_mlstm_cache(cfg, batch: int) -> Dict[str, jax.Array]:
    H = cfg.n_heads
    dh = (2 * cfg.d_model) // H
    return {"h": jnp.zeros((batch, H, _qk_dim(cfg), dh + 1), jnp.float32)}


def mlstm_prefill(p: Params, cfg, x: jax.Array, cache) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    xu = linear_apply(p["up_x"], x, cfg)
    z = linear_apply(p["up_z"], x, cfg)
    q, k, v, i_gate, log_f = _mlstm_qkvg(p, cfg, xu)
    vi = jnp.concatenate([v * i_gate[..., None],
                          i_gate[..., None].astype(v.dtype)], axis=-1)
    y_all, h = chunked_gla(q, k, vi, log_f, chunk=512, h0=cache["h"])
    y, den = y_all[..., :-1], y_all[..., -1:]
    return _mlstm_out(p, cfg, y, den, z, B, S), {"h": h}


def mlstm_decode(p: Params, cfg, x: jax.Array, cache) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape           # S == 1
    xu = linear_apply(p["up_x"], x, cfg)
    z = linear_apply(p["up_z"], x, cfg)
    q, k, v, i_gate, log_f = _mlstm_qkvg(p, cfg, xu)
    vi = jnp.concatenate([v * i_gate[..., None],
                          i_gate[..., None].astype(v.dtype)], axis=-1)
    h, y_all = gla_step(cache["h"], q[:, 0], k[:, 0], vi[:, 0],
                        jnp.exp(log_f[:, 0]))
    y, den = y_all[None, :, :, :-1].swapaxes(0, 1), y_all[None, :, :, -1:].swapaxes(0, 1)
    return _mlstm_out(p, cfg, y, den, z, B, 1), {"h": h}


# ----------------------------------------------------------------------
# sLSTM block (strictly recurrent)
# ----------------------------------------------------------------------
def slstm_init(key, cfg) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "w_in": linear_init(ks[0], d, 4 * d, dt),      # z, i, f, o pre-acts
        "r": (jax.random.normal(ks[1], (H, d // H, 4 * (d // H)))
              * (0.5 / math.sqrt(d // H))).astype(jnp.float32),
        "down": linear_init(ks[2], d, d, dt),
        "norm": norm_init(cfg, d),
    }


def init_slstm_cache(cfg, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}


def _slstm_cell(cfg, r, pre, state):
    """pre: (B, 4d) input preactivations; recurrent contribution from h."""
    B = pre.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    c, n, h = state["c"], state["n"], state["h"]
    hr = jnp.einsum("bhx,hxy->bhy", h.reshape(B, H, dh), r).reshape(B, 4 * d)
    z, i, f, o = jnp.split(pre.astype(jnp.float32) + hr, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.minimum(i, 10.0))        # exponential input gate (capped)
    f = jnp.exp(-jax.nn.softplus(-f))        # sigmoid forget
    o = jnp.exp(-jax.nn.softplus(-o))
    c = f * c + i * z
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h}


def slstm_train(p: Params, cfg, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    pre = linear_apply(p["w_in"], x, cfg)                  # (B, S, 4d)
    state = init_slstm_cache(cfg, B)

    def step(carry, pre_t):
        st = _slstm_cell(cfg, p["r"], pre_t, carry)
        return st, st["h"]

    _, hs = jax.lax.scan(step, state, pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                  # (B, S, d)
    y = norm_apply(cfg, p["norm"], y)
    return linear_apply(p["down"], y, cfg)


def slstm_prefill(p: Params, cfg, x: jax.Array, cache) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    pre = linear_apply(p["w_in"], x, cfg)

    def step(carry, pre_t):
        st = _slstm_cell(cfg, p["r"], pre_t, carry)
        return st, st["h"]

    state, hs = jax.lax.scan(step, cache, pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    y = norm_apply(cfg, p["norm"], y)
    return linear_apply(p["down"], y, cfg), state


def slstm_decode(p: Params, cfg, x: jax.Array, cache) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    pre = linear_apply(p["w_in"], x, cfg)[:, 0]
    state = _slstm_cell(cfg, p["r"], pre, cache)
    y = state["h"][:, None].astype(x.dtype)
    y = norm_apply(cfg, p["norm"], y)
    return linear_apply(p["down"], y, cfg), state
