"""Mixture-of-Experts layer with sort-based capacity dispatch and
shard_map expert parallelism (EP over the "model" mesh axis).

Dispatch: token->expert pairs are sorted by expert id and packed into a
per-expert capacity buffer (E_local, C, d) — static shapes, no host-side
ragged ops; overflow beyond capacity C = ceil(T*k*cf/E) is dropped
(standard capacity-factor semantics).  Under EP each device computes only
its local expert shard against (replicated-over-model) tokens; the
combine is a psum over the model axis.  This maps VTA's "explicit memory
arbitration" philosophy onto the MoE layer: the dispatch buffer is an
explicitly-managed scratchpad with a hard capacity, not an implicit cache.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.meshctx import get_mesh, shard_map

from .layers import linear_apply, linear_init, mlp_apply, mlp_init

Params = Dict[str, Any]


def moe_init(key, cfg) -> Params:
    d = cfg.d_model
    E = cfg.moe_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": {"w": (jax.random.uniform(ks[0], (d, E), jnp.float32,
                                            -scale, scale)).astype(jnp.float32)},
        "wi": (jax.random.uniform(ks[1], (E, d, f), jnp.float32, -scale, scale)
               ).astype(dt),
        "wg": (jax.random.uniform(ks[2], (E, d, f), jnp.float32, -scale, scale)
               ).astype(dt),
        "wo": (jax.random.uniform(ks[3], (E, f, d), jnp.float32,
                                  -1 / math.sqrt(f), 1 / math.sqrt(f))).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d,
                               cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff))
    return p


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    c = int(math.ceil(T * k * cf / E))
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane friendliness


def _expert_ffn(buf: jax.Array, wi: jax.Array, wg: jax.Array,
                wo: jax.Array) -> jax.Array:
    """buf: (E, C, d) -> (E, C, d), swiglu per expert."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
         * jnp.einsum("ecd,edf->ecf", buf, wi))
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _dispatch_compute_combine(xt: jax.Array, flat_e: jax.Array,
                              flat_g: jax.Array, k: int, n_local: int,
                              e_offset, C: int, wi, wg, wo,
                              expert_ffn=None) -> jax.Array:
    """Core dispatch for a token shard against a local expert shard.

    xt: (T, d); flat_e/flat_g: (T*k,) global expert ids / gate weights.
    `expert_ffn` overrides the per-expert FFN (2-D sharded serving path).
    Returns this expert-shard's contribution: (T, d).
    """
    T, d = xt.shape
    Tk = T * k
    flat_t = jnp.arange(Tk, dtype=jnp.int32) // k
    e_local = flat_e - e_offset
    is_local = (e_local >= 0) & (e_local < n_local)
    sort_key = jnp.where(is_local, e_local, n_local)     # non-local -> end
    order = jnp.argsort(sort_key, stable=True)
    sid = sort_key[order]                                # sorted local ids
    # position within each expert segment (cummax-of-starts trick)
    idx = jnp.arange(Tk, dtype=jnp.int32)
    is_new = jnp.concatenate([jnp.ones((1,), bool), sid[1:] != sid[:-1]])
    starts = jax.lax.associative_scan(jnp.maximum,
                                      jnp.where(is_new, idx, 0))
    pos = idx - starts
    keep = (sid < n_local) & (pos < C)
    dest = jnp.where(keep, sid * C + pos, n_local * C)   # overflow slot
    gathered = jnp.take(xt, flat_t[order], axis=0)       # (Tk, d)
    buf = jnp.zeros((n_local * C + 1, d), xt.dtype).at[dest].set(gathered)
    ffn = expert_ffn or (lambda b: _expert_ffn(b, wi, wg, wo))
    out_buf = ffn(buf[:n_local * C].reshape(n_local, C, d))
    out_pad = jnp.concatenate(
        [out_buf.reshape(n_local * C, d),
         jnp.zeros((1, d), xt.dtype)], axis=0)
    contrib = jnp.take(out_pad, dest, axis=0) * flat_g[order][:, None].astype(xt.dtype)
    y = jnp.zeros((T, d), xt.dtype).at[flat_t[order]].add(contrib)
    return y


def _route(cfg, xt: jax.Array, router_w: jax.Array
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing with renormalized gates + load-balancing aux loss."""
    logits = xt.astype(jnp.float32) @ router_w           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(probs, cfg.moe_top_k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e fraction_e * prob_e
    E = cfg.moe_experts
    onehot = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * prob)
    return top_i.astype(jnp.int32), top_g, aux


def _route_local(cfg, xt: jax.Array, router_w: jax.Array):
    """Routing math shared by the outside path and the fused-EP path.
    Returns (flat_e, flat_g, (count_sum, prob_sum)) with flat arrays of
    length T*k and per-expert partial sums for the aux loss."""
    k, E = cfg.moe_top_k, cfg.moe_experts
    logits = xt.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(probs, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    return (top_i.astype(jnp.int32).reshape(-1), top_g.reshape(-1),
            (jnp.sum(onehot, axis=0), jnp.sum(probs, axis=0)))


def _shared_partial(cfg, xt: jax.Array, sh: Params) -> jax.Array:
    """Shared-expert contribution from a model-rank's f-slice (partial sum
    completed by the EP combine psum)."""
    h = (jax.nn.silu(xt @ sh["wg"]["w"].astype(xt.dtype))
         * (xt @ sh["wi"]["w"].astype(xt.dtype)))
    return h @ sh["wo"]["w"].astype(xt.dtype)


def moe_apply(p: Params, cfg, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    k, E = cfg.moe_top_k, cfg.moe_experts

    mesh = get_mesh()
    sc = cfg.sharding
    if sc.enabled and mesh is not None and sc.model_axis in mesh.axis_names:
        tp = mesh.shape[sc.model_axis]
    else:
        tp = 1

    if tp > 1 and E % tp == 0 and cfg.moe_fused_ep:
        dp_axes_ = tuple(a for a in sc.data_axes
                         if a in mesh.axis_names and a != sc.model_axis)
        dp_size_ = 1
        for a in dp_axes_:
            dp_size_ *= mesh.shape[a]
        if T % (dp_size_ * tp) == 0:   # decode batches may be too small
            return _moe_fused_ep(p, cfg, xt, mesh, tp, B, S)

    top_i, top_g, aux = _route(cfg, xt, p["router"]["w"])
    flat_e = top_i.reshape(-1)
    flat_g = top_g.reshape(-1)

    if tp > 1 and E % tp == 0:
        n_local = E // tp
        dp_axes = tuple(a for a in sc.data_axes if a in mesh.axis_names)
        # tokens sharded over data axes, replicated over model;
        # experts sharded over model axis; combine = psum over model.
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        # 2-D resident experts (serving): weights enter the shard_map in
        # their stored (E:model, d:data) layout — zero weight collectives;
        # tokens must then be REPLICATED across data ranks (each rank
        # holds a d-slice of every token's contraction).
        expert_2d = (cfg.moe_expert_2d and len(dp_axes) > 0
                     and d % dp_size == 0)
        # capacity per expert, sized from the *local* token shard
        C = _capacity(max(1, T if expert_2d else T // dp_size),
                      k, E, cfg.moe_capacity_factor)

        combine = cfg.moe_combine
        if combine == "reduce_scatter" and (T // max(1, dp_size)) % tp != 0:
            combine = "psum"   # decode batches too small to scatter
        token_gather = (not expert_2d and cfg.moe_token_gather
                        and T % (dp_size * tp) == 0)

        def local_fn(xt_l, fe_l, fg_l, wi_l, wg_l, wo_l):
            if token_gather:
                xt_l = jax.lax.all_gather(xt_l, sc.model_axis, axis=0,
                                          tiled=True)
            e_off = jax.lax.axis_index(sc.model_axis) * n_local
            ffn2d = None
            if expert_2d:
                ds = d // dp_size
                dpi = jnp.int32(0)
                mult = 1
                for a in reversed(dp_axes):
                    dpi = dpi + jax.lax.axis_index(a) * mult
                    mult *= mesh.shape[a]

                def ffn2d(buf, wi_l=wi_l, wg_l=wg_l, wo_l=wo_l, dpi=dpi):
                    # buf: (E_l, C, d) full-d; weights: (E_l, d/dp, f),
                    # (E_l, f, d/dp) — slice buf to this rank's d-shard
                    buf_l = jax.lax.dynamic_slice_in_dim(
                        buf, dpi * ds, ds, axis=2)
                    hg = jax.lax.psum(
                        jnp.einsum("ecd,edf->ecf", buf_l, wg_l), dp_axes)
                    hi = jax.lax.psum(
                        jnp.einsum("ecd,edf->ecf", buf_l, wi_l), dp_axes)
                    h = jax.nn.silu(hg) * hi
                    y_part = jnp.einsum("ecf,efd->ecd", h, wo_l)
                    return jax.lax.all_gather(
                        y_part, dp_axes, axis=2, tiled=True)

            y = _dispatch_compute_combine(xt_l, fe_l, fg_l, k, n_local,
                                          e_off, C, wi_l, wg_l, wo_l,
                                          expert_ffn=ffn2d)
            if combine == "psum_bf16":
                return jax.lax.psum(y.astype(jnp.bfloat16),
                                    sc.model_axis).astype(xt_l.dtype)
            if combine == "reduce_scatter":
                # half the wire bytes of an all-reduce; output arrives
                # token-sharded over model — pairs with seq-parallel
                # residuals which keep it sharded between layers
                return jax.lax.psum_scatter(
                    y.astype(jnp.bfloat16), sc.model_axis,
                    scatter_dimension=0, tiled=True).astype(xt_l.dtype)
            return jax.lax.psum(y, sc.model_axis)

        dp = dp_axes if dp_axes else None
        if combine == "reduce_scatter":
            axes0 = (tuple(dp_axes) + (sc.model_axis,)) if dp_axes \
                else (sc.model_axis,)
            out_spec = P(axes0, None)
        else:
            out_spec = P(dp, None)
        xt_spec = (P((tuple(dp_axes) + (sc.model_axis,)) if dp_axes
                     else sc.model_axis, None)
                   if token_gather else P(dp, None))
        if expert_2d:
            # weights consumed in their stored 2-D layout, no resharding;
            # tokens/gates replicated across data ranks; output identical
            # on every data rank (the replication checker can't prove it
            # — disabled via check_vma/check_rep)
            wi_spec = P(sc.model_axis, dp, None)
            wo_spec = P(sc.model_axis, None, dp)
            xt_spec = P(None, None)
            fe_spec = fg_spec = P(None)
            out_spec = P(None, None)
        else:
            wi_spec = P(sc.model_axis, None, None)
            wo_spec = P(sc.model_axis, None, None)
            fe_spec = fg_spec = P(dp)
        y = shard_map(
            local_fn, mesh=mesh,
            in_specs=(xt_spec, fe_spec, fg_spec,
                      wi_spec, wi_spec, wo_spec),
            out_specs=out_spec,
            check_vma=not expert_2d,
        )(xt, flat_e, flat_g, p["wi"], p["wg"], p["wo"])
    else:
        C = _capacity(T, k, E, cfg.moe_capacity_factor)
        y = _dispatch_compute_combine(xt, flat_e, flat_g, k, E, 0, C,
                                      p["wi"], p["wg"], p["wo"])

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xt, cfg)
    return y.reshape(B, S, d), aux


def _moe_fused_ep(p: Params, cfg, xt: jax.Array, mesh, tp: int,
                  B: int, S: int) -> Tuple[jax.Array, jax.Array]:
    """Fully fused expert parallelism: tokens enter model-sharded and are
    all-gathered in bf16 inside the shard_map; routing, dispatch, expert
    FFN, the shared expert (f-sliced per rank) and the aux-loss partials
    all happen per device; ONE psum over "model" combines everything.

    Removes (measured on kimi-k2): the router-probs all-gather, the
    unsharded shared-expert activation gather, and the f32 replicated-
    input backward psum — the three largest collective line items of the
    baseline MoE layer."""
    T, d = xt.shape
    k, E = cfg.moe_top_k, cfg.moe_experts
    sc = cfg.sharding
    n_local = E // tp
    dp_axes = tuple(a for a in sc.data_axes
                    if a in mesh.axis_names and a != sc.model_axis)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    C = _capacity(max(1, T // dp_size), k, E, cfg.moe_capacity_factor)
    has_shared = bool(cfg.n_shared_experts)
    combine = cfg.moe_combine
    if combine == "reduce_scatter" and (T // max(1, dp_size)) % tp != 0:
        combine = "psum"   # decode batches too small to scatter

    def local_fn(xt_l, router_w, wi_l, wg_l, wo_l, *shared):
        xt_full = jax.lax.all_gather(xt_l, sc.model_axis, axis=0, tiled=True)
        fe, fg, (cnt, psum_probs) = _route_local(cfg, xt_full, router_w)
        e_off = jax.lax.axis_index(sc.model_axis) * n_local
        y = _dispatch_compute_combine(xt_full, fe, fg, k, n_local,
                                      e_off, C, wi_l, wg_l, wo_l)
        if has_shared:
            y = y + _shared_partial(cfg, xt_full,
                                    {"wg": {"w": shared[0]},
                                     "wi": {"w": shared[1]},
                                     "wo": {"w": shared[2]}})
        if combine == "reduce_scatter":
            y = jax.lax.psum_scatter(y.astype(jnp.bfloat16), sc.model_axis,
                                     scatter_dimension=0,
                                     tiled=True).astype(xt_l.dtype)
        else:
            y = jax.lax.psum(y, sc.model_axis)
        # aux-loss partials: identical across model ranks (computed from
        # the gathered tokens), so psum over model + /tp both replicates
        # them for the VMA checker and leaves the value unchanged
        red_axes = tuple(dp_axes) + (sc.model_axis,)
        cnt = jax.lax.psum(cnt, red_axes) / tp
        psum_probs = jax.lax.psum(psum_probs, red_axes) / tp
        return y, cnt, psum_probs

    tok_axes = (tuple(dp_axes) + (sc.model_axis,)) if dp_axes \
        else (sc.model_axis,)
    y_spec = (P(tok_axes, None) if combine == "reduce_scatter"
              else P(dp_axes if dp_axes else None, None))
    args = [xt, p["router"]["w"], p["wi"], p["wg"], p["wo"]]
    in_specs = [P(tok_axes, None), P(None, None),
                P(sc.model_axis, None, None),
                P(sc.model_axis, None, None),
                P(sc.model_axis, None, None)]
    if has_shared:
        args += [p["shared"]["wg"]["w"], p["shared"]["wi"]["w"],
                 p["shared"]["wo"]["w"]]
        in_specs += [P(None, sc.model_axis), P(None, sc.model_axis),
                     P(sc.model_axis, None)]
    sm_fn = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                      out_specs=(y_spec, P(), P()))

    # jax 0.4.x shard_map transpose chokes on symbolic-Zero cotangents for
    # the (usually undifferentiated) aux-stat outputs; custom_vjp
    # materializes them before they reach the transpose rule.
    @jax.custom_vjp
    def _fused_call(*a):
        return sm_fn(*a)

    def _fused_fwd(*a):
        out, vjp = jax.vjp(sm_fn, *a)
        return out, vjp

    def _fused_bwd(vjp, cts):
        return vjp(cts)

    _fused_call.defvjp(_fused_fwd, _fused_bwd)
    y, cnt, prob_sum = _fused_call(*args)
    frac = cnt / jnp.maximum(jnp.sum(cnt), 1.0)
    prob = prob_sum / jnp.maximum(jnp.sum(cnt), 1.0)
    aux = E * jnp.sum(frac * prob)
    return y.reshape(B, S, d), aux
