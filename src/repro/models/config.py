"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis sharding knobs consumed by distributed/sharding.py and
    the in-model activation constraints."""
    enabled: bool = False
    data_axes: Tuple[str, ...] = ("data",)     # batch-sharding axes
    model_axis: Optional[str] = "model"        # TP/EP axis
    fsdp_axes: Tuple[str, ...] = ()            # param-sharding (ZeRO-3) axes
    seq_axis: Optional[str] = None             # sequence parallelism (decode SP)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    max_seq: int = 4096

    norm: str = "rmsnorm"          # rmsnorm | layernorm | nonparametric
    pos: str = "rope"              # rope | learned | sinusoidal | none
    rope_theta: float = 10_000.0
    mlp: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    n_shared_experts: int = 0      # always-on experts (kimi-style)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0            # hybrid: shared attn block every k layers

    # --- xLSTM ---
    slstm_every: int = 0           # sLSTM block every k layers (rest mLSTM)

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # frames after the (stubbed) conv frontend

    # --- modality frontend stubs ---
    frontend: str = "none"         # none | vision_stub | audio_stub
    n_patches: int = 0             # vision: image patch embeddings per sample

    dtype: str = "bfloat16"
    scan_layers: bool = True       # scan over stacked homogeneous layers
    remat: bool = True
    remat_policy: str = "nothing_saveable"  # nothing_saveable | dots | full
    chunked_loss_chunks: int = 8   # seq chunks for vocab-sharded CE

    # --- VTA quantized-inference path (the paper's technique) ---
    quantized_inference: bool = False   # int8 PTQ weights on serve path
    use_pallas: bool = False            # pallas kernels (TPU) vs jnp oracle

    # --- distributed perf levers (§Perf hillclimbing) ---
    seq_parallel_residual: bool = False  # shard residual stream S over model
    moe_combine: str = "psum"            # psum | psum_bf16 | reduce_scatter
    moe_token_gather: bool = False       # tokens enter EP model-sharded +
                                         # explicit bf16 all_gather (backward
                                         # becomes a bf16 reduce-scatter
                                         # instead of an f32 psum)
    moe_fused_ep: bool = False           # routing + shared expert computed
                                         # inside the EP shard_map: removes
                                         # the router-probs all-gather and
                                         # the unsharded shared-expert
                                         # activation
    kv_cache_quant: bool = False         # int8 KV cache (VTA-style PTQ)
    moe_expert_2d: bool = False          # serving: experts stay RESIDENT,
                                         # sharded (E:model, d:data); the
                                         # FFN contracts d-partially with a
                                         # (tiny at decode) activation psum
                                         # instead of gathering weights
                                         # every step

    sharding: ShardingConfig = field(default_factory=ShardingConfig)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def block_pattern(self) -> Tuple[str, ...]:
        """Per-layer block types.  Homogeneous stacks return a uniform
        pattern and are scanned; heterogeneous (hybrid/xlstm) unroll."""
        if self.family == "moe":
            return tuple("moe" for _ in range(self.n_layers))
        if self.family == "hybrid":
            # zamba2: mamba2 backbone, a *shared* attention block applied
            # every `attn_every` layers (weights shared across applications)
            out = []
            for i in range(self.n_layers):
                out.append("mamba2_sharedattn"
                           if self.attn_every and (i + 1) % self.attn_every == 0
                           else "mamba2")
            return tuple(out)
        if self.family == "ssm" and self.slstm_every:
            return tuple("slstm" if (i % self.slstm_every) == self.slstm_every - 1
                         else "mlstm" for i in range(self.n_layers))
        if self.family == "ssm":
            return tuple("mamba2" for _ in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    @property
    def is_homogeneous(self) -> bool:
        return len(set(self.block_pattern())) == 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # parameter counting (for §Roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        d, hd = self.d_model, self.hd
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.mlp == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        moe = 0
        if self.moe_experts:
            e_ff = self.moe_d_ff or self.d_ff
            per_expert = 3 * d * e_ff if self.mlp == "swiglu" else 2 * d * e_ff
            moe = self.moe_experts * per_expert + d * self.moe_experts
            mlp = 0
        mamba = 0
        if self.family in ("hybrid", "ssm") and self.ssm_state:
            di = self.d_inner
            nh = self.ssm_heads
            mamba = (d * (2 * di + 2 * self.ssm_state + nh)   # in_proj (x,z,B,C,dt)
                     + di * d                                  # out_proj
                     + self.ssm_conv * (di + 2 * self.ssm_state)
                     + 2 * nh)                                 # A, D
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        counts = {"embed": emb}
        pattern = self.block_pattern()
        n_attn = sum(1 for b in pattern if "attn" in b and b != "mamba2_sharedattn")
        n_sharedattn = 1 if any(b == "mamba2_sharedattn" for b in pattern) else 0
        n_moe = sum(1 for b in pattern if b == "moe")
        n_mamba = sum(1 for b in pattern if b.startswith("mamba2"))
        n_xlstm = sum(1 for b in pattern if b in ("mlstm", "slstm"))
        counts["attn"] = n_attn * (attn + mlp)
        counts["shared_attn"] = n_sharedattn * (attn + mlp)
        counts["moe"] = n_moe * (attn + moe)
        counts["mamba"] = n_mamba * mamba
        # xlstm blocks: up/down proj + qkv-ish
        counts["xlstm"] = n_xlstm * (2 * d * 2 * d + 4 * d * d)
        if self.encoder_layers:
            counts["encoder"] = self.encoder_layers * (attn + mlp)
            counts["cross_attn"] = self.n_layers * attn
        return counts

    @property
    def n_params(self) -> int:
        return sum(self.param_counts().values())

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe_experts:
            return self.n_params
        d = self.d_model
        e_ff = self.moe_d_ff or self.d_ff
        per_expert = (3 if self.mlp == "swiglu" else 2) * d * e_ff
        total_expert = self.moe_experts * per_expert * self.n_layers
        active_expert = ((self.moe_top_k + self.n_shared_experts)
                         * per_expert * self.n_layers)
        return self.n_params - total_expert + active_expert
