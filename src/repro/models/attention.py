"""GQA attention with KV cache: train / prefill / decode modes."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.distributed.meshctx import constrain

from .layers import apply_rope, linear_apply, linear_init

Params = Dict[str, Any]


def attn_init(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    return {"wq": linear_init(ks[0], d, cfg.q_dim, dt),
            "wk": linear_init(ks[1], d, cfg.kv_dim, dt),
            "wv": linear_init(ks[2], d, cfg.kv_dim, dt),
            "wo": linear_init(ks[3], cfg.q_dim, d, dt)}


def _qkv(p: Params, cfg, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    q = linear_apply(p["wq"], x, cfg).reshape(B, S, cfg.n_heads, cfg.hd)
    k = linear_apply(p["wk"], x, cfg).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = linear_apply(p["wv"], x, cfg).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(p: Params, cfg, x: jax.Array, *, causal: bool = True,
               positions: Optional[jax.Array] = None) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(q, k, v, causal=causal, use_pallas=cfg.use_pallas)
    return linear_apply(p["wo"], o.reshape(B, S, cfg.q_dim), cfg)


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    if cfg.kv_cache_quant:
        # VTA-style int8 cache: per-(token, head) symmetric scales — the
        # paper's PTQ applied to the decode-bandwidth bottleneck
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                               jnp.int8),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd),
                               jnp.int8),
                "k_s": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32),
                "v_s": jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32)}
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)}


def _quant_kv(x: jax.Array):
    """(B, S, KH, D) -> int8 values + (B, S, KH) scales."""
    amax = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1),
                       1e-6)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -128, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attn_prefill(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full causal pass over the prompt; writes positions [0, S) of cache."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(q, k, v, causal=True, use_pallas=cfg.use_pallas)
    new_cache = dict(cache)
    if cfg.kv_cache_quant:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        for name, val in (("k", kq), ("v", vq)):
            new_cache[name] = jax.lax.dynamic_update_slice(
                cache[name], val, (0, 0, 0, 0))
        for name, val in (("k_s", ks), ("v_s", vs)):
            new_cache[name] = jax.lax.dynamic_update_slice(
                cache[name], val, (0, 0, 0))
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    return linear_apply(p["wo"], o.reshape(B, S, cfg.q_dim), cfg), new_cache


def attn_decode(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array],
                pos: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step: x (B, 1, d); pos scalar int32 = current index."""
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    ipos = pos.astype(jnp.int32)
    new_cache = dict(cache)
    if cfg.kv_cache_quant:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kq, (0, ipos, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vq, (0, ipos, 0, 0))
        new_cache["k_s"] = jax.lax.dynamic_update_slice(
            cache["k_s"], ks, (0, ipos, 0))
        new_cache["v_s"] = jax.lax.dynamic_update_slice(
            cache["v_s"], vs, (0, ipos, 0))
        k_cache = _dequant_kv(new_cache["k"], new_cache["k_s"], x.dtype)
        v_cache = _dequant_kv(new_cache["v"], new_cache["v_s"], x.dtype)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, ipos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, ipos, 0, 0))
        new_cache["k"], new_cache["v"] = k_cache, v_cache
    o = decode_attention(q, k_cache, v_cache, pos + 1,
                         use_pallas=cfg.use_pallas)
    out = linear_apply(p["wo"], o.reshape(B, 1, cfg.q_dim), cfg)
    return out, new_cache


def cross_attn_init(key, cfg) -> Params:
    return attn_init(key, cfg)


def cross_attn_apply(p: Params, cfg, x: jax.Array, enc_kv: Dict[str, jax.Array]
                     ) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (whisper)."""
    B, S, _ = x.shape
    q = linear_apply(p["wq"], x, cfg).reshape(B, S, cfg.n_heads, cfg.hd)
    o = flash_attention(q, enc_kv["k"], enc_kv["v"], causal=False,
                        use_pallas=cfg.use_pallas)
    return linear_apply(p["wo"], o.reshape(B, S, cfg.q_dim), cfg)


def encode_cross_kv(p: Params, cfg, enc_out: jax.Array) -> Dict[str, jax.Array]:
    B, T, _ = enc_out.shape
    k = linear_apply(p["wk"], enc_out, cfg).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    v = linear_apply(p["wv"], enc_out, cfg).reshape(B, T, cfg.n_kv_heads, cfg.hd)
    return {"k": k, "v": v}
