"""Model zoo: unified LM covering dense/GQA, MoE, Mamba2 hybrid, xLSTM,
whisper enc-dec and VLM-backbone architectures."""
from . import attention, config, layers, moe, ssm, transformer, xlstm  # noqa: F401
from .config import ModelConfig, ShardingConfig  # noqa: F401
