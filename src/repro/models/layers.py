"""Shared neural building blocks: norms, RoPE, MLPs, embeddings, linear
(with the VTA int8 quantized path as a first-class backend)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.vta_gemm import quantized_linear

Params = Dict[str, Any]


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------
# linear (dense or VTA-quantized)
# ----------------------------------------------------------------------
def linear_init(key, d_in: int, d_out: int, dtype) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    w = jax.random.uniform(key, (d_in, d_out), jnp.float32, -scale, scale)
    return {"w": w.astype(dtype)}


def linear_apply(p: Params, x: jax.Array, cfg=None) -> jax.Array:
    """Dense matmul, or the VTA int8 path when the weights were quantized
    (serve-time PTQ, §5): p == {"w_q": int8, "w_scale": f32}."""
    if "w_q" in p:
        return quantized_linear(
            x, p["w_q"], p["w_scale"],
            use_pallas=bool(cfg and cfg.use_pallas))
    return x @ p["w"].astype(x.dtype)


def quantize_linear_params(p: Params) -> Params:
    """Symmetric per-channel PTQ of a dense linear layer (host-side)."""
    w = jnp.asarray(p["w"], jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)
    scale = (amax / 127.0).astype(jnp.float32)
    w_q = jnp.clip(jnp.round(w / scale[None, :]), -128, 127).astype(jnp.int8)
    return {"w_q": w_q, "w_scale": scale}


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------
def norm_init(cfg, d: int) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "nonparametric":   # olmo: LN without affine params
        return {}
    raise ValueError(cfg.norm)


def norm_apply(cfg, p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        r = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (r * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    r = (xf - mu) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        r = r * p["scale"] + p["bias"]
    return r.astype(x.dtype)


# ----------------------------------------------------------------------
# rotary position embedding
# ----------------------------------------------------------------------
def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_embedding(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------
def mlp_init(key, cfg, d: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    if cfg.mlp == "swiglu":
        return {"wi": linear_init(ks[0], d, d_ff, dt),
                "wg": linear_init(ks[1], d, d_ff, dt),
                "wo": linear_init(ks[2], d_ff, d, dt)}
    return {"wi": linear_init(ks[0], d, d_ff, dt),
            "wo": linear_init(ks[1], d_ff, d, dt)}


def mlp_apply(p: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(linear_apply(p["wg"], x, cfg)) * linear_apply(p["wi"], x, cfg)
    else:
        h = jax.nn.gelu(linear_apply(p["wi"], x, cfg))
    return linear_apply(p["wo"], h, cfg)


# ----------------------------------------------------------------------
# embeddings
# ----------------------------------------------------------------------
def embed_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    p = {"tokens": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model))
                    * 0.02).astype(dt)}
    if cfg.pos == "learned":
        p["pos"] = (jax.random.normal(jax.random.fold_in(key, 1),
                                      (cfg.max_seq, cfg.d_model)) * 0.02
                    ).astype(dt)
    return p


def embed_apply(p: Params, cfg, tokens: jax.Array,
                positions: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(p["tokens"], tokens, axis=0)
    if cfg.pos == "learned":
        pos = (positions if positions is not None
               else jnp.arange(tokens.shape[-1]))
        x = x + jnp.take(p["pos"], pos, axis=0)
    elif cfg.pos == "sinusoidal":
        pos = (positions if positions is not None
               else jnp.arange(tokens.shape[-1]))
        x = x + sinusoidal_embedding(cfg.max_seq, cfg.d_model)[pos].astype(x.dtype)
    return x
