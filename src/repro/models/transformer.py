"""Unified LM covering all ten assigned architectures.

Homogeneous stacks (dense / MoE / whisper / vlm) are *scanned* over
stacked layer params — the lowered HLO is O(1) in depth, which is what
makes the 61-layer / 1T-param kimi-k2 dry-run compile in minutes.
Heterogeneous stacks (zamba2 hybrid, xlstm interleave) unroll their
pattern with per-type stacked params.

Three entry points per architecture:
  forward_train(params, cfg, batch) -> (loss, metrics)      [train_4k]
  prefill(params, cfg, batch)       -> (logits, caches)     [prefill_32k]
  decode_step(params, cfg, caches, token, pos) -> (logits, caches)
                                                   [decode_32k / long_500k]
Cross-entropy is computed in sequence chunks with vocab-sharded logits —
full (B, S, V) logits never materialize (minitron V=256k, kimi V=164k).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .config import ModelConfig
from .layers import (embed_apply, embed_init, linear_apply, linear_init,
                     mlp_apply, mlp_init, norm_apply, norm_init)

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# per-block init / apply
# ----------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, btype: str) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if btype == "attn":
        p = {"ln1": norm_init(cfg, d), "attn": attn.attn_init(ks[0], cfg),
             "ln2": norm_init(cfg, d),
             "mlp": mlp_init(ks[1], cfg, d, cfg.d_ff)}
        if cfg.encoder_layers:   # whisper decoder layer: add cross-attn
            p["lnx"] = norm_init(cfg, d)
            p["cross"] = attn.cross_attn_init(ks[2], cfg)
        return p
    if btype == "moe":
        return {"ln1": norm_init(cfg, d), "attn": attn.attn_init(ks[0], cfg),
                "ln2": norm_init(cfg, d), "moe": moe_mod.moe_init(ks[1], cfg)}
    if btype in ("mamba2", "mamba2_sharedattn"):
        return {"ln1": norm_init(cfg, d),
                "mamba": ssm_mod.mamba2_init(ks[0], cfg)}
    if btype == "mlstm":
        return {"ln1": norm_init(cfg, d),
                "mlstm": xlstm_mod.mlstm_init(ks[0], cfg)}
    if btype == "slstm":
        return {"ln1": norm_init(cfg, d),
                "slstm": xlstm_mod.slstm_init(ks[0], cfg)}
    raise ValueError(btype)


def _block_cache(cfg: ModelConfig, btype: str, batch: int, max_len: int,
                 dtype) -> Params:
    if btype in ("attn", "moe"):
        return {"kv": attn.init_kv_cache(cfg, batch, max_len, dtype)}
    if btype == "mamba2":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if btype == "mamba2_sharedattn":
        c = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        # weights of the shared block are global, but each *application*
        # attends over its own history -> per-layer KV cache
        c["shared_kv"] = attn.init_kv_cache(cfg, batch, max_len, dtype)
        return c
    if btype == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch)
    if btype == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch)
    raise ValueError(btype)


def _block_apply(p: Params, cfg: ModelConfig, btype: str, x: jax.Array,
                 mode: str, cache, pos, enc_out, shared_p):
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    sc = cfg.sharding
    dspec = sc.data_axes if sc.enabled else None

    def _attn_part(pp, xin, cc):
        h = norm_apply(cfg, pp["ln1"], xin)
        if mode == "train":
            return attn.attn_train(pp["attn"], cfg, h), cc
        if mode == "prefill":
            return attn.attn_prefill(pp["attn"], cfg, h, cc)
        return attn.attn_decode(pp["attn"], cfg, h, cc, pos)

    if btype in ("attn", "moe"):
        o, kv = _attn_part(p, x, cache["kv"] if cache is not None else None)
        x = x + o
        x = constrain(x, dspec, None, None)
        enc_kv = None
        if "cross" in p:
            h = norm_apply(cfg, p["lnx"], x)
            if mode in ("train", "prefill"):
                enc_kv = attn.encode_cross_kv(p["cross"], cfg, enc_out)
            else:
                enc_kv = cache["cross_kv"]
            x = x + attn.cross_attn_apply(p["cross"], cfg, h, enc_kv)
        h = norm_apply(cfg, p["ln2"], x)
        if btype == "moe":
            o, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        else:
            o = mlp_apply(p["mlp"], h, cfg)
        x = x + o
        if cache is not None:
            new_cache = dict(cache)
            new_cache["kv"] = kv
            if enc_kv is not None and "cross_kv" in cache:
                new_cache["cross_kv"] = jax.tree.map(
                    lambda a, b: a.astype(b.dtype), enc_kv, cache["cross_kv"]
                ) if mode == "prefill" else cache["cross_kv"]
    elif btype in ("mamba2", "mamba2_sharedattn"):
        h = norm_apply(cfg, p["ln1"], x)
        if mode == "train":
            x = x + ssm_mod.mamba2_train(p["mamba"], cfg, h)
            ssm_cache = None
        elif mode == "prefill":
            o, ssm_cache = ssm_mod.mamba2_prefill(p["mamba"], cfg, h, cache)
            x = x + o
        else:
            o, ssm_cache = ssm_mod.mamba2_decode(p["mamba"], cfg, h, cache)
            x = x + o
        if cache is not None:
            new_cache = dict(cache)
            new_cache.update(ssm_cache)
        if btype == "mamba2_sharedattn" and shared_p is not None:
            # zamba2: globally *shared* transformer block applied here;
            # its KV cache is per-application (lives in this layer's cache)
            h = norm_apply(cfg, shared_p["ln1"], x)
            if mode == "train":
                x = x + attn.attn_train(shared_p["attn"], cfg, h)
            elif mode == "prefill":
                o, skv = attn.attn_prefill(shared_p["attn"], cfg, h,
                                           cache["shared_kv"])
                x = x + o
                new_cache["shared_kv"] = skv
            else:
                o, skv = attn.attn_decode(shared_p["attn"], cfg, h,
                                          cache["shared_kv"], pos)
                x = x + o
                new_cache["shared_kv"] = skv
            h2 = norm_apply(cfg, shared_p["ln2"], x)
            x = x + mlp_apply(shared_p["mlp"], h2, cfg)
    elif btype == "mlstm":
        h = norm_apply(cfg, p["ln1"], x)
        if mode == "train":
            x = x + xlstm_mod.mlstm_train(p["mlstm"], cfg, h)
        elif mode == "prefill":
            o, new_cache = xlstm_mod.mlstm_prefill(p["mlstm"], cfg, h, cache)
            x = x + o
        else:
            o, new_cache = xlstm_mod.mlstm_decode(p["mlstm"], cfg, h, cache)
            x = x + o
    elif btype == "slstm":
        h = norm_apply(cfg, p["ln1"], x)
        if mode == "train":
            x = x + xlstm_mod.slstm_train(p["slstm"], cfg, h)
        elif mode == "prefill":
            o, new_cache = xlstm_mod.slstm_prefill(p["slstm"], cfg, h, cache)
            x = x + o
        else:
            o, new_cache = xlstm_mod.slstm_decode(p["slstm"], cfg, h, cache)
            x = x + o
    else:
        raise ValueError(btype)
    if cfg.seq_parallel_residual and mode == "train":
        # Megatron-style sequence parallelism: the residual stream (and so
        # the per-layer saved activations of the layer scan) live sharded
        # over the model axis; matmuls gather on entry, contributing the
        # same wire bytes the TP all-reduce already paid.
        x = constrain(x, dspec, sc.model_axis if sc.enabled else None, None)
    else:
        x = constrain(x, dspec, None, None)
    return x, aux, new_cache


# ----------------------------------------------------------------------
# model init
# ----------------------------------------------------------------------
def _stack_init(fn, key, n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"embed": embed_init(ks[0], cfg),
                 "final_norm": norm_init(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(ks[1], cfg.d_model, cfg.vocab_size,
                                   jnp.dtype(cfg.dtype))
    pattern = cfg.block_pattern()
    layers: Params = {}
    for i, btype in enumerate(sorted(set(pattern))):
        n = sum(1 for b in pattern if b == btype)
        layers[btype] = _stack_init(
            lambda k, bt=btype: _block_init(k, cfg, bt),
            jax.random.fold_in(ks[2], i), n)
    p["layers"] = layers
    if any(b == "mamba2_sharedattn" for b in pattern):
        d = cfg.d_model
        kk = jax.random.split(ks[3], 2)
        p["shared_attn"] = {"ln1": norm_init(cfg, d),
                            "attn": attn.attn_init(kk[0], cfg),
                            "ln2": norm_init(cfg, d),
                            "mlp": mlp_init(kk[1], cfg, d, cfg.d_ff)}
    if cfg.encoder_layers:
        p["encoder"] = {
            "layers": _stack_init(
                lambda k: {"ln1": norm_init(cfg, cfg.d_model),
                           "attn": attn.attn_init(k, cfg),
                           "ln2": norm_init(cfg, cfg.d_model),
                           "mlp": mlp_init(jax.random.fold_in(k, 1), cfg,
                                           cfg.d_model, cfg.d_ff)},
                ks[4], cfg.encoder_layers),
            "norm": norm_init(cfg, cfg.d_model),
        }
    return p


# ----------------------------------------------------------------------
# encoder (whisper) — non-causal attn stack over stub frame embeddings
# ----------------------------------------------------------------------
def _encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    def body(x, lp):
        h = norm_apply(cfg, lp["ln1"], x)
        x = x + attn.attn_train(lp["attn"], cfg, h, causal=False)
        h = norm_apply(cfg, lp["ln2"], x)
        x = x + mlp_apply(lp["mlp"], h, cfg)
        return x, None
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frames, params["encoder"]["layers"])
    return norm_apply(cfg, params["encoder"]["norm"], x)


# ----------------------------------------------------------------------
# backbone (train mode — no caches)
# ----------------------------------------------------------------------
def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _find_period(pattern) -> Tuple[int, int]:
    """Smallest repeating unit of a heterogeneous layer pattern.
    Returns (period, repeats); the tail pattern[period*repeats:] unrolls."""
    L = len(pattern)
    for p in range(1, L // 2 + 1):
        unit = pattern[:p]
        reps = L // p
        if reps >= 2 and tuple(unit) * reps == pattern[:p * reps]:
            return p, reps
    return L, 1


def forward_hidden(params: Params, cfg: ModelConfig, x: jax.Array,
                   enc_out: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Run the layer stack in train mode.  Returns (hidden, aux_loss).

    Scanning over layers is load-bearing twice: it keeps the HLO O(1) in
    depth AND it is the only *structural* rematerialization — XLA's CSE
    legally undoes jax.checkpoint recompute in unrolled stacks (measured:
    identical FLOPs with/without remat), so unrolled hetero stacks paid
    full-residual memory.  Heterogeneous patterns scan over their smallest
    repeating unit (xlstm: 7 mLSTM + 1 sLSTM; zamba2: 5 Mamba2 + shared
    attn), indexing per-type stacked params with the repeat counter."""
    pattern = cfg.block_pattern()
    shared_p = params.get("shared_attn")
    if cfg.is_homogeneous and cfg.scan_layers:
        btype = pattern[0]

        def body(x, lp):
            x, aux, _ = _block_apply(lp, cfg, btype, x, "train", None,
                                     None, enc_out, shared_p)
            return x, aux

        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, auxs = jax.lax.scan(body, x, params["layers"][btype])
        return x, jnp.sum(auxs)

    aux_total = jnp.zeros((), jnp.float32)
    period, reps = _find_period(pattern)
    start = 0
    if cfg.scan_layers and reps >= 2:
        unit = pattern[:period]
        cnt = {b: unit.count(b) for b in set(unit)}
        occ = {b: 0 for b in set(unit)}
        offs = []
        for b in unit:
            offs.append(occ[b])
            occ[b] += 1

        def pbody(x, r):
            aux_acc = jnp.zeros((), jnp.float32)
            for j, b in enumerate(unit):
                idx = r * cnt[b] + offs[j]
                lp = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, idx, 0, keepdims=False), params["layers"][b])
                x, aux, _ = _block_apply(lp, cfg, b, x, "train", None,
                                         None, enc_out, shared_p)
                aux_acc = aux_acc + aux
            return x, aux_acc

        if cfg.remat:
            pbody = jax.checkpoint(pbody, policy=_remat_policy(cfg))
        x, auxs = jax.lax.scan(pbody, x, jnp.arange(reps))
        aux_total = aux_total + jnp.sum(auxs)
        start = period * reps

    counters = {b: sum(1 for bb in pattern[:start] if bb == b)
                for b in set(pattern)}
    for btype in pattern[start:]:
        i = counters[btype]
        counters[btype] += 1
        lp = jax.tree.map(lambda a: a[i], params["layers"][btype])

        def body(x, lp=lp, btype=btype):
            return _block_apply(lp, cfg, btype, x, "train", None, None,
                                enc_out, shared_p)[:2]
        if cfg.remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        x, aux = body(x)
        aux_total = aux_total + aux
    return x, aux_total


# ----------------------------------------------------------------------
# inputs -> first hidden states
# ----------------------------------------------------------------------
def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Token embedding + modality prefixes.  Returns (x, enc_out)."""
    x = embed_apply(params["embed"], cfg, batch["tokens"])
    enc_out = None
    if cfg.frontend == "vision_stub" and "patch_emb" in batch:
        # phi-3-vision: precomputed CLIP patch embeddings prefix the text
        x = jnp.concatenate([batch["patch_emb"].astype(x.dtype), x], axis=1)
    if cfg.encoder_layers and "frames" in batch:
        enc_out = _encode(params, cfg, batch["frames"].astype(x.dtype))
    sc = cfg.sharding
    x = constrain(x, sc.data_axes if sc.enabled else None, None, None)
    return x, enc_out


def _head_weight(params: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["tokens"].T
    return params["lm_head"]["w"]


def logits_fn(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = norm_apply(cfg, params["final_norm"], h)
    logits = h @ _head_weight(params, cfg).astype(h.dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    sc = cfg.sharding
    return constrain(logits, sc.data_axes if sc.enabled else None, None,
                     sc.model_axis if sc.enabled else None)


# ----------------------------------------------------------------------
# chunked vocab-sharded cross entropy
# ----------------------------------------------------------------------
def chunked_cross_entropy(params: Params, cfg: ModelConfig, h: jax.Array,
                          targets: jax.Array) -> jax.Array:
    """h: (B, S, d); targets: (B, S) int32 (-1 = ignore)."""
    B, S, d = h.shape
    n = cfg.chunked_loss_chunks
    while S % n:
        n -= 1
    hc = h.reshape(B, n, S // n, d).swapaxes(0, 1)        # (n, B, Sc, d)
    tc = targets.reshape(B, n, S // n).swapaxes(0, 1)

    def body(carry, xt):
        hi, ti = xt
        logits = logits_fn(params, cfg, hi).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ti, 0)[..., None], axis=-1)[..., 0]
        mask = (ti >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(body)   # recompute chunk logits in backward
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc))
    return tot / jnp.maximum(cnt, 1.0)


def forward_train(params: Params, cfg: ModelConfig,
                  batch: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, enc_out = embed_inputs(params, cfg, batch)
    h, aux = forward_hidden(params, cfg, x, enc_out)
    targets = batch["targets"]
    if cfg.frontend == "vision_stub" and "patch_emb" in batch:
        h = h[:, batch["patch_emb"].shape[1]:]   # loss over text tokens only
    loss = chunked_cross_entropy(params, cfg, h, targets)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ----------------------------------------------------------------------
# serving: caches, prefill, decode
# ----------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    pattern = cfg.block_pattern()
    caches: Params = {"layers": {}}
    for btype in sorted(set(pattern)):
        n = sum(1 for b in pattern if b == btype)
        one = _block_cache(cfg, btype, batch, max_len, dtype)
        caches["layers"][btype] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy()
            if hasattr(a, "shape") else a, one)
    if cfg.encoder_layers:
        # cross-attn K/V per decoder layer, filled at prefill
        caches["layers"]["attn"]["cross_kv"] = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                            cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                            cfg.n_kv_heads, cfg.hd), dtype)}
    return caches


def _run_stack_cached(params, cfg, x, caches, mode, pos, enc_out):
    pattern = cfg.block_pattern()
    shared_p = params.get("shared_attn")
    if cfg.is_homogeneous and cfg.scan_layers:
        btype = pattern[0]

        def body(x, xs):
            lp, lc = xs
            x, _, nc = _block_apply(lp, cfg, btype, x, mode, lc, pos,
                                    enc_out, shared_p)
            return x, nc

        x, new_layer_caches = jax.lax.scan(
            body, x, (params["layers"][btype], caches["layers"][btype]))
        new_caches = {"layers": {btype: new_layer_caches}}
    else:
        counters = {b: 0 for b in set(pattern)}
        new_layer_caches = {b: [] for b in set(pattern)}
        for btype in pattern:
            i = counters[btype]
            counters[btype] += 1
            lp = jax.tree.map(lambda a: a[i], params["layers"][btype])
            lc = jax.tree.map(lambda a: a[i], caches["layers"][btype])
            x, _, nc = _block_apply(lp, cfg, btype, x, mode, lc, pos,
                                    enc_out, shared_p)
            new_layer_caches[btype].append(nc)
        stacked = {}
        for btype, lst in new_layer_caches.items():
            stacked[btype] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *lst)
        new_caches = {"layers": stacked}
    return x, new_caches


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            caches: Params) -> Tuple[jax.Array, Params]:
    """Run the prompt; returns (logits at last position, updated caches)."""
    x, enc_out = embed_inputs(params, cfg, batch)
    x, new_caches = _run_stack_cached(params, cfg, x, caches, "prefill",
                                      None, enc_out)
    logits = logits_fn(params, cfg, x[:, -1:])
    return logits, new_caches


def decode_step(params: Params, cfg: ModelConfig, caches: Params,
                token: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Params]:
    """token: (B, 1) int32; pos: scalar int32 — one serve step."""
    x = embed_apply(params["embed"], cfg, token,
                    positions=jnp.broadcast_to(pos, token.shape))
    sc = cfg.sharding
    x = constrain(x, sc.data_axes if sc.enabled else None, None, None)
    x, new_caches = _run_stack_cached(params, cfg, x, caches, "decode",
                                      pos, None)
    logits = logits_fn(params, cfg, x)
    return logits, new_caches
