"""Serve-time PTQ of a parameter tree — the paper's §5 deployment step
(float training checkpoint -> int8 weights) applied to the LM stack.

Every >=2-D linear weight inside layer blocks becomes {w_q: int8,
w_scale: f32 per-output-channel}; embeddings, norms and the LM head stay
float (standard practice, and faithful to VTA: the first conv layer also
stayed on the CPU in the paper's evaluation).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import quantize_linear_params

Params = Any

_QUANT_NAMES = ("wq", "wk", "wv", "wo", "wi", "wg", "up_x", "up_z",
                "w_in", "w_if", "down", "in_proj", "out_proj")


def quantize_params(params: Params) -> Params:
    """PTQ the layer-stack linears (leading layer dim is vmapped over)."""

    def walk(node, name=""):
        if isinstance(node, dict) and "w" in node and hasattr(node["w"], "ndim"):
            if name in _QUANT_NAMES and node["w"].ndim in (2, 3):
                if node["w"].ndim == 3:      # stacked (L, d_in, d_out)
                    return jax.vmap(quantize_linear_params)(node)
                return quantize_linear_params(node)
            return node
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        return node

    out = dict(params)
    out["layers"] = walk(params["layers"])
    if "shared_attn" in params:
        out["shared_attn"] = walk(params["shared_attn"])
    if "encoder" in params:
        out["encoder"] = walk(params["encoder"])
    return out


def quantized_param_shapes(param_shapes: Params) -> Params:
    """ShapeDtypeStruct tree of the quantized params (for the dry-run)."""
    def fake(shape_tree):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shape_tree)
    return jax.eval_shape(lambda p: quantize_params(p), param_shapes)
