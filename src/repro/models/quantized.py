"""Serve-time PTQ of a parameter tree — the paper's §5 deployment step
(float training checkpoint -> int8 weights) applied to the LM stack.

Every >=2-D linear weight inside layer blocks becomes {w_q: int8,
w_scale: f32 per-output-channel}; embeddings, norms and the LM head stay
float (standard practice, and faithful to VTA: the first conv layer also
stayed on the CPU in the paper's evaluation).

:class:`VtaLinear` routes a quantized linear layer through the
program-level JIT (``repro.core.Program``): the layer compiles once into a
task-ISA stream and every subsequent call just rebinds the activation
buffer and re-runs it on either execution backend — the deployment path
that actually exercises the VTA datapath instead of the XLA GEMM.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwspec as _hwspec
from repro.core import quantize as q
from repro.core.program import CompiledProgram, Program
from repro.core.scheduler import Epilogue

from .layers import quantize_linear_params

Params = Any

_QUANT_NAMES = ("wq", "wk", "wv", "wo", "wi", "wg", "up_x", "up_z",
                "w_in", "w_if", "down", "in_proj", "out_proj")


def quantize_params(params: Params) -> Params:
    """PTQ the layer-stack linears (leading layer dim is vmapped over)."""

    def walk(node, name=""):
        if isinstance(node, dict) and "w" in node and hasattr(node["w"], "ndim"):
            if name in _QUANT_NAMES and node["w"].ndim in (2, 3):
                if node["w"].ndim == 3:      # stacked (L, d_in, d_out)
                    return jax.vmap(quantize_linear_params)(node)
                return quantize_linear_params(node)
            return node
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        return node

    out = dict(params)
    out["layers"] = walk(params["layers"])
    if "shared_attn" in params:
        out["shared_attn"] = walk(params["shared_attn"])
    if "encoder" in params:
        out["encoder"] = walk(params["encoder"])
    return out


def quantized_param_shapes(param_shapes: Params) -> Params:
    """ShapeDtypeStruct tree of the quantized params (for the dry-run)."""
    def fake(shape_tree):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shape_tree)
    return jax.eval_shape(lambda p: quantize_params(p), param_shapes)


# ----------------------------------------------------------------------
# linear layers through the program-level JIT
# ----------------------------------------------------------------------
class VtaLinear:
    """A dense layer y = x @ W executed on the VTA datapath via a compiled
    ``Program``.

    Integer-only deployment (§5): weights are re-quantized per-tensor
    (power-of-two requant shifts need one scale), activations are
    dynamically quantized per call, and the int8 GEMM + shift/clip
    epilogue runs as a task-ISA stream on either execution backend.  One
    program is compiled per (batch rows, requant shift) signature and
    cached; subsequent calls only rebind DRAM buffers.
    """

    def __init__(self, w: np.ndarray, spec=None, backend: Any = None,
                 virtual_threads: int = 2, bits: int = 8):
        w = np.asarray(w, np.float32)          # (d_in, d_out)
        if w.ndim != 2:
            raise ValueError(f"expected a 2-D weight, got {w.shape}")
        self.d_in, self.d_out = w.shape
        self.bits = bits
        # bits < 8: weights quantize to the b-bit range and the program's
        # hardware template stores them b-bit packed in DRAM (the staged
        # constant shrinks 8/bits-fold; decode-shaped calls route through
        # the LUT-GEMM kernel on the Pallas backend)
        base = spec or _hwspec.pynq()
        self.spec = _hwspec.lowbit(bits, base) if bits < 8 else base
        self.backend = backend
        self.virtual_threads = virtual_threads
        self.qw = q.calibrate(w, bits=bits)
        self.w_q = q.quantize(w, self.qw).T.copy()   # (N=d_out, K=d_in)
        self._w_float = w
        self._qy: Optional[q.QuantParams] = None
        self._programs: Dict[Tuple[int, int], CompiledProgram] = {}

    @classmethod
    def from_params(cls, p: Params, **kw) -> "VtaLinear":
        """Build from PTQ params {w_q: (d_in, d_out) int8, w_scale: (d_out,)}
        — the per-channel PTQ weights are reconstructed and re-quantized
        per-tensor for the integer-only shift epilogue."""
        w = (np.asarray(p["w_q"], np.float32)
             * np.asarray(p["w_scale"], np.float32)[None, :])
        return cls(w, **kw)

    # ------------------------------------------------------------------
    def _program(self, m: int, shift: int) -> CompiledProgram:
        key = (m, shift)
        if key not in self._programs:
            prog = Program(self.spec, virtual_threads=self.virtual_threads)
            x = prog.input("x", (m, self.d_in))
            # weights are a graph constant: packed + staged into DRAM once
            # at compile time, so serving calls only rebind activations
            w = prog.constant("w", self.w_q)
            prog.matmul(x, w, epilogue=Epilogue(shift=shift), name="y")
            self._programs[key] = prog.compile()
        return self._programs[key]

    def __call__(self, x: np.ndarray, backend: Any = None) -> np.ndarray:
        x = np.asarray(x, np.float32)
        lead, d_in = x.shape[:-1], x.shape[-1]
        if d_in != self.d_in:
            raise ValueError(f"expected (..., {self.d_in}), got {x.shape}")
        x2 = x.reshape(-1, d_in)
        qx = q.calibrate(x2)
        if self._qy is None:
            # one-time output calibration from the float product
            self._qy = q.calibrate(x2 @ self._w_float)
        shift = q.choose_requant_shift(qx.scale, self.qw.scale,
                                       self._qy.scale)
        compiled = self._program(x2.shape[0], shift)
        y_q = compiled(backend=backend if backend is not None
                       else self.backend,
                       x=q.quantize(x2, qx))
        # exact dequant of the power-of-two requant:
        # acc * sx*sw ~= y, y_q = clip(acc >> shift)
        y = y_q.astype(np.float32) * (qx.scale * self.qw.scale * 2.0 ** shift)
        return y.reshape(*lead, self.d_out).astype(np.float32)


def vta_linear_from_params(p: Params, **kw) -> VtaLinear:
    """Route one PTQ'd linear layer ({w_q, w_scale}, as produced by
    quantize_params) through the program-level JIT."""
    return VtaLinear.from_params(p, **kw)
