"""Mamba2 (state-space duality) blocks + shared chunked GLA core.

The SSD recurrence  h_t = a_t h_{t-1} + k_t v_t^T,  y_t = q_t . h_t
(with per-head scalar decay a_t) covers both Mamba2 (q=C, k=B, v=dt*x,
a=exp(dt*A)) and mLSTM (q/k/v projections, a=sigmoid forget gate) — one
chunked implementation serves both (`chunked_gla`).

Chunked algorithm (sub-quadratic, the reason long_500k is runnable for
SSM/hybrid archs): quadratic attention *within* a chunk of Q tokens,
associative scan of (decay, state) *across* chunks, O(S*Q + S*N*P/Q).

Decode is O(1)/token: one state update per step (`gla_step`), which is why
SSM/hybrid decode cells scale to 524k contexts.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear_apply, linear_init, norm_apply, norm_init

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# chunked gated linear attention (shared by mamba2 and mLSTM)
# ----------------------------------------------------------------------
def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                log_a: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """q,k: (B, S, H, N); v: (B, S, H, P); log_a: (B, S, H) (<= 0 decay).
    Returns y: (B, S, H, P) and final state h: (B, H, N, P).

    y_t = q_t . (sum_{s<=t} exp(L_t - L_s) k_s v_s^T + exp(L_t) h0)
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    qf = q.astype(jnp.float32).reshape(B, nc, Q, H, N)
    kf = k.astype(jnp.float32).reshape(B, nc, Q, H, N)
    vf = v.astype(jnp.float32).reshape(B, nc, Q, H, P)
    la = log_a.astype(jnp.float32).reshape(B, nc, Q, H)

    L = jnp.cumsum(la, axis=2)                      # (B,nc,Q,H) within-chunk
    Ltot = L[:, :, -1, :]                           # (B,nc,H)

    # ---- intra-chunk (quadratic within Q) ----
    scores = jnp.einsum("bcqhn,bckhn->bchqk", qf, kf)
    decay = L[:, :, :, None, :].transpose(0, 1, 4, 2, 3) \
        - L[:, :, None, :, :].transpose(0, 1, 4, 2, 3)   # (B,nc,H,Q,K) = L_t - L_s
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: above-diagonal L_t - L_s > 0 would overflow and
    # poison gradients through the masked branch
    w = jnp.exp(jnp.where(causal[None, None, None], decay, -jnp.inf))
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores * w, vf)

    # ---- per-chunk state contribution ----
    # state_c = sum_s exp(Ltot - L_s) k_s v_s^T
    ks = kf * jnp.exp(Ltot[:, :, None, :] - L)[..., None]
    state_c = jnp.einsum("bcqhn,bcqhp->bchnp", ks, vf)   # (B,nc,H,N,P)

    # ---- sequential scan across chunks: h_c = d_c * h_{c-1} + s_c ----
    # (lax.scan, not associative_scan: the log-tree materializes ~2x the
    # per-chunk states, which dominates memory for matrix-memory heads)
    d5 = jnp.exp(Ltot)[..., None, None]                  # (B,nc,H,1,1)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, xs):
        d_c, s_c = xs                                    # (B,H,1,1),(B,H,N,P)
        h_next = h * d_c + s_c
        return h_next, h                                 # emit state *before*

    h_final, h_in = jax.lax.scan(
        step, h0, (d5.swapaxes(0, 1), state_c.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                           # (B,nc,H,N,P)

    # ---- inter-chunk: y += (q_t exp(L_t)) . h_in ----
    qd = qf * jnp.exp(L)[..., None]
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", qd, h_in)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_final


def gla_step(h: jax.Array, q: jax.Array, k: jax.Array, v: jax.Array,
             a: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  h: (B,H,N,P); q,k: (B,H,N); v: (B,H,P); a: (B,H)."""
    h = h * a[..., None, None] + jnp.einsum("bhn,bhp->bhnp",
                                            k.astype(jnp.float32),
                                            v.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), h)
    return h, y


# ----------------------------------------------------------------------
# Mamba2 block
# ----------------------------------------------------------------------
def mamba2_init(key, cfg) -> Params:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H     # z, x, B, C, dt
    conv_ch = di + 2 * N
    p = {
        "in_proj": linear_init(ks[0], d, d_in_proj, dt),
        "out_proj": linear_init(ks[1], di, d, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, conv_ch))
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": norm_init(cfg, di),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (W, C).  Returns
    (y, new_state) where state is the last W-1 inputs."""
    B, S, C = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+W-1, C)
    y = sum(xp[:, i:i + S, :] * w[i][None, None] for i in range(W)) + b
    new_state = xp[:, S:, :] if W > 1 else state
    return y, new_state


def _ssm_inner(cfg, p, zxbcdt: jax.Array, conv_state, ssm_state,
               chunked: bool):
    """Shared post-in_proj computation for train/prefill (chunked) and
    decode (single step)."""
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_head_dim
    B_, S, _ = zxbcdt.shape
    z = zxbcdt[..., :di]                       # gate branch
    xBC = zxbcdt[..., di:2 * di + 2 * N]       # conv channels (x, B, C)
    dt_raw = zxbcdt[..., 2 * di + 2 * N:]      # per-head dt logits (H)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :di].reshape(B_, S, H, Pd)
    Bmat = xBC[..., di:di + N]
    Cmat = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    log_a = dt * A[None, None, :]
    v = x.astype(jnp.float32) * dt[..., None]
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B_, S, H, N))
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B_, S, H, N))
    if chunked:
        # chunk ~ state dim N: larger chunks make the intra-chunk
        # quadratic dominate FLOPs; smaller waste the scan
        y, ssm_state = chunked_gla(q, k, v, log_a, chunk=max(32, N),
                                   h0=ssm_state)
    else:
        a = jnp.exp(log_a[:, 0])                                      # (B,H)
        ssm_state, y = gla_step(ssm_state, q[:, 0], k[:, 0], v[:, 0], a)
        y = y[:, None]
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, S, di).astype(z.dtype)
    y = norm_apply(cfg, p["norm"], y * jax.nn.silu(z))
    return y, conv_state, ssm_state


def mamba2_train(p: Params, cfg, x: jax.Array) -> jax.Array:
    zxbcdt = linear_apply(p["in_proj"], x, cfg)
    y, _, _ = _ssm_inner(cfg, p, zxbcdt, None, None, chunked=True)
    return linear_apply(p["out_proj"], y, cfg)


def init_ssm_cache(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    di, N = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, N, cfg.ssm_head_dim),
                         jnp.float32),
    }


def mamba2_prefill(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array]
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    zxbcdt = linear_apply(p["in_proj"], x, cfg)
    y, conv_state, ssm_state = _ssm_inner(
        cfg, p, zxbcdt, cache["conv"], cache["ssm"], chunked=True)
    return (linear_apply(p["out_proj"], y, cfg),
            {"conv": conv_state, "ssm": ssm_state})


def mamba2_decode(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d) — O(1) state update."""
    zxbcdt = linear_apply(p["in_proj"], x, cfg)
    y, conv_state, ssm_state = _ssm_inner(
        cfg, p, zxbcdt, cache["conv"], cache["ssm"], chunked=False)
    return (linear_apply(p["out_proj"], y, cfg),
            {"conv": conv_state, "ssm": ssm_state})
