"""Quantized autoregressive decoder served through the compiled stack.

The paper's end-to-end claim (§5: compile real models onto the template,
split work between CPU and accelerator) applied to the repo's most real
workload — autoregressive transformer decode:

  * every linear (QKV / attention-out / MLP up / MLP down / LM head) is
    an int8 accelerator matmul with the shift-clip epilogue, its weights
    staged once as ``Program.constant``;
  * attention is a host segment over the GQA decode kernel
    (``kernels/decode_attention``) or a pure-numpy equivalent — the
    paper's C1 heterogeneous split;
  * the KV cache and the position counter live in **persistent** DRAM
    buffers (``Program.persistent``): appended in place each step by the
    attention host op, at stable addresses, with zero per-step DRAM
    allocation.

One compiled program = one decode STEP; calling it N times decodes N
tokens.  Serving goes through ``serve.DevicePool``: every pool session
is one independent dialogue (its own KV bytes), and same-step sessions
gang their accelerator segments across slots.

Everything is deterministic integer/float32 math, and the eager
:class:`DecoderReference` shares the exact host fns and the
``matmul_reference`` integer oracle with the compiled path — compiled
decode is bit-exact against it on BOTH engines (tested in
``tests/test_persistent.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import hwspec as _hwspec
from repro.core.program import CompiledProgram, Program
from repro.core.scheduler import Epilogue, matmul_reference

# fixed-point convention for the attention host segment: int8 activations
# carry a 1/16 scale, attention runs in float32, the output requantizes
# back to int8 with the same scale.  Arbitrary but fixed — both the
# compiled path and the eager reference evaluate the SAME function.
_ATTN_SCALE = 16.0


@dataclass(frozen=True)
class DecoderConfig:
    d_model: int = 64
    n_blocks: int = 2
    n_heads: int = 2          # KV heads == query heads (MHA decode)
    d_ff: int = 128
    vocab: int = 32
    s_max: int = 96           # KV-cache capacity (max decode steps)
    shift: int = 7            # requant shift of every accelerator matmul
    seed: int = 0
    attention: str = "numpy"  # "numpy" | "kernel" (decode_attention)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def _attention_core(cfg: DecoderConfig, q: np.ndarray, K: np.ndarray,
                    V: np.ndarray, kv_len: int) -> np.ndarray:
    """(d,) int8 query against the first kv_len rows of the (S, d) int8
    caches -> (d,) int8 attention output.  Mode "kernel" routes through
    the decode_attention op (B=1 GQA decode over the padded cache); mode
    "numpy" is the dependency-free equivalent.  Both are deterministic."""
    H, D = cfg.n_heads, cfg.head_dim
    if cfg.attention == "kernel":
        import jax.numpy as jnp

        from repro.kernels.decode_attention.ops import decode_attention
        qf = jnp.asarray(q, jnp.float32).reshape(1, 1, H, D) / _ATTN_SCALE
        kf = jnp.asarray(K, jnp.float32).reshape(1, cfg.s_max, H, D) \
            / _ATTN_SCALE
        vf = jnp.asarray(V, jnp.float32).reshape(1, cfg.s_max, H, D) \
            / _ATTN_SCALE
        out = decode_attention(qf, kf, vf, jnp.int32(kv_len),
                               use_pallas=True, interpret=True)
        of = np.asarray(out, np.float32).reshape(cfg.d_model)
    else:
        qf = (q.astype(np.float32) / _ATTN_SCALE).reshape(H, D)
        kf = (K[:kv_len].astype(np.float32) / _ATTN_SCALE) \
            .reshape(kv_len, H, D)
        vf = (V[:kv_len].astype(np.float32) / _ATTN_SCALE) \
            .reshape(kv_len, H, D)
        # scores: (H, kv_len) — identical scaling to the kernel path
        s = np.einsum("hd,khd->hk", qf, kf) / np.float32(np.sqrt(D))
        s = s - s.max(axis=1, keepdims=True)
        p = np.exp(s, dtype=np.float32)
        p = p / p.sum(axis=1, keepdims=True)
        of = np.einsum("hk,khd->hd", p, vf).reshape(cfg.d_model)
    return np.clip(np.rint(of * _ATTN_SCALE), -128, 127).astype(np.int8)


def _attn_step(cfg: DecoderConfig, qkv: np.ndarray, K: np.ndarray,
               V: np.ndarray, pos: np.ndarray):
    """The attention host op: append this step's k/v into the persistent
    caches at `pos`, attend over the pos+1 live rows, advance pos.
    Returns (attn_out, K', V', pos') — the trailing three are written
    back into the persistent buffers in place (``host(updates=...)``)."""
    d = cfg.d_model
    row = qkv.reshape(3 * d)
    q, k, v = row[:d], row[d:2 * d], row[2 * d:]
    p = int(pos[0])
    if p >= cfg.s_max:
        raise RuntimeError(f"KV cache overflow: step {p} >= s_max "
                           f"{cfg.s_max}")
    K = K.copy()
    V = V.copy()
    K[p] = k
    V[p] = v
    a = _attention_core(cfg, q, K, V, p + 1)
    return (a.reshape(1, d), K, V,
            np.array([p + 1], np.int32))


def _residual(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """int8 residual add with the same saturation the tensor ALU uses."""
    return np.clip(a.astype(np.int32) + b.astype(np.int32),
                   -128, 127).astype(np.int8)


def _make_weights(cfg: DecoderConfig) -> List[Dict[str, np.ndarray]]:
    """Small random int8 weights per block (+ the LM head on the last
    entry).  Deterministic in cfg.seed."""
    rng = np.random.default_rng(cfg.seed)
    d, f = cfg.d_model, cfg.d_ff

    def w(nout, nin):
        return rng.integers(-8, 8, size=(nout, nin), dtype=np.int8)

    blocks = [dict(wqkv=w(3 * d, d), wo=w(d, d),
                   w1=w(f, d), w2=w(d, f))
              for _ in range(cfg.n_blocks)]
    blocks[-1]["head"] = w(cfg.vocab, d)
    return blocks


class QuantDecoder:
    """A 2-block (configurable) quantized decoder whose per-step graph
    compiles once into task-ISA streams + host attention segments, with
    the KV caches in persistent DRAM.

        dec = QuantDecoder()
        c = dec.compile()
        for t in range(64):
            logits = c(x=dec.token(t))        # state advances in DRAM

    Pool serving: ``DevicePool(dec.compile(), size=4)`` then one
    ``pool.session()`` per concurrent dialogue."""

    def __init__(self, cfg: Optional[DecoderConfig] = None, spec=None,
                 **cfg_kw):
        self.cfg = cfg or DecoderConfig(**cfg_kw)
        if self.cfg.d_model % self.cfg.n_heads:
            raise ValueError("d_model must divide into n_heads")
        self.spec = spec or _hwspec.pynq()
        self.weights = _make_weights(self.cfg)

    # ------------------------------------------------------------------
    def token(self, t: int) -> np.ndarray:
        """Deterministic pseudo-token embedding for step t (teacher-forced
        driver for tests/benchmarks)."""
        rng = np.random.default_rng(self.cfg.seed * 7919 + t)
        return rng.integers(-32, 32, size=(1, self.cfg.d_model),
                            dtype=np.int8)

    def build_program(self) -> Program:
        cfg = self.cfg
        d = cfg.d_model
        ep = Epilogue(shift=cfg.shift)
        p = Program(self.spec)
        x = p.input("x", (1, d))
        for b, wts in enumerate(self.weights):
            wqkv = p.constant(f"wqkv{b}", wts["wqkv"])
            wo = p.constant(f"wo{b}", wts["wo"])
            w1 = p.constant(f"w1_{b}", wts["w1"])
            w2 = p.constant(f"w2_{b}", wts["w2"])
            K = p.persistent(f"k{b}", (cfg.s_max, d))
            V = p.persistent(f"v{b}", (cfg.s_max, d))
            pos = p.persistent(f"pos{b}", (1,), dtype="int32")
            qkv = p.matmul(x, wqkv, epilogue=ep, name=f"qkv{b}")
            a = p.host(
                lambda qkvv, Kv, Vv, posv, _c=cfg: _attn_step(
                    _c, qkvv, Kv, Vv, posv),
                qkv, K, V, pos, shape=(1, d), kind="mat",
                key=f"qdec.attn.{b}.{cfg.attention}.{cfg.s_max}."
                    f"{cfg.n_heads}",
                updates=(K, V, pos), name=f"attn{b}")
            ao = p.matmul(a, wo, epilogue=ep, name=f"aout{b}")
            h = p.host(_residual, x, ao, shape=(1, d), kind="mat",
                       key="qdec.residual", name=f"res_a{b}")
            m1 = p.matmul(h, w1, epilogue=Epilogue(shift=cfg.shift,
                                                   relu=True),
                          name=f"mlp_up{b}")
            m2 = p.matmul(m1, w2, epilogue=ep, name=f"mlp_dn{b}")
            x = p.host(_residual, h, m2, shape=(1, d), kind="mat",
                       key="qdec.residual", name=f"res_m{b}")
        logits = p.matmul(x, p.constant("whead",
                                        self.weights[-1]["head"]),
                          epilogue=ep, name="logits")
        p.output(logits)
        return p

    def compile(self, device=None, **kw) -> CompiledProgram:
        """Compile the decoder graph.  `device` co-stages this decoder
        onto an existing staged image (disjoint DRAM range — see
        ``program.compile_multi``) so one pool slot can serve a
        heterogeneous model mix alongside other programs."""
        return self.build_program().compile(device=device, **kw)

    def reference(self) -> "DecoderReference":
        return DecoderReference(self)


@dataclass
class DecoderReference:
    """Eager stateful numpy oracle: the SAME host fns and the
    matmul_reference integer semantics, KV caches as plain arrays.  One
    instance = one session."""
    dec: QuantDecoder
    K: List[np.ndarray] = field(default_factory=list)
    V: List[np.ndarray] = field(default_factory=list)
    pos: List[np.ndarray] = field(default_factory=list)

    def __post_init__(self):
        cfg = self.dec.cfg
        for _ in range(cfg.n_blocks):
            self.K.append(np.zeros((cfg.s_max, cfg.d_model), np.int8))
            self.V.append(np.zeros((cfg.s_max, cfg.d_model), np.int8))
            self.pos.append(np.zeros(1, np.int32))

    def step(self, x: np.ndarray) -> np.ndarray:
        cfg = self.dec.cfg
        ep = Epilogue(shift=cfg.shift)
        x = np.asarray(x, np.int8).reshape(1, cfg.d_model)
        for b, wts in enumerate(self.dec.weights):
            qkv = matmul_reference(x, wts["wqkv"], ep)
            a, self.K[b], self.V[b], self.pos[b] = _attn_step(
                cfg, qkv, self.K[b], self.V[b], self.pos[b])
            ao = matmul_reference(a, wts["wo"], ep)
            h = _residual(x, ao)
            m1 = matmul_reference(h, wts["w1"],
                                  Epilogue(shift=cfg.shift, relu=True))
            m2 = matmul_reference(m1, wts["w2"], ep)
            x = _residual(h, m2)
        return matmul_reference(x, self.dec.weights[-1]["head"], ep)
