"""repro: VTA (Versatile Tensor Accelerator) hardware-software stack in JAX.

Layers: core (VTA template/ISA/runtime/simulator/compiler), kernels
(Pallas TPU realizations), models (assigned LM architectures), distributed
substrate (mesh/sharding/checkpoint/fault-tolerance), launch (dry-run,
train, serve).
"""
__version__ = "1.0.0"
