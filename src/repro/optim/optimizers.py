"""Optimizers as pure pytree transforms (no external deps).

AdamW for everything that fits; Adafactor (factored second moment, no
first moment) for the 1T-param kimi-k2 config, where AdamW fp32 states
(~12 TB) exceed the 512-chip pod's 8 TB HBM.  Optimizer state mirrors the
parameter pytree, so the same PartitionSpecs shard it (ZeRO-style when
FSDP is on).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


# ----------------------------------------------------------------------
# grad clipping
# ----------------------------------------------------------------------
def global_norm(tree: Params) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------
def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Params, state: Dict[str, Any], params: Params, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1
                 ) -> Tuple[Params, Dict[str, Any]]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": m, "v": v, "count": count}


# ----------------------------------------------------------------------
# Adafactor (factored second moment; memory ~ O(rows + cols))
# ----------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Params) -> Dict[str, Any]:
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: Params, state: Dict[str, Any], params: Params, *,
                     lr: jax.Array, decay: float = 0.99, eps: float = 1e-30,
                     clip_threshold: float = 1.0, weight_decay: float = 0.0
                     ) -> Tuple[Params, Dict[str, Any]]:
    count = state["count"] + 1

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(g.shape):
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                     ) * vc[..., None, :]
            update = g * jax.lax.rsqrt(denom + eps)
            new_v = {"vr": vr, "vc": vc}
        else:
            nv = decay * v["v"] + (1 - decay) * g2
            update = g * jax.lax.rsqrt(nv + eps)
            new_v = {"v": nv}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + eps)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return new_v, (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    flat = jax.tree.map(upd, grads, state["v"], params,
                        is_leaf=lambda x: isinstance(x, dict)
                        and set(x) <= {"vr", "vc", "v"})
    new_v = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_p = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"v": new_v, "count": count}


# ----------------------------------------------------------------------
# factory
# ----------------------------------------------------------------------
def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
