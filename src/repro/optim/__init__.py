from .optimizers import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, make_optimizer)
from .schedules import cosine_schedule, linear_warmup
