"""Deterministic synthetic LM data pipeline.

Host-side, per-process sharded token stream with a seedable generator —
the data-parallel analogue of VTA's "runtime prepares DRAM buffers"
contract.  Determinism is keyed on (seed, step, shard), so elastic
restarts resume the exact stream from a checkpointed step without
replaying the history (a requirement for fault-tolerant training).

The synthetic distribution is a mixture of Zipfian unigrams and short
repeated motifs, giving a learnable signal (loss drops well below
ln(vocab)) while needing no external corpus.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1       # data-parallel host shards
    shard_id: int = 0
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLMDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipfian unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # fixed motif table: short phrases the model can memorize
        self.motifs = root.integers(0, v, size=(cfg.n_motifs, cfg.motif_len))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % cfg.n_shards == 0
        local = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard_id))  # deterministic per (step, shard)
        toks = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len + 1),
                          p=self.unigram)
        # plant motifs: ~50% of positions covered by repeated phrases
        n_plant = (cfg.seq_len // cfg.motif_len) // 2
        for b in range(local):
            ids = rng.integers(0, cfg.n_motifs, size=n_plant)
            starts = rng.choice(cfg.seq_len - cfg.motif_len, size=n_plant,
                                replace=False)
            for m, s in zip(ids, starts):
                toks[b, s:s + cfg.motif_len] = self.motifs[m]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


def make_train_iterator(cfg: DataConfig, start_step: int = 0
                        ) -> Iterator[Dict[str, np.ndarray]]:
    ds = SyntheticLMDataset(cfg)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
