from .pipeline import DataConfig, SyntheticLMDataset, make_train_iterator
