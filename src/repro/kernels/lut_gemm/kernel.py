"""T-MAC-style LUT-GEMM for sub-byte weights (Pallas).

For memory-bound decode matmuls (few activation rows, large sub-byte
weight matrix) the MXU is idle waiting on weight bytes; the T-MAC trick
replaces the multiply array with table lookups over precomputed partial
sums of the activations:

  * Split the reduction axis K into G = K/g groups of g lanes.
  * Per activation row m and group, precompute the table of all 2^g
    subset sums  T[m, grp, p] = sum_{j: bit j of p} a[m, grp*g + j]
    — one small (g x 2^g) integer matmul against the bit-pattern matrix.
  * Decompose each b-bit two's-complement weight into its bit planes:
    w = sum_{t<b-1} 2^t * bit_t - 2^(b-1) * bit_{b-1}.  Per plane and
    group, the g weight bits along the reduction lanes form a g-bit
    table index  idx_t[grp, n].
  * The GEMM becomes gathers + adds:
      acc[m, n] = sum_t coef_t * sum_grp T[m, grp, idx_t[grp, n]]

All arithmetic is exact int32, so the result is BIT-IDENTICAL to the
dense int8 GEMM over the sign-extended weights — the property the
cross-backend fuzzer locks in.  The fused requant epilogue reproduces
``vta_gemm``'s exactly (truncating arithmetic shift, clip, int8).

The gathers use ``jnp.take_along_axis``; on interpret mode (CPU, the
validation target) this lowers directly.  Native-TPU Mosaic restricts
dynamic gathers — a one-hot-contraction fallback is the known rewrite if
a native pass lands (see ROADMAP "native-TPU pass").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _lut_kernel(a_ref, w_ref, o_ref, *, bits: int, group: int,
                epilogue: str, shift: int):
    a = a_ref[...].astype(jnp.int32)          # (M, K)
    w = w_ref[...].astype(jnp.int32)          # (K, bn)
    M, K = a.shape
    N = w.shape[1]
    G = K // group
    P = 1 << group

    # activation table: one (g x 2^g) subset-sum matmul per row/group
    pats = jnp.arange(P, dtype=jnp.int32)
    bitsel = ((pats[:, None] >> jnp.arange(group)[None, :]) & 1)  # (P, g)
    ag = a.reshape(M, G, group)
    table = jax.lax.dot_general(
        ag, bitsel.astype(jnp.int32).T,
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.int32)  # (M,G,P)

    # weight bit planes -> g-bit table indices per (plane, group, n)
    wu = (w & ((1 << bits) - 1)).reshape(G, group, N)
    lane_w = (jnp.int32(1) << jnp.arange(group, dtype=jnp.int32))
    acc = jnp.zeros((M, N), jnp.int32)
    for t in range(bits):
        bit = (wu >> t) & 1
        idx = jnp.sum(bit * lane_w[None, :, None], axis=1)           # (G, N)
        picked = jnp.take_along_axis(
            table, jnp.broadcast_to(idx[None], (M, G, N)), axis=2)
        coef = -(1 << t) if t == bits - 1 else (1 << t)   # MSB = sign plane
        acc = acc + jnp.int32(coef) * jnp.sum(picked, axis=1)

    if epilogue == "none":
        o_ref[...] = acc
    elif epilogue == "requant":
        q = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
        o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
    else:
        raise ValueError(epilogue)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group", "epilogue", "shift", "bn", "interpret"))
def lut_gemm_pallas(a: jax.Array, w: jax.Array, *, bits: int,
                    group: int = 4, epilogue: str = "none", shift: int = 0,
                    bn: int = 128, interpret: bool = True) -> jax.Array:
    """C[M,N] = epilogue(A[M,K](int8) @ W[K,N](int{bits})) via table lookup.

    Same operand/epilogue contract as ``vta_gemm_pallas`` (so the backend
    can swap it in per shape), minus bias/dequant which the decode path
    never fuses.  `w` values must lie in the b-bit two's-complement range
    (they are the sign-extended int8 the WGT SRAM holds); K must be a
    multiple of `group`, N of `bn`.
    """
    if bits not in (1, 2, 4):
        raise ValueError(f"lut_gemm: bits must be 1, 2 or 4, got {bits}")
    M, K = a.shape
    K2, N = w.shape
    assert K == K2, (a.shape, w.shape)
    assert K % group == 0, f"pad K to a multiple of group: {K} vs {group}"
    assert N % bn == 0, f"pad N to a multiple of bn: {N} vs {bn}"
    out_dtype = {"none": jnp.int32, "requant": jnp.int8}[epilogue]

    return pl.pallas_call(
        functools.partial(_lut_kernel, bits=bits, group=group,
                          epilogue=epilogue, shift=shift),
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((M, K), lambda j: (0, 0)),   # activations (small M)
            pl.BlockSpec((K, bn), lambda j: (0, j)),  # weight column block
        ],
        out_specs=pl.BlockSpec((M, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a, w)
