"""Public op: sub-byte weight GEMM by activation-table lookup (T-MAC).

Dispatches to the Pallas kernel or the jnp oracle; both share exact
integer semantics.  Pads K to a group multiple and N to the column block
(zero weight values contribute nothing on any bit plane, zero activation
lanes add nothing to any subset sum — padding is exact).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .._compat import resolve_interpret
from .kernel import lut_gemm_pallas
from .ref import lut_gemm_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def lut_gemm(a: jax.Array, w: jax.Array, *, bits: int, group: int = 4,
             epilogue: str = "none", shift: int = 0,
             use_pallas: bool = False, interpret: Optional[bool] = None,
             bn: int = 128) -> jax.Array:
    """int8 x int{bits} -> int32 GEMM (optionally fused requant -> int8).

    a: (M, K) int8;  w: (K, N) int8 holding sign-extended b-bit values.
    Bit-identical to ``vta_gemm(a, w, ...)`` — the dense path is the
    differential reference.
    """
    M, K = a.shape
    _, N = w.shape
    if not use_pallas:
        return lut_gemm_ref(a, w, epilogue=epilogue, shift=shift)
    ap = _pad_to(a, 1, group)
    wp = _pad_to(_pad_to(w, 0, group), 1, bn)
    out = lut_gemm_pallas(ap, wp, bits=bits, group=group,
                          epilogue=epilogue, shift=shift, bn=bn,
                          interpret=resolve_interpret(interpret))
    return out[:M, :N]
