"""Pure-jnp oracle for the LUT GEMM kernel.

The LUT decomposition is algebraically the plain integer GEMM over the
sign-extended weights, so the oracle IS the dense dot with the identical
epilogue — any divergence from the table path is a kernel bug.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_gemm_ref(a: jax.Array, w: jax.Array, *, epilogue: str = "none",
                 shift: int = 0) -> jax.Array:
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    if epilogue == "none":
        return acc
    if epilogue == "requant":
        q = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    raise ValueError(epilogue)
