from .kernel import lut_gemm_pallas  # noqa: F401
from .ops import lut_gemm  # noqa: F401
from .ref import lut_gemm_ref  # noqa: F401
