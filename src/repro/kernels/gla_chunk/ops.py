"""Public op: GLA chunk scan over (B, S, H, ...) tensors."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import gla_chunk_pallas
from .ref import gla_chunk_ref


def gla_chunk(q: jax.Array, k: jax.Array, v: jax.Array, la: jax.Array,
              h0: Optional[jax.Array] = None, *, chunk: int = 64,
              use_pallas: bool = False, interpret: bool = True
              ) -> Tuple[jax.Array, jax.Array]:
    """q,k: (B, S, H, N); v: (B, S, H, P); la: (B, S, H) log-decay;
    h0: (B, H, N, P) or None.  Returns (y (B,S,H,P), h (B,H,N,P))."""
    B, S, H, N = q.shape
    P_ = v.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    def to_bh(x, feat):
        return (x.transpose(0, 2, 1, 3)
                .reshape(B * H, nc, Q, feat))

    qb, kb = to_bh(q, N), to_bh(k, N)
    vb = to_bh(v, P_)
    lab = la.transpose(0, 2, 1).reshape(B * H, nc, Q)
    h0b = (jnp.zeros((B * H, N, P_), jnp.float32) if h0 is None
           else h0.reshape(B * H, N, P_).astype(jnp.float32))
    fn = gla_chunk_pallas if use_pallas else gla_chunk_ref
    kw = {"interpret": interpret} if use_pallas else {}
    yb, hb = fn(qb, kb, vb, lab, h0b, **kw)
    y = yb.reshape(B, H, S, P_).transpose(0, 2, 1, 3)
    return y, hb.reshape(B, H, N, P_)
