"""Chunked gated-linear-attention scan (Mamba2 SSD / mLSTM), Pallas TPU.

The cross-chunk recurrence h_c = d_c * h_{c-1} + state_c is inherently
sequential — exactly the situation VTA's decoupled access-execute targets:
while the MXU computes chunk c (intra-chunk quadratic + state update),
the grid pipeline DMAs chunk c+1's q/k/v blocks from HBM.  The recurrent
state h lives in VMEM scratch across grid steps (the "register file"),
so the sequential dependency never round-trips HBM.

Grid: (B*H, n_chunks); chunk dim is "arbitrary" (ordered), batch*heads
parallel.  Per-step working set (Q=64, N=64, P=64, f32):
q/k (Q,N) + v/y (Q,P) + scores (Q,Q) + h (N,P) ~= 80 KiB « VMEM.

Math per chunk (L = within-chunk cumsum of log-decay):
    y = (q·kᵀ ⊙ exp(L_i − L_j) ⊙ causal) v  +  (q ⊙ exp(L)) h
    h = exp(L_tot) h + (k ⊙ exp(L_tot − L))ᵀ v
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _gla_kernel(q_ref, k_ref, v_ref, la_ref, h0_ref, y_ref, hout_ref,
                h_ref, *, nc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32)       # (Q, N)
    k = k_ref[0, 0].astype(jnp.float32)       # (Q, N)
    v = v_ref[0, 0].astype(jnp.float32)       # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)     # (Q,)
    L = jnp.cumsum(la)                        # (Q,)
    Ltot = L[-1]

    # intra-chunk: causal decay-weighted attention
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    Q = s.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, L[:, None] - L[None, :], -jnp.inf)
    y = jax.lax.dot_general(s * jnp.exp(decay), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    h = h_ref[...]
    y = y + jax.lax.dot_general(q * jnp.exp(L)[:, None], h,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update
    ks = k * jnp.exp(Ltot - L)[:, None]
    h_ref[...] = h * jnp.exp(Ltot) + jax.lax.dot_general(
        ks, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(c == nc - 1)
    def _finish():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gla_chunk_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                     la: jax.Array, h0: jax.Array, *,
                     interpret: bool = True):
    """q,k: (BH, nc, Q, N); v: (BH, nc, Q, P); la: (BH, nc, Q);
    h0: (BH, N, P) f32.  Returns (y: (BH, nc, Q, P), h: (BH, N, P))."""
    BH, nc, Q, N = q.shape
    P_ = v.shape[-1]
    return pl.pallas_call(
        functools.partial(_gla_kernel, nc=nc),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, P_), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, P_), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P_), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, N, P_), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P_), q.dtype),
            jax.ShapeDtypeStruct((BH, N, P_), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P_), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, la, h0)
