"""Oracle: the model-layer chunked_gla (itself validated against the
step-by-step recurrence) reshaped to the kernel's (BH, nc, Q, ...) layout."""
from __future__ import annotations

import jax

from repro.models.ssm import chunked_gla


def gla_chunk_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  la: jax.Array, h0: jax.Array):
    """Same signature as gla_chunk_pallas."""
    BH, nc, Q, N = q.shape
    P_ = v.shape[-1]
    S = nc * Q
    # (BH, nc, Q, X) -> (BH, S, 1, X): treat BH as batch, single head
    qs = q.reshape(BH, S, 1, N)
    ks = k.reshape(BH, S, 1, N)
    vs = v.reshape(BH, S, 1, P_)
    las = la.reshape(BH, S, 1)
    y, h = chunked_gla(qs, ks, vs, las, chunk=Q, h0=h0[:, None])
    return y.reshape(BH, nc, Q, P_).astype(q.dtype), h[:, 0]
