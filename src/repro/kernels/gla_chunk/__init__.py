from .kernel import gla_chunk_pallas  # noqa: F401
from .ops import gla_chunk  # noqa: F401
from .ref import gla_chunk_ref  # noqa: F401
