"""Small jax-version shims shared by the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` after
the 0.4.x series; the kernels support both so the repo runs on the
container's pinned jax as well as current releases.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """interpret=None -> auto: run Mosaic-native on TPU, fall back to the
    Pallas interpreter everywhere else (CPU CI, the cross-backend tests)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
