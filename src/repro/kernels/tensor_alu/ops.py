"""Public op wrapper for the VTA tensor ALU."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .._compat import resolve_interpret
from .kernel import tensor_alu_pallas
from .ref import tensor_alu_ref

_LANES = 128  # VPU lane width: last dim of a native tile


def tensor_alu(dst: jax.Array, src: Optional[jax.Array] = None,
               *, chain: Tuple[Tuple[str, Optional[int]], ...],
               use_pallas: bool = False,
               interpret: Optional[bool] = None,
               bm: int = 256) -> jax.Array:
    if not use_pallas:
        return tensor_alu_ref(dst, src, chain=chain)
    # The kernel wants rows in bm-sized blocks and lane-aligned columns;
    # callers (e.g. the execution backend's tile epilogues) hand it
    # arbitrary tile shapes, so pad here and slice the result back.
    M, N = dst.shape
    bm_eff = min(bm, M)
    pad_m = (-M) % bm_eff
    pad_n = (-N) % _LANES
    if pad_m or pad_n:
        widths = ((0, pad_m), (0, pad_n))
        dst = jnp.pad(dst, widths)
        if src is not None:
            src = jnp.pad(src, widths)
    out = tensor_alu_pallas(dst, src, chain=chain, bm=bm,
                            interpret=resolve_interpret(interpret))
    if pad_m or pad_n:
        out = out[:M, :N]
    return out


def requantize(acc: jax.Array, shift: int, lo: int = -128,
               hi: int = 127, **kw) -> jax.Array:
    """The canonical VTA epilogue: SHR then clip (MIN/MAX pair)."""
    return tensor_alu(acc, chain=(("shr", shift), ("max", lo), ("min", hi)),
                      **kw)
