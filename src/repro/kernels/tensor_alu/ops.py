"""Public op wrapper for the VTA tensor ALU."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import tensor_alu_pallas
from .ref import tensor_alu_ref


def tensor_alu(dst: jax.Array, src: Optional[jax.Array] = None,
               *, chain: Tuple[Tuple[str, Optional[int]], ...],
               use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    if not use_pallas:
        return tensor_alu_ref(dst, src, chain=chain)
    return tensor_alu_pallas(dst, src, chain=chain, interpret=interpret)


def requantize(acc: jax.Array, shift: int, lo: int = -128,
               hi: int = 127, **kw) -> jax.Array:
    """The canonical VTA epilogue: SHR then clip (MIN/MAX pair)."""
    return tensor_alu(acc, chain=(("shr", shift), ("max", lo), ("min", hi)),
                      **kw)
