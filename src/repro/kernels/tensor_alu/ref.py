"""Pure-jnp oracle for the tensor ALU kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def tensor_alu_ref(dst: jax.Array, src: Optional[jax.Array] = None,
                   *, chain: Tuple[Tuple[str, Optional[int]], ...]) -> jax.Array:
    x = dst
    for op, imm in chain:
        y = jnp.full_like(x, imm) if imm is not None else src
        if op == "min":
            x = jnp.minimum(x, y)
        elif op == "max":
            x = jnp.maximum(x, y)
        elif op == "add":
            x = x + y
        elif op == "mul":
            x = x * y
        elif op == "shr":
            x = jnp.where(y >= 0, jax.lax.shift_right_arithmetic(x, y),
                          jax.lax.shift_left(x, -y))
        else:
            raise ValueError(op)
    return x
