"""VTA tensor ALU, TPU-native (Pallas).

The FPGA tensor ALU performs element-wise MIN/MAX/ADD/SHR/MUL over
register-file tensors (tensor-tensor or tensor-immediate, Fig. 8) at an
initiation interval >= 2 because the register file has one read port.  On
TPU the VPU performs these over (8,128) vregs; the kernel streams int32
blocks through VMEM.  Fused chains (e.g. shift->max->min = requantize+clip)
run in one pass — the resource-balance trade §2.5 discusses.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ALU_OPS = ("min", "max", "add", "shr", "mul")


def _apply(op: str, x: jax.Array, y: jax.Array) -> jax.Array:
    if op == "min":
        return jnp.minimum(x, y)
    if op == "max":
        return jnp.maximum(x, y)
    if op == "add":
        return x + y
    if op == "mul":
        return x * y
    if op == "shr":
        # VTA semantics: negative shift = shift left
        return jnp.where(y >= 0,
                         jax.lax.shift_right_arithmetic(x, y),
                         jax.lax.shift_left(x, -y))
    raise ValueError(op)


def _alu_kernel(dst_ref, src_ref, o_ref, *, chain: Tuple[Tuple[str, Optional[int]], ...]):
    x = dst_ref[...]
    src = src_ref[...] if src_ref is not None else None
    for op, imm in chain:
        y = jnp.full_like(x, imm) if imm is not None else src
        x = _apply(op, x, y)
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("chain", "bm", "interpret"))
def tensor_alu_pallas(dst: jax.Array, src: Optional[jax.Array] = None,
                      *, chain: Tuple[Tuple[str, Optional[int]], ...],
                      bm: int = 256, interpret: bool = True) -> jax.Array:
    """Apply a chain of VTA ALU ops to an int32 tensor.

    chain: tuple of (op, imm) — imm=None means tensor-tensor with `src`.
    dst/src: (M, N) int32 with N a multiple of 128 (lane width).
    """
    M, N = dst.shape
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    has_src = any(imm is None for _, imm in chain)
    in_specs = [pl.BlockSpec((bm, N), lambda i: (i, 0))]
    args = [dst]
    if has_src:
        assert src is not None
        in_specs.append(pl.BlockSpec((bm, N), lambda i: (i, 0)))
        args.append(src)

    def kernel(*refs):
        if has_src:
            d_ref, s_ref, o_ref = refs
        else:
            (d_ref, o_ref), s_ref = refs, None
        _alu_kernel(d_ref, s_ref, o_ref, chain=chain)

    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(*args)
