from .kernel import tensor_alu_pallas  # noqa: F401
from .ops import requantize, tensor_alu  # noqa: F401
from .ref import tensor_alu_ref  # noqa: F401
