"""Causal GQA flash attention (prefill), Pallas TPU.

VTA's decoupled access-execute pattern applied to attention: the KV
stream is consumed block-by-block from HBM while the MXU computes the
running-softmax update for the previous block (grid pipelining double-
buffers the DMA exactly like VTA's load/compute FIFO overlap).  Scratch
(m, l, acc) lives in VMEM — the explicit "register file" of the kernel.

Grid: (batch*q_heads, q_blocks, kv_blocks), kv innermost ("arbitrary"),
rest parallel.  GQA: the kv BlockSpec index_map folds the q-head index
onto its kv head (h // group), so no host-side KV replication is needed.
Causality: kv blocks strictly above the diagonal are skipped via pl.when
(no wasted MXU work); the diagonal block is masked.

VMEM working set per step (bq=bk=256, D=128, f32):
  q/acc (bq, D)*2 + k/v (bk, D)*2 + scores (bq, bk) ~= 0.8 MiB « VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, scale: float, causal: bool, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip kv blocks entirely above the causal diagonal
        pl.when(ik * bk <= iq * bq + bq - 1)(body)
    else:
        body()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("group", "causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           group: int, causal: bool = True,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """q: (B*HQ, S, D);  k/v: (B*KH, S, D);  group = HQ // KH.

    The kv index_map sends q head h to kv head h // group — GQA without
    materializing replicated KV.
    """
    BH, S, D = q.shape
    _, Sk, _ = k.shape
    assert BH % group == 0
    bq = min(bq, S)
    bk = min(bk, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, Sk, bq, bk)
    nk = Sk // bk
    scale = 1.0 / (D ** 0.5)

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, nk=nk),
        grid=(BH, S // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
