"""Public op: (B, S, H, D)-layout GQA attention with pallas/ref dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain

from .kernel import flash_attention_pallas
from .ref import attention_ref, attention_ref_chunked

# the merged (batch*heads) dim shards over the WHOLE mesh — attention is
# embarrassingly parallel across it; without this constraint GSPMD keeps
# only one mesh axis and replicates the other (16x redundant compute)
_BH_AXES = ("pod", "data", "model")

# above this many score elements per head, the materialized oracle would
# dominate memory — switch to the lax.scan flash formulation
_CHUNKED_THRESHOLD = 2048 * 2048


def _to_heads(x: jax.Array) -> jax.Array:
    """(B, S, H, D) -> (B*H, S, D)"""
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _from_heads(x: jax.Array, B: int) -> jax.Array:
    BH, S, D = x.shape
    H = BH // B
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, use_pallas: bool = False,
                    interpret: bool = True, bq: int = 256,
                    bk: int = 256) -> jax.Array:
    """q: (B, S, HQ, D); k/v: (B, S, KH, D). Returns (B, S, HQ, D)."""
    B, S, HQ, D = q.shape
    KH = k.shape[2]
    group = HQ // KH
    if use_pallas:
        qh, kh, vh = _to_heads(q), _to_heads(k), _to_heads(v)
        out = flash_attention_pallas(qh, kh, vh, group=group, causal=causal,
                                     bq=bq, bk=bk, interpret=interpret)
        return _from_heads(out, B)
    if S * k.shape[1] > _CHUNKED_THRESHOLD:
        # sequence parallelism: q rows are independent — shard the q seq
        # dim over "model" (uniform across head counts), batch over data
        data = ("pod", "data")
        q = constrain(q, data, "model", None, None)
        k = constrain(k, data, "model", None, None)
        v = constrain(v, data, "model", None, None)
        out = attention_ref_chunked(q, k, v, group=group, causal=causal)
        return constrain(out, data, "model", None, None)
    qh, kh, vh = _to_heads(q), _to_heads(k), _to_heads(v)
    out = attention_ref(qh, kh, vh, group=group, causal=causal)
    return _from_heads(out, B)
