"""Pure-jnp oracles: exact softmax attention with GQA + causal mask.

Two forms:
  * attention_ref        — materialized (S, Sk) scores; the test oracle.
  * attention_ref_chunked — lax.scan over kv blocks with running softmax
    (flash semantics in plain XLA).  Used on the dry-run path for long
    sequences: peak memory is one (S, bk) block instead of (S, Sk), and
    cost_analysis still sees real FLOPs (unlike an opaque Pallas call).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  group: int, causal: bool = True) -> jax.Array:
    """q: (B*HQ, S, D); k/v: (B*KH, S, D); group = HQ // KH."""
    BH, S, D = q.shape
    kv = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kv.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        Sk = k.shape[1]
        mask = jnp.tril(jnp.ones((S, Sk), bool), k=Sk - S)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_ref_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          group: int, causal: bool = True,
                          bk: int = 1024) -> jax.Array:
    """Flash-style running softmax over kv blocks, pure XLA (lax.scan).

    Operates on the native (B, S, H, D) layout with NO (B*H) merge or
    transpose: merged-dim reshapes of differently-sharded dims trigger
    GSPMD "involuntary full rematerialization" (full-tensor all-gathers).
    Under the production mesh the q sequence dim is sharded over "model"
    (sequence parallelism — rows of the softmax are independent), the
    batch dim over the data axes; each kv block is broadcast, which is
    the cheap direction (bk*D per step vs S*d activations).
    """
    B, S, HQ, D = q.shape
    _, Sk, KH, _ = k.shape
    bk = min(bk, Sk)
    if Sk % bk:
        # non-power-of-two kv length (e.g. whisper's 1500 encoder frames):
        # fall back to one block if small, else the largest even divisor
        if Sk <= 4096:
            bk = Sk
        else:
            bk = next(b for b in range(bk, 0, -1) if Sk % b == 0)
    nk = Sk // bk
    scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kb = k.astype(jnp.float32).reshape(B, nk, bk, KH, D).swapaxes(0, 1)
    vb = v.astype(jnp.float32).reshape(B, nk, bk, KH, D).swapaxes(0, 1)
    # absolute position of each q row (cache prefix of Sk - S tokens)
    q_pos = (jnp.arange(S) + (Sk - S))[None, None, :, None]   # (1,1,S,1)
    qg = qf.reshape(B, S, KH, group, D)

    def body(carry, blk):
        m, l, acc, j = carry
        kj, vj = blk                                  # (B, bk, KH, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kj)   # (B,KH,G,S,bk)
        s = s.reshape(B, HQ, S, bk)
        if causal:
            k_pos = j * bk + jnp.arange(bk)[None, None, None, :]
            s = jnp.where(q_pos >= k_pos, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd",
                        p.reshape(B, KH, group, S, bk), vj)
        acc = acc * jnp.moveaxis(alpha, 1, 2) + pv.reshape(B, S, HQ, D)
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((B, HQ, S, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, HQ, S, 1), jnp.float32)
    acc0 = jnp.zeros((B, S, HQ, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.int32(0)),
                                     (kb, vb))
    out = acc / jnp.moveaxis(jnp.maximum(l, 1e-30), 1, 2)
    return out.astype(q.dtype)
