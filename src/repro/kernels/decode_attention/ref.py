"""Pure-jnp oracles for single-step decode attention with length masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q: (B*KH, G, D); k/v: (B*KH, S, D); kv_len: () or (1,) int32."""
    BH, G, D = q.shape
    S = k.shape[1]
    s = jnp.einsum("hgd,hkd->hgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    valid = jnp.arange(S)[None, None, :] < jnp.reshape(kv_len, ())
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgk,hkd->hgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref_4d(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array,
                            kv_len: jax.Array) -> jax.Array:
    """Cache-native layout: NO transpose of the (huge) KV cache.

    q: (B, 1, HQ, D); caches: (B, S, KH, D).  The cache seq dim can be
    sharded (GSPMD-native flash-decoding: the softmax over a sharded S
    lowers to per-shard partials + a tiny all-reduce combine).
    Returns (B, 1, HQ, D)."""
    B, _, HQ, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = HQ // KH
    qg = q.reshape(B, KH, G, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    valid = (jnp.arange(S) < jnp.reshape(kv_len, ()))[None, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, HQ, D).astype(q.dtype)
