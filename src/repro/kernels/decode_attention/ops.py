"""Public op: batched GQA decode step over a (possibly padded) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.meshctx import constrain

from .kernel import decode_attention_pallas
from .ref import decode_attention_ref, decode_attention_ref_4d


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, use_pallas: bool = False,
                     interpret: bool = True, bk: int = 512) -> jax.Array:
    """q: (B, 1, HQ, D); caches: (B, S, KH, D); kv_len: scalar int32.
    Returns (B, 1, HQ, D)."""
    B, _, HQ, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = HQ // KH
    if use_pallas:
        qh = q.reshape(B, HQ, D).reshape(B, KH, G, D).reshape(B * KH, G, D)
        kh = k_cache.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
        vh = v_cache.transpose(0, 2, 1, 3).reshape(B * KH, S, D)
        out = decode_attention_pallas(qh, kh, vh, jnp.asarray(kv_len),
                                      bk=bk, interpret=interpret)
        return out.reshape(B, KH, G, D).reshape(B, 1, HQ, D)
    # cache-native path: no transpose of the cache; works with a
    # sequence-sharded cache (GSPMD flash-decoding)
    return decode_attention_ref_4d(q, k_cache, v_cache, jnp.asarray(kv_len))
