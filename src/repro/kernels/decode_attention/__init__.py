from .kernel import decode_attention_pallas  # noqa: F401
from .ops import decode_attention  # noqa: F401
from .ref import decode_attention_ref  # noqa: F401
