"""Single-step decode attention over a long KV cache (flash-decoding).

The decode cells (decode_32k / long_500k) are KV-bandwidth-bound: one new
query token attends over S cached keys.  The kernel streams the KV cache
through VMEM in seq blocks (grid innermost dim) with a running softmax —
arithmetic intensity ~2 flops/byte, so the roofline is the HBM stream rate
and the job of the kernel is purely to keep the DMA saturated (VTA's
latency-hiding argument in its purest form).

All G q-heads of one kv head are processed together so the KV block is
read once per group rather than once per head (G-fold HBM traffic saving
— same motivation as VTA's weight-buffer reuse).

Grid: (B*KH, S//bk).  q block: (1, G, D); kv block: (1, bk, D);
scratch m/l: (G, 1), acc: (G, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bk: int, scale: float, nk: int):
    ik = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ik * bk < kv_len)  # skip blocks beyond the valid cache length
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            kv_len: jax.Array, *, bk: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q: (B*KH, G, D) one new token per sequence, grouped per kv head;
    k/v: (B*KH, S, D) cache (padded to S); kv_len: (1,) int32 valid length.
    """
    BH, G, D = q.shape
    _, S, _ = k.shape
    bk = min(bk, S)
    assert S % bk == 0
    nk = S // bk
    scale = 1.0 / (D ** 0.5)

    return pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, scale=scale, nk=nk),
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv_len scalar
            pl.BlockSpec((1, G, D), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len.astype(jnp.int32).reshape(1), q, k, v)
