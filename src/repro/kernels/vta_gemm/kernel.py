"""VTA GEMM core, TPU-native (Pallas).

The FPGA design's (BATCH x BLOCK_IN x BLOCK_OUT) single-cycle intrinsic
becomes the MXU's 128x128 systolic matmul; the data-specialized SRAMs
become per-operand VMEM blocks with explicit BlockSpecs; decoupled
access-execute becomes Mosaic's grid software pipeline (HBM->VMEM DMA for
block k+1 overlaps the MXU pass over block k — the same load/compute
overlap VTA achieves with dependence-token FIFOs); and the tensor-ALU
epilogue (bias / shift-requantize / clip, §2.5) is fused after the last
reduction step so the register file is written once.

Semantics (faithful to the VTA datapath):
    acc(int32) = sum_k  A(int8) @ W(int8)
    epilogue:
      "none":    out = acc                                  (int32)
      "requant": out = clip((acc + bias) >> shift) as int8  (truncating SHR)
      "dequant": out = (acc + bias) * scale as float32      (LM serving path)

Block shapes default to (128, 128, 128): MXU-aligned (int8 min tile is
(32,128); 128x128 keeps both matmul operands and the int32 accumulator at
hardware-native tiling).  VMEM working set per grid step:
    bm*bk (A, int8) + bk*bn (W, int8) + bm*bn*4 (acc) + out block
  = 16 KiB + 16 KiB + 64 KiB + <=64 KiB  «  ~16 MiB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _gemm_kernel(a_ref, w_ref, bias_ref, scale_ref, o_ref, acc_ref, *,
                 epilogue: str, shift: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.int32)
        if epilogue == "none":
            o_ref[...] = acc
        elif epilogue == "requant":
            # VTA SHR is a truncating arithmetic shift; clip = tensor-ALU
            # MIN/MAX pair; the OUT store narrows to int8.
            q = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
            o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)
        elif epilogue == "dequant":
            o_ref[...] = acc.astype(jnp.float32) * scale_ref[...]
        else:
            raise ValueError(epilogue)


@functools.partial(
    jax.jit,
    static_argnames=("epilogue", "shift", "bm", "bn", "bk", "interpret"))
def vta_gemm_pallas(a: jax.Array, w: jax.Array,
                    bias: Optional[jax.Array] = None,
                    scale: Optional[jax.Array] = None,
                    *, epilogue: str = "none", shift: int = 0,
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """C[M,N] = epilogue(A[M,K](int8) @ W[K,N](int8) + bias).

    bias: (N,) int32, scale: (N,) float32 (per-output-channel, like VTA's
    per-filter requant constants).  `interpret=True` for CPU validation;
    on TPU pass interpret=False.
    """
    M, K = a.shape
    K2, N = w.shape
    assert K == K2, (a.shape, w.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"pad shapes to block multiples: {(M, N, K)} vs {(bm, bn, bk)}"
    nk = K // bk
    out_dtype = {"none": jnp.int32, "requant": jnp.int8,
                 "dequant": jnp.float32}[epilogue]

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # A tile (inp buffer)
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # W tile (wgt buffer)
    ]
    args = [a, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(bias.reshape(1, N))
    if epilogue == "dequant":
        assert scale is not None
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(scale.reshape(1, N))

    def kernel(*refs):
        a_ref, w_ref = refs[0], refs[1]
        idx = 2
        b_ref = None
        s_ref = None
        if bias is not None:
            b_ref = refs[idx]; idx += 1
        if epilogue == "dequant":
            s_ref = refs[idx]; idx += 1
        o_ref, acc_ref = refs[idx], refs[idx + 1]
        _gemm_kernel(a_ref, w_ref, b_ref, s_ref, o_ref, acc_ref,
                     epilogue=epilogue, shift=shift, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],  # register file
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
