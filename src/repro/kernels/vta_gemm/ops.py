"""Public op: quantized GEMM through the VTA datapath.

Dispatches to the Pallas kernel on TPU and the jnp oracle elsewhere; both
share exact integer semantics, so tests sweep shapes/dtypes against ref.
Handles padding to block multiples (the runtime's job on the FPGA: VTA's
2D DMA pads tiles on the fly; here we pad once at the XLA level).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .._compat import resolve_interpret
from .kernel import vta_gemm_pallas
from .ref import vta_gemm_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def vta_gemm(a: jax.Array, w: jax.Array,
             bias: Optional[jax.Array] = None,
             scale: Optional[jax.Array] = None,
             *, epilogue: str = "none", shift: int = 0,
             use_pallas: bool = False, interpret: Optional[bool] = None,
             bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """int8 x int8 -> int32 GEMM with fused VTA epilogue.

    a: (M, K) int8;  w: (K, N) int8;  bias: (N,) int32;  scale: (N,) f32.
    use_pallas=False runs the jnp oracle (identical math) — used by the
    dry-run so cost_analysis sees real FLOPs; tests exercise both paths.
    interpret=None auto-selects (native on TPU, interpreter elsewhere).
    """
    if not use_pallas:
        return vta_gemm_ref(a, w, bias, scale, epilogue=epilogue, shift=shift)
    M, K = a.shape
    _, N = w.shape
    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(bias, 0, bn) if bias is not None else None
    sp = _pad_to(scale, 0, bn) if scale is not None else None
    out = vta_gemm_pallas(ap, wp, bp, sp, epilogue=epilogue, shift=shift,
                          bm=bm, bn=bn, bk=bk,
                          interpret=resolve_interpret(interpret))
    return out[:M, :N]


def quantized_linear(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     x_scale: Optional[jax.Array] = None,
                     *, use_pallas: bool = False,
                     interpret: Optional[bool] = None) -> jax.Array:
    """LM serving path: y(f32) = (x_q @ w_q) * (sx * sw[n]).

    x: float activations -> dynamically quantized to int8 per-tensor;
    w_q: (K, N) int8 with per-channel scales. This is the paper's PTQ
    deployment scheme lifted to the LM stack.
    """
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    if x_scale is None:
        amax = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-6)
        x_scale = (amax / 127.0).astype(jnp.float32)
    x_q = jnp.clip(jnp.round(x2 / x_scale), -128, 127).astype(jnp.int8)
    scale = (w_scale.astype(jnp.float32) * x_scale).astype(jnp.float32)
    y = vta_gemm(x_q, w_q, scale=scale, epilogue="dequant",
                 use_pallas=use_pallas, interpret=interpret)
    return y.reshape(*orig_shape[:-1], w_q.shape[1]).astype(x.dtype)
