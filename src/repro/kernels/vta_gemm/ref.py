"""Pure-jnp oracle for the VTA GEMM kernel (identical integer semantics)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def vta_gemm_ref(a: jax.Array, w: jax.Array,
                 bias: Optional[jax.Array] = None,
                 scale: Optional[jax.Array] = None,
                 *, epilogue: str = "none", shift: int = 0) -> jax.Array:
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    if epilogue == "none":
        return acc
    if epilogue == "requant":
        q = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    if epilogue == "dequant":
        return acc.astype(jnp.float32) * scale[None, :]
    raise ValueError(epilogue)
