from .kernel import vta_gemm_pallas  # noqa: F401
from .ops import quantized_linear, vta_gemm  # noqa: F401
from .ref import vta_gemm_ref  # noqa: F401
