"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel ships three files: kernel.py (pl.pallas_call + explicit
BlockSpec VMEM tiling), ops.py (jit'd public wrapper with pallas/oracle
dispatch), ref.py (pure-jnp oracle).  All kernels validate in
interpret=True mode on CPU; TPU is the compilation target.
"""
from . import (decode_attention, flash_attention, gla_chunk,  # noqa: F401
               tensor_alu, vta_gemm)
