"""Gradient compression: int8-quantized all-reduce with error feedback.

Beyond-paper distributed-optimization trick, built from the paper's own
machinery: the symmetric int8 quantization VTA uses for weights (§5)
applied to the DP gradient all-reduce.  Per-shard max-abs scale, int8
payload (4x less DP wire traffic than fp32, 2x less than bf16), local
error feedback (residual carried to the next step) preserves convergence.
int32 accumulation mirrors VTA's wide-accumulator datapath.

Implemented with shard_map + psum so the collective actually moves int8
on the wire — a with_sharding_constraint formulation would let XLA
all-reduce in f32 and the compression would be cosmetic.

Integration: the train step computes per-DP-shard microbatch gradients
inside shard_map and reduces them through `compressed_mean`; the error
tree lives in the optimizer state (same sharding as grads).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .meshctx import shard_map

Params = Any


def quantize_shard(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -128, 127
                 ).astype(jnp.int8)
    return q, scale


def compressed_mean_local(g: jax.Array, err: jax.Array, axes
                          ) -> Tuple[jax.Array, jax.Array]:
    """Per-device body (call inside shard_map): agree on a global scale
    (pmax of local max-abs — a scalar collective), int8-quantize (g+err),
    psum the int8 payload as int32, decode exactly.  Returns
    (mean gradient [replicated over axes], new error)."""
    names = axes if isinstance(axes, tuple) else (axes,)
    n = 1
    for a in names:
        # jax.lax.axis_size is jax >= 0.5; psum(1, axis) works everywhere
        size_of = getattr(jax.lax, "axis_size", None)
        n = n * (size_of(a) if size_of is not None else jax.lax.psum(1, a))
    gi = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(gi)), names)    # shared scale
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gi / scale), -128, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), names)    # int32 accumulate
    mean = total.astype(jnp.float32) * scale / n
    new_err = gi - q.astype(jnp.float32) * scale        # local residual
    return mean.astype(g.dtype), new_err


def compressed_mean(stacked_grads: jax.Array, errors: jax.Array,
                    mesh: Mesh, axis: str = "data"
                    ) -> Tuple[jax.Array, jax.Array]:
    """Reference entry point: `stacked_grads` (n_shards, ...) holds each
    DP shard's gradient; returns (mean (...), new errors (n_shards, ...)).
    """
    def body(g, e):
        out, err = compressed_mean_local(g[0], e[0], axis)
        return out[None], err[None]

    fn = shard_map(body, mesh=mesh,
                       in_specs=(P(axis), P(axis)),
                       out_specs=(P(axis), P(axis)))
    mean_stacked, new_err = fn(stacked_grads, errors)
    # every shard's mean row is identical; row 0 is the reduced gradient
    return mean_stacked[0], new_err
