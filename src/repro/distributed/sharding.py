"""Parameter/optimizer/cache sharding rules (TP + FSDP + EP).

Maps every parameter leaf to a PartitionSpec by name-based rules with
divisibility fallbacks:
  * TP ("model" axis): attention heads, FFN hidden, MoE experts, vocab;
  * FSDP (ZeRO-3, over the data axes): the complementary large dim —
    required for kimi-k2 (1T params: 2 TB bf16 must spread over all 512
    chips, not 16);
  * small/odd leaves (norms, scalars, conv taps) replicate.

The same spec tree shards optimizer states (they mirror params) and is
what restore-time resharding (elastic restart) targets.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _fits(shape, dim: int, mesh: Mesh, entry) -> bool:
    if entry is None or dim >= len(shape):
        return False
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        if a not in mesh.shape:
            return False
        n *= mesh.shape[a]
    return shape[dim] % n == 0 and shape[dim] >= n


def _spec(shape, mesh, assignments) -> P:
    """assignments: list of (dim, axis_entry) — applied when divisible,
    falling back to the largest dividing prefix of a multi-axis entry."""
    out = [None] * len(shape)
    used = set()
    for dim, entry in assignments:
        if entry is None:
            continue
        names = tuple(entry) if isinstance(entry, tuple) else (entry,)
        names = tuple(a for a in names if a not in used)
        while names:
            cand = names if len(names) > 1 else names[0]
            if _fits(shape, dim, mesh, cand):
                out[dim] = cand
                used.update(names)
                break
            names = names[:-1]
    return P(*out)


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh,
                fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching `params`."""
    sc = cfg.sharding
    model = sc.model_axis if sc.model_axis in mesh.shape else None
    fsdp_axes: Optional[Tuple[str, ...]] = None
    if fsdp:
        axes = tuple(a for a in (sc.fsdp_axes or sc.data_axes)
                     if a in mesh.shape)
        fsdp_axes = axes if axes else None

    def leaf_spec(path: str, x) -> P:
        shape = x.shape
        nd = len(shape)
        if nd == 0:
            return P()
        L = 1 if "layers/" in path else 0  # stacked leading layer dim

        def d(i):   # dim index offset by the stacked layer dim
            return L + i

        last = path.split("/")[-1]
        if last in ("w", "w_q"):
            lname = path.split("/")[-2]
        else:
            lname = last
        if last == "w_scale":       # per-channel PTQ scales: tiny, replicate
            return P()
        if "norm" in path or lname in ("scale", "bias", "A_log", "D",
                                       "dt_bias", "conv_w", "conv_b", "r"):
            return P()
        if path.startswith("embed/tokens"):
            return _spec(shape, mesh, [(0, model), (1, fsdp_axes)])
        if path.startswith("embed/pos"):
            return _spec(shape, mesh, [(1, fsdp_axes)])
        if path.startswith("lm_head"):
            return _spec(shape, mesh, [(1, model), (0, fsdp_axes)])
        # --- MoE experts: EP over model on the expert dim ---
        if "/moe/" in path or "/shared/" in path:
            if lname in ("wi", "wg") and nd == d(3):
                return _spec(shape, mesh, [(d(0), model), (d(1), fsdp_axes)])
            if lname == "wo" and nd == d(3):
                return _spec(shape, mesh, [(d(0), model), (d(2), fsdp_axes)])
            if lname == "router" or "/router/" in path:
                return _spec(shape, mesh, [(d(0), fsdp_axes)])
            if lname in ("wi", "wg"):   # shared-expert dense mlp (L, d, f)
                return _spec(shape, mesh, [(d(1), model), (d(0), fsdp_axes)])
            if lname == "wo":
                return _spec(shape, mesh, [(d(0), model), (d(1), fsdp_axes)])
        # --- attention projections ---
        if lname in ("wq", "wk", "wv"):
            return _spec(shape, mesh, [(d(1), model), (d(0), fsdp_axes)])
        if lname == "wo":
            return _spec(shape, mesh, [(d(0), model), (d(1), fsdp_axes)])
        # --- dense MLP ---
        if lname in ("wi", "wg"):
            return _spec(shape, mesh, [(d(1), model), (d(0), fsdp_axes)])
        # --- mamba / xlstm projections: TP-free (small), FSDP on d ---
        if lname in ("in_proj", "up_x", "up_z", "w_in"):
            return _spec(shape, mesh, [(d(0), fsdp_axes)])
        if lname in ("out_proj", "down"):
            return _spec(shape, mesh, [(d(1), fsdp_axes)])
        if lname == "w_if":
            return _spec(shape, mesh, [(d(0), fsdp_axes)])
        # generic fallback: try model on the last dim, fsdp on the first
        return _spec(shape, mesh, [(nd - 1, model), (max(0, nd - 2), fsdp_axes)])

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths_specs = {}

    def path_str(kp) -> str:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    leaves, treedef = flat
    specs = [leaf_spec(path_str(kp), leaf) for kp, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def opt_state_specs(opt_state: Any, param_spec_tree: Any,
                    params_shapes: Any) -> Any:
    """Optimizer-state PartitionSpecs.

    AdamW m/v mirror the params exactly.  Adafactor's factored moments
    drop one trailing dim: vr = spec[:-1], vc = spec[:-2] + spec[-1:];
    factoring only happens for >=2-D params (see optimizers._factored).
    Scalars (count) replicate."""
    out = {}
    for k, v in opt_state.items():
        if k == "count":
            out[k] = P()
        elif k == "m":
            out[k] = param_spec_tree        # mirrors params exactly
        elif k == "v":
            leaves = jax.tree_util.tree_leaves(
                v, is_leaf=lambda x: isinstance(x, dict)
                and set(x) <= {"vr", "vc", "v"})
            if leaves and isinstance(leaves[0], dict):   # Adafactor
                def per_param(sp, shape_leaf):
                    entries = list(sp) + [None] * (
                        len(shape_leaf.shape) - len(list(sp)))
                    if len(shape_leaf.shape) >= 2 and \
                            shape_leaf.shape[-1] > 1 and shape_leaf.shape[-2] > 1:
                        return {"vr": P(*entries[:-1]),
                                "vc": P(*(entries[:-2] + entries[-1:]))}
                    return {"v": P(*entries)}
                out[k] = jax.tree.map(
                    per_param, param_spec_tree, params_shapes,
                    is_leaf=lambda x: isinstance(x, P))
            else:                                        # AdamW
                out[k] = param_spec_tree
        else:
            out[k] = jax.tree.map(lambda _: P(), v)
    return out


def cache_specs(caches: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """KV caches: shard batch over data axes, kv-heads over model when
    divisible; SSM states: batch over data."""
    sc = cfg.sharding
    data = tuple(a for a in sc.data_axes if a in mesh.shape) or None
    model = sc.model_axis if sc.model_axis in mesh.shape else None

    def leaf(x) -> P:
        shape = x.shape
        if len(shape) == 5:
            # (L, B, S, KH, D) kv cache: batch over data; kv-heads over
            # model when divisible, else the SEQ dim over model (GSPMD
            # flash-decoding: partial softmax per shard + tiny combine) —
            # without this, GQA caches with KH < TP replicate 16x.
            assignments = [(1, data)]
            if model is not None and shape[3] % mesh.shape[model] == 0:
                assignments.append((3, model))
            else:
                assignments.append((2, model))
            return _spec(shape, mesh, assignments)
        if len(shape) >= 2:
            return _spec(shape, mesh, [(1, data)])
        return P()

    return jax.tree.map(leaf, caches)


def batch_specs(batch_shapes: Dict[str, Any], cfg: ModelConfig,
                mesh: Mesh) -> Dict[str, P]:
    sc = cfg.sharding
    data = tuple(a for a in sc.data_axes if a in mesh.shape) or None
    out = {}
    for k, v in batch_shapes.items():
        shape = v.shape if hasattr(v, "shape") else v
        spec = [None] * len(shape)
        if len(shape) >= 1 and data is not None:
            names = data
            while names:   # largest dividing prefix (see meshctx.constrain)
                n = 1
                for a in names:
                    n *= mesh.shape[a]
                if shape[0] % n == 0:
                    spec[0] = names if len(names) > 1 else names[0]
                    break
                names = names[:-1]
        out[k] = P(*spec)
    return out
