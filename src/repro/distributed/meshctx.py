"""Ambient mesh context shared between the launch layer and model code.

The launch layer (dryrun/train/serve) sets the mesh once; model layers
that need explicit collectives (expert-parallel MoE via shard_map) or
sharding constraints read it here.  Smoke tests run with no mesh set and
every distributed hook degrades to a no-op.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def use_mesh(mesh: Mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map: `jax.shard_map` only exists on jax >= 0.5;
    0.4.x ships the same API under jax.experimental.shard_map, where the
    replication-checker flag is named check_rep instead of check_vma."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.
    Axis names absent from the mesh are dropped (e.g. "pod" on the
    single-pod mesh); axes that do not evenly divide the corresponding
    dim are dropped (e.g. batch=1 long-context decode keeps the data
    axes unsharded)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    fixed = []
    used = set()
    for i, entry in enumerate(spec):
        if entry is None or i >= x.ndim:
            fixed.append(None)
            continue
        names = tuple(a for a in
                      (entry if isinstance(entry, tuple) else (entry,))
                      if a in mesh.shape and a not in used)
        # largest prefix of the axis tuple that divides the dim (e.g.
        # batch=32 over ("data","model")=256 falls back to ("data",)=16)
        chosen = None
        while names:
            entry2 = names if len(names) > 1 else names[0]
            if x.shape[i] % _axis_size(mesh, entry2) == 0:
                chosen = entry2
                break
            names = names[:-1]
        if chosen is None:
            fixed.append(None)
        else:
            fixed.append(chosen)
            used.update(names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
