"""Fault-tolerance runtime: straggler watchdog, failure detection hooks,
elastic mesh reconfiguration.

On a real multi-pod deployment these hooks sit around the training loop:
  * `StepWatchdog` — flags steps exceeding `deadline = k * EMA(step_time)`
    (straggler mitigation: the launcher can preempt the slow host, shrink
    the mesh, and restart from the last checkpoint);
  * `ElasticPlan` — given surviving device count, picks the largest valid
    (pod, data, model) mesh <= survivors and rescales batch/LR;
  * `simulate_failure` — test hook that drops devices deterministically.

The CPU container can't kill real hosts, so tests exercise the logic via
the simulation hook — the decision code (what to do on failure) is the
production code path; only the failure *source* is simulated.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


class StepWatchdog:
    """EMA-based straggler detector with a hard deadline multiplier."""

    def __init__(self, slack: float = 3.0, ema: float = 0.9,
                 min_deadline_s: float = 1.0):
        self.slack = slack
        self.ema = ema
        self.min_deadline_s = min_deadline_s
        self.mean_step_s: Optional[float] = None
        self.straggler_events: List[Tuple[int, float]] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    @property
    def deadline_s(self) -> float:
        if self.mean_step_s is None:
            return float("inf")
        return max(self.min_deadline_s, self.slack * self.mean_step_s)

    def end_step(self, step: int, elapsed: Optional[float] = None) -> bool:
        """Returns True if this step was a straggler."""
        dt = elapsed if elapsed is not None else time.monotonic() - self._t0
        straggler = (self.mean_step_s is not None
                     and dt > self.deadline_s)
        if straggler:
            self.straggler_events.append((step, dt))
        else:
            # only healthy steps update the EMA (stragglers would poison it)
            self.mean_step_s = (dt if self.mean_step_s is None
                                else self.ema * self.mean_step_s
                                + (1 - self.ema) * dt)
        return straggler


@dataclass
class ElasticPlan:
    """Mesh + batch decision after a membership change."""
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    lr_scale: float
    dropped_devices: int


def plan_elastic_restart(n_devices: int, model_parallel: int,
                         target_batch: int,
                         pods: int = 1) -> ElasticPlan:
    """Largest (pod, data, model) mesh that fits the survivors, keeping TP
    intact (model groups must stay whole — TP shards are not recoverable
    piecemeal) and shrinking data parallelism; batch shrinks with DP and
    LR scales linearly (the standard recipe)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{n_devices} devices — restore needs resharding to smaller TP")
    groups = n_devices // model_parallel
    # keep pod axis only if groups divide evenly across surviving pods
    if pods > 1 and groups % pods == 0:
        shape = (pods, groups // pods, model_parallel)
        names = ("pod", "data", "model")
        dp = groups
    else:
        shape = (groups, model_parallel)
        names = ("data", "model")
        dp = groups
    # per-replica batch stays fixed; global batch scales with DP
    per_replica = max(1, target_batch // max(1, dp))
    new_batch = per_replica * dp
    return ElasticPlan(mesh_shape=shape, axis_names=names,
                       global_batch=new_batch,
                       lr_scale=new_batch / target_batch,
                       dropped_devices=0)


def simulate_failure(n_devices: int, n_failures: int, seed: int = 0) -> int:
    """Deterministic survivor count for tests."""
    assert 0 <= n_failures < n_devices
    return n_devices - n_failures
