"""Distributed substrate: mesh context, sharding rules, checkpointing
helpers, fault tolerance, gradient compression."""
from . import meshctx  # noqa: F401
