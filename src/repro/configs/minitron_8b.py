"""minitron-8b [arXiv:2407.14679] — pruned nemotron; 256k vocab."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=256000,
        norm="rmsnorm", pos="rope", mlp="gelu",
        chunked_loss_chunks=16),
    optimizer="adamw", fsdp=True,
)
