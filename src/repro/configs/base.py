"""Architecture registry plumbing: ArchSpec, shape table, input specs,
reduced (smoke-test) configs."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShardingConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    optimizer: str = "adamw"          # adamw | adafactor
    fsdp: bool = False                # ZeRO-3 over data axes
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""              # why some shapes are skipped
    # SSM/recurrent archs have no tensor-parallel weights — the model
    # axis would idle, so data parallelism extends over it (DP=512)
    dp_over_model: bool = False

    @property
    def name(self) -> str:
        return self.model.name


def for_shape(spec: ArchSpec, shape: ShapeSpec,
              sharding: Optional[ShardingConfig] = None,
              quantized: bool = False) -> ModelConfig:
    """Model config specialized to one (shape, sharding) cell."""
    kw: Dict[str, Any] = {"max_seq": shape.seq_len}
    if sharding is not None:
        kw["sharding"] = sharding
    if quantized:
        kw["quantized_inference"] = True
    if shape.kind == "decode" and spec.model.moe_experts:
        # §Perf D2: decode steps must keep experts resident — per-step
        # FSDP weight gathers cost ~50x the useful traffic (EXPERIMENTS.md)
        kw["moe_expert_2d"] = True
    return spec.model.replace(**kw)


# ----------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for the given shape, as ShapeDtypeStructs.

    Modality frontends are STUBS: `patch_emb` / `frames` are precomputed
    embeddings (the assignment's input_specs contract)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        text = S
        batch: Dict[str, Any] = {}
        if cfg.frontend == "vision_stub":
            text = S - cfg.n_patches
            batch["patch_emb"] = sds((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.frontend == "audio_stub":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
        batch["tokens"] = sds((B, text), i32)
        batch["targets"] = sds((B, text), i32)
        return batch
    if shape.kind == "prefill":
        text = S
        batch = {}
        if cfg.frontend == "vision_stub":
            text = S - cfg.n_patches
            batch["patch_emb"] = sds((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.frontend == "audio_stub":
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), dt)
        batch["tokens"] = sds((B, text), i32)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"token": sds((B, 1), i32),
            "pos": sds((), i32)}


# ----------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ----------------------------------------------------------------------
def reduced(cfg: ModelConfig) -> ModelConfig:
    kw: Dict[str, Any] = dict(
        d_model=64, n_heads=4, head_dim=16, d_ff=128 if cfg.d_ff else 0,
        vocab_size=512, max_seq=64, dtype="float32", remat=False,
        chunked_loss_chunks=2,
    )
    kw["n_kv_heads"] = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4
    if cfg.family == "hybrid":
        kw.update(n_layers=4, attn_every=2, ssm_state=16, ssm_head_dim=16,
                  d_ff=128)
    elif cfg.family == "ssm" and cfg.slstm_every:
        kw.update(n_layers=4, slstm_every=2)
    elif cfg.family == "ssm":
        kw.update(n_layers=3, ssm_state=16, ssm_head_dim=16)
    else:
        kw["n_layers"] = 2
    if cfg.moe_experts:
        kw.update(moe_experts=4, moe_top_k=min(cfg.moe_top_k, 2),
                  moe_d_ff=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.frontend == "vision_stub":
        kw.update(n_patches=8)
    return cfg.replace(**kw)
