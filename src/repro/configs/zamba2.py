"""zamba2-1.2b [arXiv:2411.15242] — Mamba2 backbone + shared attn block."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
        attn_every=6,
        norm="rmsnorm", pos="rope", mlp="swiglu",
        seq_parallel_residual=True),  # §Perf Z1/X2 winner
    optimizer="adamw",
    dp_over_model=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
