"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064,
        moe_experts=16, moe_top_k=2, moe_d_ff=6400,
        norm="layernorm", pos="rope", mlp="swiglu",
        moe_fused_ep=True),  # §Perf winner; baseline recorded without
    optimizer="adamw", fsdp=True,
)
