"""The paper's own hardware configuration (§5): PYNQ-Z1 VTA build.
Not an LM architecture — exposed so examples/benchmarks can grab the
evaluation-platform spec from the same registry."""
from repro.core import hwspec

SPEC = hwspec.pynq()
