"""llama3.2-3b [hf:meta-llama/Llama-3.2-*] — small llama3, GQA 24/8."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=128256,
        norm="rmsnorm", pos="rope", rope_theta=500000.0, mlp="swiglu",
        tie_embeddings=True),
    optimizer="adamw",
)
