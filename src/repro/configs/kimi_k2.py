"""kimi-k2-1t-a32b [arXiv:2501.kimi2] — trillion-param MoE.

AdamW fp32 states would need ~12 TB (> the 8 TB of 512 v5e chips), so the
optimizer is Adafactor (factored second moment) with FSDP over pod+data —
recorded in DESIGN.md §Arch-applicability."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=2048, vocab_size=163840,
        moe_experts=384, moe_top_k=8, moe_d_ff=2048, n_shared_experts=1,
        norm="rmsnorm", pos="rope", mlp="swiglu",
        chunked_loss_chunks=16,
        # production defaults = the §Perf winners (EXPERIMENTS.md);
        # baseline rows in the roofline table were recorded without them
        moe_fused_ep=True, seq_parallel_residual=True,
        moe_combine="reduce_scatter"),
    optimizer="adafactor", fsdp=True,
)
