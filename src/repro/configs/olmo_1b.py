"""olmo-1b [arXiv:2402.00838] — non-parametric LayerNorm, tied embeddings."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=8192, vocab_size=50304,
        norm="nonparametric", pos="rope", mlp="swiglu",
        tie_embeddings=True),
    optimizer="adamw",
)
