"""Architecture registry: one module per assigned architecture."""
from typing import Dict, List

from .base import SHAPES, ArchSpec, ShapeSpec, for_shape, input_specs, reduced

_ARCH_MODULES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "kimi-k2-1t-a32b": "kimi_k2",
    "zamba2-1.2b": "zamba2",
    "olmo-1b": "olmo_1b",
    "minitron-8b": "minitron_8b",
    "llama3.2-3b": "llama32_3b",
    "starcoder2-7b": "starcoder2_7b",
    "xlstm-1.3b": "xlstm_1b",
    "phi-3-vision-4.2b": "phi3_vision",
    "whisper-large-v3": "whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_arch(name: str) -> ArchSpec:
    import importlib
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.ARCH


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skips per DESIGN.md unless asked."""
    out = []
    for a in list_archs():
        spec = get_arch(a)
        for s in SHAPES:
            if s in spec.shapes or include_skipped:
                out.append((a, s))
    return out
