"""starcoder2-7b [arXiv:2402.19173] — GQA 36/4, RoPE, LayerNorm, GELU."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
        d_ff=18432, vocab_size=49152,
        norm="layernorm", pos="rope", mlp="gelu"),
    optimizer="adamw", fsdp=True,
)
