"""whisper-large-v3 [arXiv:2212.04356] — enc-dec backbone.

The conv audio frontend is a STUB: input_specs provides precomputed frame
embeddings (B, 1500, d_model).  Decoder cells exercise self-attn KV cache
+ cross-attn over the encoder output."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51866,
        encoder_layers=32, encoder_seq=1500,
        frontend="audio_stub",
        norm="layernorm", pos="learned", mlp="gelu"),
    optimizer="adamw",
)
