"""xlstm-1.3b [arXiv:2405.04517] — mLSTM blocks with sLSTM every 8th."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
        d_ff=0, vocab_size=50304,
        slstm_every=8,
        norm="rmsnorm", pos="none", mlp="swiglu",
        seq_parallel_residual=True),  # §Perf Z1/X2 winner
    optimizer="adamw",
    dp_over_model=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
