"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

Backbone only: the CLIP frontend is a STUB — input_specs provides
precomputed patch embeddings (B, n_patches, d_model)."""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    model=ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, vocab_size=32064,
        frontend="vision_stub", n_patches=576,
        norm="rmsnorm", pos="rope", mlp="swiglu"),
    optimizer="adamw",
)
