"""Sharded checkpointing with async save and elastic restore.

Format: one directory per step, one .npy per pytree leaf (flattened key
path), plus a JSON manifest (tree structure, shapes, dtypes, step, and
the mesh the save ran under).  Restore re-shards onto the *current* mesh
— the elastic-restart path after losing nodes: a checkpoint written on a
2x16x16 mesh restores onto 16x16 (or any other) because leaves are saved
unsharded-logical and re-placed via jax.device_put with the new sharding.

Async: `AsyncCheckpointer.save` snapshots leaves to host memory
synchronously (cheap: device->host copy) and writes files on a background
thread, overlapping I/O with the next training steps — checkpoint stalls
hide behind compute exactly like VTA's load/compute overlap.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        flat["/".join(parts)] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Params,
                    extra: Optional[Dict] = None) -> str:
    """Synchronous save.  Returns the step directory."""
    flat = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", name) + ".npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)   # atomic publish: no torn checkpoints
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Params,
                       shardings: Optional[Params] = None
                       ) -> Tuple[Params, Dict]:
    """Restore into the structure of `like`; if `shardings` (a pytree of
    jax.sharding.Sharding matching `like`) is given, leaves are placed
    sharded — this is where elastic resharding happens."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for name, ref in flat_like.items():
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(step_dir, meta["file"]))
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != "
                             f"expected {ref.shape}")
        sh = flat_shard.get(name)
        out[name] = (jax.device_put(arr, sh) if sh is not None
                     else jax.device_put(arr))
    # rebuild tree
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(like)
    names = list(_flatten(like).keys())
    restored = jax.tree_util.tree_unflatten(
        treedef, [out[n] for n in names])
    return restored, manifest.get("extra", {})


class AsyncCheckpointer:
    """Background-thread writer with at-most-one pending save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error

    def save(self, step: int, tree: Params,
             extra: Optional[Dict] = None) -> None:
        self.wait()
        # snapshot to host synchronously — the device buffers may be
        # donated/overwritten by the next step
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except Exception as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
