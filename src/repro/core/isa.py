"""VTA two-level ISA: 128-bit CISC instructions, bit-packed.

Four CISC instructions (§2.2): LOAD, GEMM, ALU, STORE (+ FINISH sentinel).
Every instruction carries 4 dependence-flag bits (pop_prev, pop_next,
push_prev, push_next) that drive the RAW/WAR token FIFOs between the
load / compute / store modules (§2.3, Fig. 3).

Field widths are *derived from the HardwareSpec* (SRAM depths, intrinsic
shape), reproducing the paper's co-design fluidity: change the template
parameters and the binary encoding changes with them; the runtime and
simulator re-derive the layout so generated code always matches the
generated hardware instance.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from enum import IntEnum
from typing import List, Tuple

import numpy as np

from .hwspec import HardwareSpec

INSN_BITS = 128


class Opcode(IntEnum):
    LOAD = 0
    STORE = 1
    GEMM = 2
    FINISH = 3
    ALU = 4


class MemId(IntEnum):
    """Target scratchpad of a LOAD/STORE (data-specialized SRAMs, §2.6)."""
    UOP = 0
    WGT = 1
    INP = 2
    ACC = 3
    OUT = 4


class AluOp(IntEnum):
    MIN = 0
    MAX = 1
    ADD = 2
    SHR = 3   # arithmetic shift right; negative shift = shift left
    MUL = 4


# module ids for dependence-token routing
LOAD_Q, COMPUTE_Q, STORE_Q = 1, 2, 3

# Dependence-edge tables shared by every stream consumer (runtime validator,
# backends): which token FIFO a queue's instruction consumes / produces, and
# the dep flag that requests it.  Each module consumes from a disjoint FIFO
# set — the property that makes greedy FIFO-order replay an *exact*
# deadlock check (firing an enabled instruction can never disable another).
DEP_IN_EDGES = {LOAD_Q: (("c2l", "pop_next"),),
                COMPUTE_Q: (("l2c", "pop_prev"), ("s2c", "pop_next")),
                STORE_Q: (("c2s", "pop_prev"),)}
DEP_OUT_EDGES = {LOAD_Q: (("l2c", "push_next"),),
                 COMPUTE_Q: (("c2l", "push_prev"), ("c2s", "push_next")),
                 STORE_Q: (("s2c", "push_prev"),)}


@dataclass
class DepFlags:
    pop_prev: bool = False
    pop_next: bool = False
    push_prev: bool = False
    push_next: bool = False


@dataclass
class LoadStoreInsn:
    """2D strided DMA between DRAM and an SRAM (Fig. 3, Fig. 9).

    Addresses are in *elements* of the target buffer (one element = one
    tensor register row, e.g. a BATCH x BLOCK_IN int8 block for INP).
    Padding fields insert zero rows/columns on the fly (conv2d tiling
    without host-side re-layout)."""
    opcode: Opcode            # LOAD or STORE
    dep: DepFlags
    memory_type: MemId
    sram_base: int
    dram_base: int
    y_size: int               # number of rows
    x_size: int               # elements per row
    x_stride: int             # DRAM row stride, elements
    y_pad_0: int = 0
    y_pad_1: int = 0
    x_pad_0: int = 0
    x_pad_1: int = 0


@dataclass
class GemmInsn:
    """Micro-coded GEMM (Fig. 7): runs uops[uop_bgn:uop_end] inside a
    2-level nested loop; tensor-register indices are affine in the loop
    variables.  `reset` zeroes the accumulator instead of multiplying."""
    dep: DepFlags
    reset: bool
    uop_bgn: int
    uop_end: int
    iter_out: int
    iter_in: int
    dst_factor_out: int
    dst_factor_in: int
    src_factor_out: int
    src_factor_in: int
    wgt_factor_out: int
    wgt_factor_in: int
    opcode: Opcode = Opcode.GEMM


@dataclass
class AluInsn:
    """Micro-coded tensor-ALU op (Fig. 8), same 2-level loop structure.
    src operand is a register-file tensor or an immediate."""
    dep: DepFlags
    reset: bool
    uop_bgn: int
    uop_end: int
    iter_out: int
    iter_in: int
    dst_factor_out: int
    dst_factor_in: int
    src_factor_out: int
    src_factor_in: int
    alu_opcode: AluOp
    use_imm: bool
    imm: int
    opcode: Opcode = Opcode.ALU


@dataclass
class FinishInsn:
    dep: DepFlags
    opcode: Opcode = Opcode.FINISH


Insn = LoadStoreInsn | GemmInsn | AluInsn | FinishInsn


# ----------------------------------------------------------------------
# bit packing
# ----------------------------------------------------------------------
class _Packer:
    def __init__(self, max_bits: int = INSN_BITS):
        self.value = 0
        self.pos = 0
        self.max_bits = max_bits

    def put(self, v: int, bits: int, name: str = "?"):
        v = int(v)
        if v < 0 or v >= (1 << bits):
            raise ValueError(f"field {name}={v} does not fit in {bits} bits")
        self.value |= v << self.pos
        self.pos += bits
        if self.pos > self.max_bits:
            raise ValueError(f"instruction exceeds {self.max_bits} bits")


class _Unpacker:
    def __init__(self, value: int):
        self.value = value
        self.pos = 0

    def get(self, bits: int) -> int:
        v = (self.value >> self.pos) & ((1 << bits) - 1)
        self.pos += bits
        return v


class IsaLayout:
    """Field-width table derived from a HardwareSpec."""

    OPCODE_BITS = 3
    MEMID_BITS = 3
    ALUOP_BITS = 3
    DRAM_ADDR_BITS = 32
    SIZE_BITS = 16
    STRIDE_BITS = 16
    PAD_BITS = 4
    LOOP_BITS = 14
    IMM_BITS = 16

    def __init__(self, spec: HardwareSpec):
        self.spec = spec
        # SRAM address width = max over scratchpads (shared field)
        self.sram_addr_bits = max(
            spec.inp_addr_bits, spec.wgt_addr_bits,
            spec.acc_addr_bits, spec.uop_addr_bits, 12,
        )
        self.uop_addr_bits = max(spec.uop_addr_bits, 12) + 1  # uop_end is exclusive
        # affine factor widths: must address the largest scratchpad
        self.factor_bits = max(spec.acc_addr_bits, spec.inp_addr_bits,
                               spec.wgt_addr_bits, 11)
        # co-design fluidity (§2.2): large template instances widen the
        # instruction word from 128 to 256 bits so all fields still fit.
        gemm_bits = (self.OPCODE_BITS + 4 + 1 + 2 * self.uop_addr_bits
                     + 2 * self.LOOP_BITS + 6 * self.factor_bits)
        mem_bits = (self.OPCODE_BITS + 4 + self.MEMID_BITS
                    + self.sram_addr_bits + self.DRAM_ADDR_BITS
                    + 2 * self.SIZE_BITS + self.STRIDE_BITS + 4 * self.PAD_BITS)
        need = max(gemm_bits, mem_bits)
        self.insn_bits = 128 if need <= 128 else 256
        self.insn_words = self.insn_bits // 64

    @property
    def insn_bytes(self) -> int:
        return self.insn_bits // 8

    # ---- encode ----
    def encode(self, insn: Insn) -> Tuple[int, ...]:
        p = _Packer(self.insn_bits)
        p.put(insn.opcode, self.OPCODE_BITS, "opcode")
        d = insn.dep
        p.put(d.pop_prev, 1); p.put(d.pop_next, 1)
        p.put(d.push_prev, 1); p.put(d.push_next, 1)
        if isinstance(insn, LoadStoreInsn):
            p.put(insn.memory_type, self.MEMID_BITS, "memory_type")
            p.put(insn.sram_base, self.sram_addr_bits, "sram_base")
            p.put(insn.dram_base, self.DRAM_ADDR_BITS, "dram_base")
            p.put(insn.y_size, self.SIZE_BITS, "y_size")
            p.put(insn.x_size, self.SIZE_BITS, "x_size")
            p.put(insn.x_stride, self.STRIDE_BITS, "x_stride")
            p.put(insn.y_pad_0, self.PAD_BITS, "y_pad_0")
            p.put(insn.y_pad_1, self.PAD_BITS, "y_pad_1")
            p.put(insn.x_pad_0, self.PAD_BITS, "x_pad_0")
            p.put(insn.x_pad_1, self.PAD_BITS, "x_pad_1")
        elif isinstance(insn, GemmInsn):
            p.put(insn.reset, 1, "reset")
            p.put(insn.uop_bgn, self.uop_addr_bits, "uop_bgn")
            p.put(insn.uop_end, self.uop_addr_bits, "uop_end")
            p.put(insn.iter_out, self.LOOP_BITS, "iter_out")
            p.put(insn.iter_in, self.LOOP_BITS, "iter_in")
            p.put(insn.dst_factor_out, self.factor_bits, "dst_factor_out")
            p.put(insn.dst_factor_in, self.factor_bits, "dst_factor_in")
            p.put(insn.src_factor_out, self.factor_bits, "src_factor_out")
            p.put(insn.src_factor_in, self.factor_bits, "src_factor_in")
            p.put(insn.wgt_factor_out, self.factor_bits, "wgt_factor_out")
            p.put(insn.wgt_factor_in, self.factor_bits, "wgt_factor_in")
        elif isinstance(insn, AluInsn):
            p.put(insn.reset, 1, "reset")
            p.put(insn.uop_bgn, self.uop_addr_bits, "uop_bgn")
            p.put(insn.uop_end, self.uop_addr_bits, "uop_end")
            p.put(insn.iter_out, self.LOOP_BITS, "iter_out")
            p.put(insn.iter_in, self.LOOP_BITS, "iter_in")
            p.put(insn.dst_factor_out, self.factor_bits, "dst_factor_out")
            p.put(insn.dst_factor_in, self.factor_bits, "dst_factor_in")
            p.put(insn.src_factor_out, self.factor_bits, "src_factor_out")
            p.put(insn.src_factor_in, self.factor_bits, "src_factor_in")
            p.put(insn.alu_opcode, self.ALUOP_BITS, "alu_opcode")
            p.put(insn.use_imm, 1, "use_imm")
            p.put(np.uint16(np.int16(insn.imm)), self.IMM_BITS, "imm")
        elif isinstance(insn, FinishInsn):
            pass
        else:
            raise TypeError(type(insn))
        mask = (1 << 64) - 1
        return tuple((p.value >> (64 * i)) & mask
                     for i in range(self.insn_words))

    # ---- decode ----
    def decode(self, *words: int) -> Insn:
        value = 0
        for i, w in enumerate(words):
            value |= int(w) << (64 * i)
        u = _Unpacker(value)
        opcode = Opcode(u.get(self.OPCODE_BITS))
        dep = DepFlags(bool(u.get(1)), bool(u.get(1)), bool(u.get(1)), bool(u.get(1)))
        if opcode in (Opcode.LOAD, Opcode.STORE):
            return LoadStoreInsn(
                opcode=opcode, dep=dep,
                memory_type=MemId(u.get(self.MEMID_BITS)),
                sram_base=u.get(self.sram_addr_bits),
                dram_base=u.get(self.DRAM_ADDR_BITS),
                y_size=u.get(self.SIZE_BITS),
                x_size=u.get(self.SIZE_BITS),
                x_stride=u.get(self.STRIDE_BITS),
                y_pad_0=u.get(self.PAD_BITS), y_pad_1=u.get(self.PAD_BITS),
                x_pad_0=u.get(self.PAD_BITS), x_pad_1=u.get(self.PAD_BITS),
            )
        if opcode == Opcode.GEMM:
            return GemmInsn(
                dep=dep, reset=bool(u.get(1)),
                uop_bgn=u.get(self.uop_addr_bits), uop_end=u.get(self.uop_addr_bits),
                iter_out=u.get(self.LOOP_BITS), iter_in=u.get(self.LOOP_BITS),
                dst_factor_out=u.get(self.factor_bits), dst_factor_in=u.get(self.factor_bits),
                src_factor_out=u.get(self.factor_bits), src_factor_in=u.get(self.factor_bits),
                wgt_factor_out=u.get(self.factor_bits), wgt_factor_in=u.get(self.factor_bits),
            )
        if opcode == Opcode.ALU:
            return AluInsn(
                dep=dep, reset=bool(u.get(1)),
                uop_bgn=u.get(self.uop_addr_bits), uop_end=u.get(self.uop_addr_bits),
                iter_out=u.get(self.LOOP_BITS), iter_in=u.get(self.LOOP_BITS),
                dst_factor_out=u.get(self.factor_bits), dst_factor_in=u.get(self.factor_bits),
                src_factor_out=u.get(self.factor_bits), src_factor_in=u.get(self.factor_bits),
                alu_opcode=AluOp(u.get(self.ALUOP_BITS)),
                use_imm=bool(u.get(1)),
                imm=int(np.int16(np.uint16(u.get(self.IMM_BITS)))),
            )
        if opcode == Opcode.FINISH:
            return FinishInsn(dep=dep)
        raise ValueError(opcode)

    # ---- stream helpers ----
    def encode_stream(self, insns: List[Insn]) -> np.ndarray:
        out = np.zeros((len(insns), self.insn_words), dtype=np.uint64)
        for i, insn in enumerate(insns):
            for j, w in enumerate(self.encode(insn)):
                out[i, j] = np.uint64(w)
        return out

    def decode_stream(self, buf: np.ndarray) -> List[Insn]:
        return [self.decode(*(int(buf[i, j]) for j in range(buf.shape[1])))
                for i in range(buf.shape[0])]


def route_queue(insn: Insn) -> int:
    """fetch-module routing rule (§2.4): which command queue an instruction
    is pushed to.  LOADs of UOP/ACC data go to the *compute* queue; LOADs of
    INP/WGT go to the *load* queue; STOREs go to the store queue."""
    if isinstance(insn, LoadStoreInsn):
        if insn.opcode == Opcode.STORE:
            return STORE_Q
        if insn.memory_type in (MemId.INP, MemId.WGT):
            return LOAD_Q
        return COMPUTE_Q  # UOP / ACC loads execute on the compute module
    return COMPUTE_Q      # GEMM / ALU / FINISH
