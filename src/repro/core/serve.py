"""Async multi-stream serving: device pools, submit/wait futures, and
sharded batch dispatch.

The paper's task-ISA "explicitly orchestrates concurrent compute and
memory tasks" inside one device; this module orchestrates concurrency
ACROSS devices, which is how the runtime the paper sketches (and TVM's,
arXiv 1802.04799) serves real traffic: a compiled program is staged once,
cloned onto a pool of devices, and requests stream through an async
submit()/wait() API.

  * :class:`DevicePool` — N cloned, pre-staged devices serving one
    CompiledProgram **or a co-staged program mix**
    (``program.compile_multi``: every program occupies a disjoint
    ``ImageRange`` of ONE resident image, so a single slot clone holds
    the whole heterogeneous mix with every baked address valid).
    ``Device.clone(trim=True)`` of the staged image means streams,
    constants and the recycled intermediate arenas are already in DRAM,
    and a slot can never allocate — the zero-per-call-DRAM serving
    contract, enforced per slot by construction.  Requests are assigned
    to slot queues at submit time by a round-robin or least-loaded
    policy.

  * a **worker-scheduler** (one thread) that advances every in-flight
    request step by step: host segments are dispatched to a host
    executor thread FIRST, then the accelerator segments of the other
    requests run — so one request's host work overlaps another's
    accelerator work — and requests sitting at the SAME program's SAME
    accelerator segment execute as one lockstep **gang**
    (:meth:`PallasBackend.execute_gang`): every kernel launch batches
    the peer tiles of all gang members, so aggregate calls/sec scales
    with pool size instead of with the GIL.  Different programs never
    gang (their streams differ); the continuous-batching admission
    layer (``core.sched``) exists to park and release same-program
    requests together so gangs actually form under open-loop traffic.

  * :class:`BatchServer` — shards a batch of requests across the pool
    and gathers results in submission order.

  * :class:`Session` — persistent-state serving (``Program.persistent``
    buffers: KV caches, recurrent state).  ``pool.session()`` pins a
    session to one slot; its submits run in order on that slot, each
    call advancing the session's state in the slot's DRAM.  When several
    sessions share a slot the scheduler swaps the resident state — raw
    DRAM reads/writes at the stable persistent addresses, never an
    allocation, so the trimmed-clone zero-alloc contract survives
    arbitrary session interleavings.  Residency is tracked per program:
    sessions of co-staged programs live at disjoint addresses and never
    evict each other.

Failure is loud, never a hang: a worker exception or a dead slot fails
the waiting future (the error carries the request id), the scheduler and
host-worker threads are watchdogged against each other, and
:meth:`DevicePool.kill_slot` is the chaos hook the regression suite uses
to prove it — every request parked on or active in a killed slot raises
:class:`SlotDied` immediately.

Failure is also RECOVERABLE (the self-healing plane), opt-in per pool:

  * **slot respawn** (``max_respawns``) — a killed slot is rebuilt from
    the CompiledProgram's pristine staged image (the same
    ``Device.clone(trim=True)`` path used at construction) and rejoins
    the rotation; ``SlotStats.deaths``/``respawns`` account every event.
    Past the cap the slot stays dead and its recoverable sessions are
    re-homed to a surviving slot.
  * **session checkpoint/restore** (``checkpoint_every``) — every N
    completed calls a session's persistent bytes are snapshotted to host
    memory via ``persistent_image``; when its slot dies the session
    transparently restores the last snapshot onto the respawned (or
    re-homed) slot, and ``SessionStats.restored_from_step`` makes the
    replayed steps visible — never silent.  A session with no snapshot
    to fall back on is marked lost and fails typed at the next submit.
  * **stateless request retry** (``retries``) — a sessionless request
    killed by :class:`SlotDied` or the segment watchdog is re-submitted
    to a surviving slot with exponential backoff (idempotent: staging is
    per-request, inputs are retained).  Exhaustion surfaces the ORIGINAL
    typed error annotated with the attempt count
    (``PoolFuture.attempts``).
  * **segment watchdog** (``watchdog=WatchdogConfig(...)``) — every
    scheduler round gets a wall-clock deadline derived from the
    calibrated TimingModel (cycles / freq, times a generous multiplier,
    floored); a hung gang or host fn gets its slot killed — and the
    requests failed or retried — rather than hanging ``wait()`` forever.
  * **DRAM integrity** (``integrity=True``) — CRC32 checksums over the
    constant regions are verified before every gang (and over persistent
    regions after every stateful call); a mismatch — e.g. an injected
    bit-flip — triggers restage-from-pristine / restore-from-checkpoint
    instead of computing on corrupted bits.
  * **fault injection** (``fault_plan=chaos.FaultPlan(...)``) — a seeded
    script of kills / bit-flips / delays applied at gang boundaries, the
    hook the chaos fuzzer flavor and ``benchmarks/bench_chaos.py`` drive.

The simulator engine has no gang mode; a pool over ``backend=
"simulator"`` runs its slots serially and acts as the concurrency
oracle: the differential suite byte-diffs every pooled execution against
serial single-device runs on both engines.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .backend import BackendLike, resolve_backend
from .chaos import FaultPlan
from .compiler import AccelStep, CpuStep
from .isa import IsaLayout
from .program import CompiledProgram
from .simulator import TimingModel, replay_timing

POLICIES = ("round_robin", "least_loaded")


class PoolClosed(RuntimeError):
    pass


class SlotDied(RuntimeError):
    """A pool slot died (killed or crashed) with requests parked on or
    active in it; every affected future raises this, carrying the
    request id — never a silent hang."""
    pass


class WaitTimeout(TimeoutError):
    """``PoolFuture.wait(timeout=)`` lapsed before the request resolved
    — e.g. a forgotten future whose dispatcher died.  Carries the
    request id; a TimeoutError subclass, so callers catching the plain
    type keep working."""
    pass


class WatchdogTimeout(RuntimeError):
    """A scheduler round overran its TimingModel-derived wall-clock
    deadline: the hung slot was killed and its requests failed (or
    retried) with this — ``wait()`` never hangs on a wedged gang or
    host fn."""
    pass


class IntegrityError(RuntimeError):
    """A DRAM integrity checksum mismatched: a constant or persistent
    region was corrupted (e.g. an injected bit-flip) and could not be
    repaired from the pristine image or a session checkpoint."""
    pass


@dataclass(frozen=True)
class WatchdogConfig:
    """Segment-watchdog knobs.  The per-round deadline is
    ``floor_s + mult * predicted_wall`` where predicted_wall prices each
    distinct accelerator segment in the round on the calibrated
    TimingModel (``replay_timing`` cycles / spec frequency).  `mult` is
    deliberately generous — the interpret-mode engines run far behind
    the hardware model — and `floor_s` bounds it below so host segments
    (unpriceable) and jit warm-up never false-positive."""
    mult: float = 50.0
    floor_s: float = 5.0
    poll_s: float = 0.05

    def __post_init__(self):
        if self.mult <= 0 or self.floor_s <= 0 or self.poll_s <= 0:
            raise ValueError("watchdog mult/floor_s/poll_s must be > 0")


# ----------------------------------------------------------------------
# futures
# ----------------------------------------------------------------------
class PoolFuture:
    """Handle to one submitted request.  ``wait()`` blocks until the
    scheduler finishes the request (in any order relative to other
    futures — waits may be out of submission order) and returns the
    program outputs; request-local stats ride on the future, never on
    shared CompiledProgram state.  Errors propagate: a worker exception
    or slot death raises here (annotated with the request id), it never
    strands the waiter."""

    def __init__(self, slot_id: int, seq: int):
        self.slot_id = slot_id          # which pool slot serves it
        #                                 (re-homed if the request retries)
        self.seq = seq                  # global submission order
        self.stats: List[RunStats] = []  # per accel segment, this request
        self.staging_bytes = 0
        self.attempts = 1               # submissions tried (retries + 1)
        self.done_at: Optional[float] = None  # perf_counter at completion
        self._done = threading.Event()
        self._outputs: Any = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None
             ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        if not self._done.wait(timeout):
            raise WaitTimeout(
                f"request #{self.seq} (slot {self.slot_id}) not done "
                f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._outputs

    result = wait

    # scheduler side; first outcome wins — a request can be failed by
    # kill_slot while its last gang is still retiring, and the late
    # result must not overwrite the death notice (or vice versa)
    def _finish(self, outputs: Any) -> bool:
        if self._done.is_set():
            return False
        self._outputs = outputs
        self.done_at = time.perf_counter()
        self._done.set()
        return True

    def _fail(self, exc: BaseException) -> bool:
        if self._done.is_set():
            return False
        if hasattr(exc, "add_note"):             # 3.11+: carry the id
            try:
                exc.add_note(f"[pool request #{self.seq} on slot "
                             f"{self.slot_id}]")
            except TypeError:                    # pragma: no cover
                pass
        self._exc = exc
        self.done_at = time.perf_counter()
        self._done.set()
        return True


@dataclass
class SlotStats:
    """Cumulative serving counters of one pool slot (touched only by the
    scheduler thread — per-slot by construction, so concurrent requests
    cannot cross-contaminate them)."""
    calls: int = 0
    staging_bytes: int = 0
    accel_steps: int = 0
    cpu_steps: int = 0
    ganged_steps: int = 0           # accel steps executed in a gang > 1
    max_gang: int = 0               # widest gang this slot took part in
    queue_hiwater: int = 0          # deepest the slot's submit queue got
    tiles_resolved: int = 0
    tile_batches: int = 0
    # persistent-state serving: resident-session swaps performed on this
    # slot, and the high-water of persistent bytes this slot has held
    # for its sessions (resident + swapped-out store)
    session_swaps: int = 0
    persist_hiwater: int = 0
    # self-healing: kill_slot/watchdog/integrity events on this slot
    deaths: int = 0                 # times this slot was declared dead
    respawns: int = 0               # times it was rebuilt from pristine
    watchdog_kills: int = 0         # deaths caused by the watchdog
    integrity_restages: int = 0     # corrupted regions repaired


@dataclass
class SessionStats:
    """Recovery counters of one session (scheduler/kill paths only).
    ``restored_from_step`` makes replayed decode steps VISIBLE: after a
    restore the caller must re-drive steps restored_from_step..lost-1 —
    silent replay would double-advance external state."""
    checkpoints: int = 0            # snapshots taken
    checkpoint_step: int = -1       # calls-count the last snapshot holds
    restores: int = 0               # times state was restored after death
    restored_from_step: Optional[int] = None  # step the last restore hit
    rehomes: int = 0                # moved to a new slot (old one stayed
    #                                 dead past the respawn cap)


@dataclass
class _Slot:
    id: int
    device: Any
    stats: SlotStats = field(default_factory=SlotStats)
    queue: List["_Request"] = field(default_factory=list)
    active: Optional["_Request"] = None
    dead: bool = False
    # per-program residency: prog key -> sid of the session whose
    # persistent state is materialized in this slot's DRAM (absent:
    # virgin init state / slot-resident mode).  Co-staged programs have
    # disjoint persistent addresses, so their residents never collide.
    resident: Dict[int, int] = field(default_factory=dict)
    # serializes session swap-in/swap-out against kill/respawn: a swap
    # holds it for the whole read-modify-write, kill_slot's respawn
    # acquires it before yanking the device — no half-swapped sessions.
    # Lock order: pool._lock may be held when taking swap_lock, never
    # the reverse.
    swap_lock: threading.Lock = field(default_factory=threading.Lock)
    # integrity: last recorded post-call checksum of each program's
    # persistent regions (prog key -> crc), when the pool records them
    persist_crc: Dict[int, int] = field(default_factory=dict)

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self.active is not None else 0)


@dataclass
class _SessionState:
    """Pool-internal record of one session: its program, sticky slot
    and, when NOT resident there, the swapped-out raw persistent
    image.  `ckpt` is the periodic host-memory snapshot the recovery
    path restores from when the slot dies with the state resident."""
    sid: int
    slot_id: int
    prog: CompiledProgram
    image: Optional[Dict[str, np.ndarray]] = None
    calls: int = 0
    ckpt: Optional[Dict[str, np.ndarray]] = None
    ckpt_step: int = -1
    lost: bool = False              # died resident with no checkpoint
    stats: SessionStats = field(default_factory=SessionStats)


@dataclass
class _Request:
    future: PoolFuture
    inputs: Dict[str, np.ndarray]
    prog: CompiledProgram
    step_idx: int = -1              # -1: inputs not yet staged
    session: Optional[_SessionState] = None
    retired: bool = False           # future resolved + inflight released
    # stateless-retry bookkeeping: original inputs kept for restaging
    # (only when the pool retries), first typed error to surface on
    # exhaustion, and submissions tried so far
    saved_inputs: Optional[Dict[str, np.ndarray]] = None
    first_error: Optional[BaseException] = None
    attempts: int = 1


class Session:
    """Handle to one persistent-state serving session on a DevicePool.

        sess = pool.session()
        for tok in prompt:
            y = sess.submit(x=tok).wait()    # state advances in DRAM

    Submits are sticky to one slot and run in submission order there;
    sessions sharing a slot are transparently swapped by the scheduler.
    ``state()``/``reset()`` inspect or rewind the session — call them
    only while the session has no in-flight requests (``pool.drain()``)."""

    def __init__(self, pool: "DevicePool", state: _SessionState):
        self.pool = pool
        self._state = state

    @property
    def sid(self) -> int:
        return self._state.sid

    @property
    def slot_id(self) -> int:
        return self._state.slot_id

    @property
    def calls(self) -> int:
        return self._state.calls

    @property
    def stats(self) -> SessionStats:
        """Recovery counters — ``restored_from_step`` is not None iff
        the session came back from a checkpoint after its slot died, in
        which case the caller must replay steps from there."""
        return self._state.stats

    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        return self.pool._enqueue(inputs, session=self._state,
                                  prog=self._state.prog)

    def state(self, name: str) -> np.ndarray:
        """Logical value of one persistent buffer as this session sees it
        (resident slot DRAM, swapped-out image, or the init image if the
        session never ran)."""
        return self.pool._session_state(self._state, name)

    def reset(self) -> None:
        """Rewind to the compile-time init images (a fresh dialogue on
        the same session handle)."""
        self.pool._session_reset(self._state)


# ----------------------------------------------------------------------
# the pool
# ----------------------------------------------------------------------
class DevicePool:
    """N cloned pre-staged devices serving one CompiledProgram — or a
    co-staged mix of them — through an async submit()/wait() API.

    Parameters
    ----------
    compiled: the staged artifact (``prestage=True`` recommended —
        trimmed slot clones cannot allocate DRAM), or a SEQUENCE of
        artifacts produced by ``program.compile_multi``: they share one
        device image at disjoint DRAM ranges, and the pool serves the
        whole mix.  ``submit()`` targets the first program;
        ``submit_to(program, ...)`` targets any of them.  Only
        same-program same-segment requests gang.
    size: number of device slots.
    backend: engine every request runs on ("pallas" gangs lockstep
        requests; "simulator" is the serial oracle).  One engine
        instance is shared by the whole pool so jit/decode caches warm
        once.
    policy: "round_robin" assigns submits to slots cyclically;
        "least_loaded" picks the slot with the fewest queued + running
        requests (ties to the lowest slot id).
    trim: clone only the allocated DRAM image per slot (MemoryError on
        any per-call allocation instead of silent growth).  Defaults to
        every program being prestaged — a restaging (prestage=False)
        program legitimately allocates its stream every call and needs
        the full address space.
    max_respawns: per-slot cap on automatic rebuilds after kill_slot /
        watchdog death (0: deaths are terminal, the pre-recovery
        behavior).  A respawned slot is a fresh ``clone(trim)`` of the
        pristine staged image; resident session state is restored from
        checkpoints (see ``checkpoint_every``).
    retries: bounded automatic re-submission of STATELESS requests
        failed by SlotDied/WatchdogTimeout (0: fail immediately).
        Exponential backoff from ``retry_backoff_s``; exhaustion raises
        the original error annotated with the attempt count.
    checkpoint_every: snapshot each session's persistent bytes to host
        memory every N completed calls (0: never).  The snapshot is what
        a dead slot's resident session restores from.
    integrity: verify constant-region CRCs before every gang (repairing
        from the pristine image) and record/verify persistent-region
        CRCs across stateful calls.
    watchdog: a :class:`WatchdogConfig` arms the segment watchdog —
        rounds that overrun their TimingModel-derived deadline get the
        offending slots killed instead of hanging ``wait()``.
    fault_plan: a seeded :class:`chaos.FaultPlan` applied at gang
        boundaries (kills, constant bit-flips, delays) — the chaos
        harness hook.
    """

    def __init__(self, compiled: Union[CompiledProgram,
                                       Sequence[CompiledProgram]],
                 size: int = 2,
                 backend: BackendLike = "pallas",
                 policy: str = "round_robin", timing: Any = None,
                 trim: Optional[bool] = None,
                 max_respawns: int = 0,
                 retries: int = 0,
                 retry_backoff_s: float = 0.05,
                 checkpoint_every: int = 0,
                 integrity: bool = False,
                 watchdog: Optional[WatchdogConfig] = None,
                 fault_plan: Optional[FaultPlan] = None):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size}")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if max_respawns < 0 or retries < 0 or checkpoint_every < 0:
            raise ValueError("max_respawns/retries/checkpoint_every "
                             "must be >= 0")
        if retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        progs = (list(compiled)
                 if isinstance(compiled, (list, tuple)) else [compiled])
        if not progs:
            raise ValueError("DevicePool of zero programs")
        dev = progs[0].device
        for c in progs[1:]:
            if c.device is not dev:
                raise ValueError(
                    "multi-program pools require co-staged programs "
                    "(program.compile_multi) — these were compiled onto "
                    "different devices, their DRAM images cannot merge")
        if trim is None:
            trim = all(c.prestage for c in progs)
        self.programs: List[CompiledProgram] = progs
        self.compiled = progs[0]            # default-submit target
        self._prog_key = {id(c): i for i, c in enumerate(progs)}
        self.engine = resolve_backend(backend)
        self.policy = policy
        self.timing = timing
        self.max_respawns = max_respawns
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s
        self.checkpoint_every = checkpoint_every
        self.integrity = integrity
        self.watchdog = watchdog
        self.fault_plan = fault_plan
        self.fault_log: List[Dict[str, Any]] = []
        self._dev = dev                 # pristine staged image: the
        self._trim = trim               # respawn + restage source
        self.slots = [_Slot(id=i, device=dev.clone(trim=trim))
                      for i in range(size)]
        self._rr = itertools.cycle(range(size))
        self._seq = itertools.count()
        self._gang_seq = itertools.count()  # fault-plan clock
        self._sessions: Dict[int, _SessionState] = {}
        self._session_seq = itertools.count()
        self._session_rr = itertools.cycle(range(size))
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._closed = False
        self._inflight = 0
        self._idle = threading.Condition(self._lock)
        # stateless retries awaiting their backoff: (due_at, request)
        self._retries: List[Tuple[float, _Request]] = []
        # pristine constant-region checksums (identical for every slot
        # by construction — clones of one image)
        self._const_crc: List[Optional[int]] = [
            (c.integrity_checksum(device=dev)
             if integrity and c.integrity_regions() else None)
            for c in progs]
        # watchdog round state (written by the scheduler thread, read by
        # the watchdog thread; transitions re-checked under _lock)
        self._round_id = 0
        self._round_deadline: Optional[float] = None
        self._round_watch: set = set()      # slot ids still owing work
        self._round_had_host = False
        self._round_abandoned = -1          # last round the watchdog shot
        self._budget_cache: Dict[Tuple[int, int], float] = {}
        # persistent host worker: one long-lived thread consuming host
        # segment batches, so the hot serving path never pays per-round
        # thread creation
        self._host_q: "queue.Queue[Any]" = queue.Queue()
        self._host_thread = threading.Thread(
            target=self._run_host_worker, name="repro-pool-host",
            daemon=True)
        self._host_thread.start()
        self._scheduler = threading.Thread(
            target=self._run_scheduler, name="repro-pool-scheduler",
            daemon=True)
        self._scheduler.start()
        if watchdog is not None:
            self._watchdog_thread = threading.Thread(
                target=self._run_watchdog, name="repro-pool-watchdog",
                daemon=True)
            self._watchdog_thread.start()

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.slots)

    def __enter__(self) -> "DevicePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _resolve_prog(self, program: Union[None, int, CompiledProgram]
                      ) -> CompiledProgram:
        if program is None:
            return self.compiled
        if isinstance(program, int):
            return self.programs[program]
        if id(program) not in self._prog_key:
            raise ValueError("program was not staged on this pool "
                             "(co-stage it with program.compile_multi)")
        return program

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        """Enqueue one request against the pool's first (default)
        program; returns immediately with a future.  Thread-safe: any
        thread may submit, waits may happen in any order.  Input arrays
        are validated here (fail fast, in the caller) and staged into
        the slot's DRAM by the scheduler.  For a program with persistent
        state, sessionless submits run in slot-resident mode (each slot
        IS one implicit session); use :meth:`session` for explicit,
        swappable sessions."""
        return self._enqueue(inputs, session=None, prog=self.compiled)

    def submit_to(self, program: Union[int, CompiledProgram],
                  **inputs: np.ndarray) -> PoolFuture:
        """Enqueue one request against a specific co-staged program
        (index into ``self.programs`` or the artifact itself)."""
        return self._enqueue(inputs, session=None,
                             prog=self._resolve_prog(program))

    def _pick_slot(self, session: Optional[_SessionState],
                   avoid: frozenset = frozenset()) -> _Slot:
        """Pick the serving slot (lock held).  Dead slots are skipped;
        a session stays pinned and raises if its slot died.  `avoid`
        lists slots already claimed by the same atomic batch — prefer
        spreading a batch over distinct slots (so it can gang), falling
        back to doubling up only when the batch outsizes the pool."""
        if session is not None:
            if session.lost:
                raise SlotDied(
                    f"session {session.sid}'s state was lost when slot "
                    f"{session.slot_id} died with no checkpoint to "
                    f"restore from (checkpoint_every=0?)")
            slot = self.slots[session.slot_id]   # sticky: state lives
            if slot.dead:                        # (or swaps) there
                raise SlotDied(f"session {session.sid}'s slot "
                               f"{slot.id} died")
            return slot
        alive = [s for s in self.slots if not s.dead]
        if not alive:
            raise PoolClosed("every pool slot is dead")
        if self.policy == "round_robin":
            for prefer_fresh in (True, False):
                for _ in range(len(self.slots)):
                    slot = self.slots[next(self._rr)]
                    if slot.dead:
                        continue
                    if prefer_fresh and slot.id in avoid:
                        continue
                    return slot
            raise PoolClosed("every pool slot is dead")  # pragma: no cover
        fresh = [s for s in alive if s.id not in avoid] or alive
        return min(fresh, key=lambda s: (s.load, s.id))

    def _enqueue(self, inputs: Dict[str, np.ndarray],
                 session: Optional[_SessionState],
                 prog: CompiledProgram) -> PoolFuture:
        return self._enqueue_batch([(inputs, session, prog)])[0]

    def submit_batch(self, program: Union[None, int, CompiledProgram],
                     requests: Sequence[Dict[str, np.ndarray]]
                     ) -> List[PoolFuture]:
        """Enqueue several requests of one program ATOMICALLY: the
        scheduler observes all of them at the same admission point, so
        on an idle pool they land on distinct slots in the same round
        and stay lockstep (a gang) for the whole program.  Sequential
        ``submit()`` calls race the scheduler's round loop and can
        stagger — this is the release primitive the admission window
        (``core.sched``) is built on."""
        prog = self._resolve_prog(program)
        return self._enqueue_batch([(dict(r), None, prog)
                                    for r in requests])

    def _enqueue_batch(self, items: Sequence[Tuple[Dict[str, np.ndarray],
                                                   Optional[_SessionState],
                                                   CompiledProgram]]
                       ) -> List[PoolFuture]:
        for inputs, _, prog in items:
            prog.check_inputs(inputs)
        futs: List[PoolFuture] = []
        with self._lock:
            if self._closed:
                raise PoolClosed("submit() on a closed DevicePool")
            # validate before enqueuing anything: a mid-batch failure
            # must not leave a half-admitted gang behind
            for _, session, _ in items:
                if session is not None:
                    if session.lost:
                        raise SlotDied(
                            f"session {session.sid}'s state was lost "
                            f"when slot {session.slot_id} died with no "
                            f"checkpoint to restore from")
                    if self.slots[session.slot_id].dead:
                        raise SlotDied(f"session {session.sid}'s slot "
                                       f"{session.slot_id} died")
            if all(s.dead for s in self.slots):
                raise PoolClosed("every pool slot is dead")
            used: set = set()
            for inputs, session, prog in items:
                slot = self._pick_slot(session, avoid=frozenset(used))
                used.add(slot.id)
                fut = PoolFuture(slot_id=slot.id, seq=next(self._seq))
                slot.queue.append(_Request(
                    future=fut, inputs=dict(inputs), prog=prog,
                    session=session,
                    # stateless retry needs the original inputs back for
                    # idempotent restaging on a fresh slot; slot-resident
                    # stateful submits never retry (a replay would
                    # double-advance the implicit per-slot state)
                    saved_inputs=(dict(inputs)
                                  if self.retries and session is None
                                  and not prog.persistent_ids
                                  else None)))
                slot.stats.queue_hiwater = max(slot.stats.queue_hiwater,
                                               len(slot.queue))
                self._inflight += 1
                futs.append(fut)
            self._wake.notify_all()
        return futs

    # ------------------------------------------------------------------
    # sessions (persistent-state serving)
    # ------------------------------------------------------------------
    def session(self, slot: Optional[int] = None,
                program: Union[None, int, CompiledProgram] = None
                ) -> Session:
        """Open a new session: an independent copy of one program's
        persistent state, pinned to one slot (round-robin by default).
        Same-slot sessions are swapped in and out of the slot's DRAM by
        the scheduler; same-step submits of different sessions still
        gang across slots."""
        prog = self._resolve_prog(program)
        with self._lock:
            if self._closed:
                raise PoolClosed("session() on a closed DevicePool")
            sid = next(self._session_seq)
            slot_id = slot if slot is not None else next(self._session_rr)
            if not 0 <= slot_id < len(self.slots):
                raise ValueError(f"slot {slot_id} out of range")
            if self.slots[slot_id].dead:
                raise SlotDied(f"slot {slot_id} is dead")
            st = _SessionState(sid=sid, slot_id=slot_id, prog=prog)
            self._sessions[sid] = st
        return Session(self, st)

    def _ensure_resident(self, slot: _Slot, req: _Request) -> None:
        """Make `req`'s session state resident in `slot` before the
        request stages.  Swaps are raw DRAM reads/writes at the stable
        persistent addresses — NEVER an allocation, so trimmed clones
        stay within the zero-alloc contract.  Residency is per program
        (disjoint address ranges under compile_multi).  Scheduler-thread
        only.

        The whole swap-out/swap-in runs under the slot's swap lock:
        ``kill_slot``'s respawn takes the same lock before yanking the
        device, so a kill landing mid-swap either waits for a COMPLETE
        swap (then recovers the now-resident session from its
        checkpoint) or finishes first (then this raises SlotDied before
        touching any byte) — a session can never end up half-swapped or
        marked resident on a device that does not hold its state."""
        sess = req.session
        if sess is None or not sess.prog.persistent_ids:
            return
        with slot.swap_lock:
            if slot.dead:
                raise SlotDied(f"session {sess.sid}'s slot {slot.id} "
                               f"died before its state could swap in")
            if sess.lost:
                raise SlotDied(
                    f"session {sess.sid}'s state was lost when its slot "
                    f"died with no checkpoint to restore from")
            key = self._prog_key[id(sess.prog)]
            if slot.resident.get(key) == sess.sid:
                return
            old_sid = slot.resident.get(key)
            if old_sid is not None:
                old = self._sessions.get(old_sid)
                if old is not None:
                    old.image = old.prog.persistent_image(
                        device=slot.device)
            if sess.image is not None:
                sess.prog.load_persistent_image(sess.image,
                                                device=slot.device)
                sess.image = None                  # resident now
            else:
                sess.prog.reset_persistent(device=slot.device)
            slot.resident[key] = sess.sid
            slot.persist_crc.pop(key, None)    # snapshot was the OLD
            slot.stats.session_swaps += 1      # resident's bytes
            held = sess.prog.persistent_bytes + sum(
                sum(a.nbytes for a in s.image.values())
                for s in self._sessions.values()
                if s.slot_id == slot.id and s.image is not None)
            slot.stats.persist_hiwater = max(slot.stats.persist_hiwater,
                                             held)

    def _session_state(self, st: _SessionState, name: str) -> np.ndarray:
        prog = st.prog
        key = self._prog_key[id(prog)]
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident.get(key) == st.sid:
                return prog.read_persistent(name, device=slot.device)
            nid = prog.input_ids[name]
            node = prog.nodes[nid]
            if st.image is None:                   # never ran
                return np.array(node.const)
            raw = st.image[name]
            blocked = raw.view(node.meta.np_dtype()).reshape(
                node.meta.blocked_shape(prog.spec))
            return node.meta.unpack(blocked, prog.spec)

    def _session_reset(self, st: _SessionState) -> None:
        key = self._prog_key[id(st.prog)]
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident.get(key) == st.sid:
                st.prog.reset_persistent(device=slot.device)
            else:
                st.image = None
            st.calls = 0

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        with self._lock:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError("DevicePool.drain timed out")

    def kill_slot(self, slot_id: int) -> int:
        """Chaos/ops hook: declare one slot dead NOW.  The mid-flight
        request fails immediately with :class:`SlotDied` (the error
        names the request) — or, with ``retries`` enabled, re-submits
        to a surviving slot after backoff.  QUEUED stateless requests
        never touched the device, so they transplant to a surviving (or
        respawned) slot without burning a retry attempt; queued
        session-bound / implicit-state requests fail typed with their
        state.  The slot leaves the submit rotation and the scheduler
        discards any in-flight result it may still produce.  With ``max_respawns`` the slot is then rebuilt from
        the pristine staged image and rejoins the rotation; resident
        session state restores from checkpoints.  Returns the number of
        requests affected (failed or scheduled for retry).  The
        regression suite kills a slot mid-flight to prove waits raise
        instead of hanging."""
        with self._lock:
            slot = self.slots[slot_id]
            return self._kill_slot_locked(
                slot,
                lambda req: SlotDied(
                    f"request #{req.future.seq} lost: slot {slot_id} "
                    f"died mid-flight"))

    def _kill_slot_locked(self, slot: _Slot, exc_for,
                          watchdog: bool = False) -> int:
        """Shared death path (pool lock held): fail-or-retry every
        victim, recover the slot's sessions, then respawn under the cap
        (else re-home recoverable sessions to a survivor)."""
        if slot.dead:
            return 0
        slot.dead = True
        slot.stats.deaths += 1
        if watchdog:
            slot.stats.watchdog_kills += 1
        queued = list(slot.queue)
        slot.queue.clear()
        active = None
        if slot.active is not None and not slot.active.retired:
            active = slot.active
        slot.active = None
        # recover sessions and respawn FIRST so a rebuilt slot can take
        # transplanted queue entries back
        self._recover_sessions(slot)
        if self.max_respawns and slot.stats.respawns < self.max_respawns:
            self._respawn_locked(slot)
        else:
            self._rehome_sessions(slot)
        n = 0
        now = time.perf_counter()
        # the active request was mid-execution on the dead device: that
        # work is lost, so it burns a retry attempt (or fails typed)
        if active is not None:
            n += 1
            self._fail_or_retry(active, exc_for(active), now)
        # queued requests never touched the device — fully stateless
        # ones keep their place without consuming a retry attempt: on a
        # respawned slot the queue simply survives (balance preserved),
        # on a permanently dead slot they transplant to a survivor.
        # Session-bound and implicit-state requests stay on the death
        # path (their state lived here and may have rolled back)
        for req in queued:
            if req.retired:
                continue
            n += 1
            if req.session is None and not req.prog.persistent_ids:
                if not slot.dead:               # respawned
                    slot.queue.append(req)
                    continue
                try:
                    target = self._pick_slot(None)
                except PoolClosed:
                    self._fail_or_retry(req, exc_for(req), now)
                    continue
                req.future.slot_id = target.id
                target.queue.append(req)
                target.stats.queue_hiwater = max(
                    target.stats.queue_hiwater, len(target.queue))
            else:
                self._fail_or_retry(req, exc_for(req), now)
        self._idle.notify_all()
        self._wake.notify_all()
        return n

    def _fail_or_retry(self, req: _Request, exc: BaseException,
                       now: float) -> None:
        """Fail one victim — or park it for a backoff retry when it is
        stateless, retries remain, and the pool is still open (lock
        held).  Exhaustion surfaces the FIRST typed error, annotated
        with the attempt count."""
        if req.first_error is None:
            req.first_error = exc
        if (req.session is None and req.saved_inputs is not None
                and req.attempts <= self.retries and not self._closed):
            delay = self.retry_backoff_s * (2 ** (req.attempts - 1))
            req.attempts += 1
            req.future.attempts = req.attempts
            req.retired = False
            req.step_idx = -1               # restage from scratch
            req.inputs = dict(req.saved_inputs)
            self._retries.append((now + delay, req))
            return                          # _inflight stays claimed
        req.retired = True
        self._inflight -= 1
        err = req.first_error
        err.attempts = req.attempts         # first-class attempt count
        if req.attempts > 1 and hasattr(err, "add_note"):
            try:
                err.add_note(f"[failed after {req.attempts} attempts]")
            except TypeError:               # pragma: no cover
                pass
        req.future.attempts = req.attempts
        req.future._fail(err)

    def _promote_retries(self, now: float) -> None:
        """Move due retries onto surviving slots' queues (lock held;
        scheduler thread).  A closing pool promotes everything
        immediately — close() waits for in-flight work, and backoff
        would only delay the inevitable."""
        if not self._retries:
            return
        keep: List[Tuple[float, _Request]] = []
        for due, req in self._retries:
            if due > now and not self._closed:
                keep.append((due, req))
                continue
            try:
                slot = self._pick_slot(None)
            except PoolClosed:
                req.retired = True
                self._inflight -= 1
                err = req.first_error or PoolClosed(
                    f"request #{req.future.seq}: every slot died before "
                    f"its retry could run")
                err.attempts = req.attempts
                if hasattr(err, "add_note"):
                    try:
                        err.add_note(
                            f"[failed after {req.attempts} attempts]")
                    except TypeError:       # pragma: no cover
                        pass
                req.future.attempts = req.attempts
                req.future._fail(err)
                self._idle.notify_all()
                continue
            req.future.slot_id = slot.id    # re-home the handle
            slot.queue.append(req)
            slot.stats.queue_hiwater = max(slot.stats.queue_hiwater,
                                           len(slot.queue))
        self._retries = keep

    def _recover_sessions(self, slot: _Slot) -> None:
        """Death handling for the slot's sessions (lock held).  Swapped-
        out sessions keep their host-memory image untouched; RESIDENT
        sessions lose their live DRAM state with the slot and fall back
        to the last checkpoint (visible via ``restored_from_step``), to
        virgin init if they never ran, or are marked lost — a typed
        SlotDied at their next submit, never silently-wrong state."""
        for sess in self._sessions.values():
            if sess.slot_id != slot.id or sess.lost:
                continue
            key = self._prog_key[id(sess.prog)]
            if slot.resident.get(key) != sess.sid:
                continue                    # swapped out: image survives
            if sess.ckpt is not None:
                sess.image = {k: v.copy() for k, v in sess.ckpt.items()}
                sess.calls = sess.ckpt_step
                sess.stats.restores += 1
                sess.stats.restored_from_step = sess.ckpt_step
            elif sess.calls == 0:
                sess.image = None           # virgin: reinit on next use
            else:
                sess.lost = True
        slot.resident.clear()
        slot.persist_crc.clear()

    def _respawn_locked(self, slot: _Slot) -> None:
        """Rebuild a dead slot from the pristine staged image (lock
        held).  Takes the swap lock so an in-flight session swap fully
        completes on the old device before it is replaced."""
        with slot.swap_lock:
            slot.device = self._dev.clone(trim=self._trim)
            slot.active = None
            slot.dead = False
            slot.stats.respawns += 1

    def _rehome_sessions(self, slot: _Slot) -> None:
        """The slot stayed dead (respawn cap exhausted): move its
        recoverable sessions to the least-loaded survivor so their
        checkpoint/image state keeps serving (lock held)."""
        alive = [s for s in self.slots if not s.dead]
        if not alive:
            return
        for sess in self._sessions.values():
            if sess.slot_id != slot.id or sess.lost:
                continue
            target = min(alive, key=lambda s: (s.load, s.id))
            sess.slot_id = target.id
            sess.stats.rehomes += 1

    def respawn_slot(self, slot_id: int) -> bool:
        """Ops hook: explicitly rebuild a dead slot from the pristine
        image, ignoring the automatic ``max_respawns`` cap (an operator
        deciding to revive is not a crash loop).  Returns True if the
        slot was dead and came back."""
        with self._lock:
            slot = self.slots[slot_id]
            if not slot.dead:
                return False
            self._respawn_locked(slot)
            self._wake.notify_all()
            return True

    # ------------------------------------------------------------------
    # segment watchdog
    # ------------------------------------------------------------------
    def _accel_step_seconds(self, prog: CompiledProgram, pk: int,
                            idx: int) -> float:
        """Predicted wall seconds of one accelerator segment: decode the
        stream, replay it on the TimingModel, convert cycles at the
        PROGRAM's spec frequency — replayed cycles are in the spec's
        clock domain, so any other rate is off by the frequency ratio
        (a re-fitted/calibrated spec would get spuriously tight or
        never-firing deadlines).  The interpret-mode slowdown is what
        ``WatchdogConfig.mult``/``floor_s`` pad for.  Cached per
        (program, step): decode + replay run once per pool lifetime."""
        key = (pk, idx)
        got = self._budget_cache.get(key)
        if got is not None:
            return got
        step = prog.steps[idx]
        tm = (self.timing if isinstance(self.timing, TimingModel)
              else TimingModel(prog.spec))
        insns = IsaLayout(prog.spec).decode_stream(
            np.ascontiguousarray(step.stream))
        cycles = replay_timing(prog.spec, insns, tm).total_cycles
        sec = cycles / (prog.spec.freq_mhz * 1e6)
        self._budget_cache[key] = sec
        return sec

    def _run_watchdog(self) -> None:
        """Watchdog thread: when a scheduler round overruns its
        TimingModel-derived deadline, kill every slot still owing work
        (failing or retrying its requests and respawning under the cap)
        and — if the round had host segments — replace the host worker,
        whose thread may be wedged inside a user host fn.  Waiters get
        typed :class:`WatchdogTimeout` errors; nothing hangs."""
        cfg = self.watchdog
        while True:
            time.sleep(cfg.poll_s)
            with self._lock:
                if self._closed and self._inflight == 0:
                    return
                deadline = self._round_deadline
                if deadline is None or time.perf_counter() < deadline:
                    continue
                rid = self._round_id
                self._round_deadline = None
                self._round_abandoned = rid
                stuck = [self.slots[i] for i in set(self._round_watch)]
                for slot in stuck:
                    self._kill_slot_locked(
                        slot,
                        lambda req, _sid=slot.id: WatchdogTimeout(
                            f"request #{req.future.seq}: segment "
                            f"watchdog deadline exceeded on slot "
                            f"{_sid}; slot killed"),
                        watchdog=True)
                if self._round_had_host:
                    # the old worker may be wedged inside a host fn:
                    # orphan it (daemon) and start a fresh one
                    self._host_q = queue.Queue()
                    self._host_thread = threading.Thread(
                        target=self._run_host_worker,
                        name="repro-pool-host", daemon=True)
                    self._host_thread.start()

    # ------------------------------------------------------------------
    # DRAM integrity
    # ------------------------------------------------------------------
    def verify_integrity(self, slot_id: Optional[int] = None,
                         repair: bool = True) -> List[str]:
        """Audit the constant and persistent DRAM regions of every (or
        one) alive slot against their recorded CRC32 checksums.  With
        ``repair`` (the default) corrupted constants restage from the
        pristine image and a corrupted resident session restores from
        its last checkpoint — or is marked lost, failing typed at its
        next submit, never computing on silently-wrong state.  With
        ``repair=False`` a non-empty audit raises
        :class:`IntegrityError`.  Returns the findings (empty = clean).
        Requires the pool to have been built with ``integrity=True``
        (otherwise there are no recorded checksums and the audit is
        vacuous)."""
        findings: List[str] = []
        with self._lock:
            slots = ([self.slots[slot_id]] if slot_id is not None
                     else self.slots)
            for slot in slots:
                if slot.dead:
                    continue
                with slot.swap_lock:
                    for pk, prog in enumerate(self.programs):
                        want = self._const_crc[pk]
                        if want is not None and prog.integrity_checksum(
                                device=slot.device) != want:
                            findings.append(
                                f"slot{slot.id}/prog{pk}: constant "
                                f"region checksum mismatch")
                            if repair:
                                prog.restage_constants(
                                    slot.device, pristine=self._dev)
                                slot.stats.integrity_restages += 1
                        rec = slot.persist_crc.get(pk)
                        if rec is not None and prog.persistent_ids and \
                                prog.integrity_checksum(
                                    device=slot.device,
                                    persistent=True) != rec:
                            findings.append(
                                f"slot{slot.id}/prog{pk}: persistent "
                                f"region checksum mismatch")
                            if repair:
                                self._repair_persistent(slot, pk, prog)
        if findings and not repair:
            raise IntegrityError("; ".join(findings))
        return findings

    def _repair_persistent(self, slot: _Slot, pk: int,
                           prog: CompiledProgram) -> None:
        """Corrupted persistent bytes (lock + swap lock held): restore
        the resident session from its checkpoint, mark it lost if it has
        none, or — slot-resident mode, no session — reset to the
        program's initial state."""
        slot.persist_crc.pop(pk, None)
        sid = slot.resident.get(pk)
        sess = self._sessions.get(sid) if sid is not None else None
        if sess is not None:
            slot.resident.pop(pk, None)
            if sess.ckpt is not None:
                sess.image = {k: v.copy() for k, v in sess.ckpt.items()}
                sess.calls = sess.ckpt_step
                sess.stats.restores += 1
                sess.stats.restored_from_step = sess.ckpt_step
            else:
                sess.lost = True
        else:
            prog.reset_persistent(device=slot.device)

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Reject new submits, let in-flight requests finish, stop the
        scheduler and host-worker threads.  If the scheduler fails to
        drain within `timeout` (a wedged host fn or kernel), every
        still-pending future is FAILED with PoolClosed so no waiter
        blocks forever on a pool that will never answer."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._wake.notify_all()
        self._scheduler.join(timeout)
        if self._scheduler.is_alive():
            err = PoolClosed(
                f"DevicePool.close: scheduler did not drain within "
                f"{timeout}s; failing all pending futures")
            with self._lock:
                for slot in self.slots:
                    pending = list(slot.queue)
                    slot.queue.clear()
                    if slot.active is not None:
                        pending.append(slot.active)
                    for req in pending:
                        if not req.future.done():
                            req.future._fail(err)
                for _, req in self._retries:
                    if not req.future.done():
                        req.future._fail(req.first_error or err)
                self._retries.clear()
        self._host_q.put(None)                  # stop the host worker
        self._host_thread.join(timeout)

    # ------------------------------------------------------------------
    # the worker-scheduler
    # ------------------------------------------------------------------
    def _run_host_worker(self) -> None:
        """Long-lived host-segment executor: the scheduler hands it the
        round's CpuStep batch, then runs the accelerator gangs while the
        host fns execute here — one request's host work overlaps other
        requests' accelerator work (the GIL drops inside the gangs' XLA
        kernels).  ``done.set()`` is unconditional: a raising host fn
        must never leave the scheduler waiting on the round."""
        while True:
            item = self._host_q.get()
            if item is None:
                return
            jobs, host_errs, done = item
            try:
                for slot, device, req, step_idx in jobs:
                    try:
                        if req.retired or req.step_idx != step_idx:
                            continue              # killed/retried
                        step = req.prog.steps[step_idx]
                        try:
                            req.prog.exec_step(step, device, self.engine,
                                               timing=self.timing)
                            slot.stats.cpu_steps += 1
                        except BaseException as e:
                            host_errs[slot.id] = e
                    finally:
                        if self.watchdog is not None:
                            self._round_watch.discard(slot.id)
            finally:
                done.set()

    def _run_scheduler(self) -> None:
        try:
            self._scheduler_loop()
        except BaseException as e:
            # nothing may escape the loop silently: a dead scheduler
            # thread would strand every current AND future waiter, so
            # fail everything in flight loudly before the thread exits
            with self._lock:
                for slot in self.slots:
                    victims = list(slot.queue)
                    slot.queue.clear()
                    if slot.active is not None:
                        victims.append(slot.active)
                        slot.active = None
                    for req in victims:
                        if req.retired:
                            continue
                        req.retired = True
                        self._inflight -= 1
                        req.future._fail(PoolClosed(
                            f"request #{req.future.seq} lost: pool "
                            f"scheduler died: {e!r}"))
                for _, req in self._retries:
                    if not req.retired:
                        req.retired = True
                        self._inflight -= 1
                        req.future._fail(req.first_error or PoolClosed(
                            f"request #{req.future.seq} lost: pool "
                            f"scheduler died: {e!r}"))
                self._retries.clear()
                self._idle.notify_all()
            raise

    def _scheduler_loop(self) -> None:
        while True:
            with self._lock:
                active: List[_Slot] = []
                while True:
                    now = time.perf_counter()
                    self._promote_retries(now)
                    if self._closed and self._inflight == 0:
                        return
                    # admit queued requests to their slots (dead slots
                    # are drained by kill_slot, never admitted)
                    for slot in self.slots:
                        if slot.dead:
                            continue
                        if slot.active is None and slot.queue:
                            slot.active = slot.queue.pop(0)
                    active = [s for s in self.slots
                              if s.active is not None and not s.dead]
                    if active:
                        break
                    if (self._inflight > 0 and not self._retries
                            and not any(s.active or s.queue
                                        for s in self.slots)):
                        # inflight counter leaked (should be impossible)
                        self._inflight = 0
                        self._idle.notify_all()
                    # idle until new work, close, or the earliest retry
                    # backoff comes due
                    timeout = None
                    if self._retries:
                        timeout = max(0.0, min(due for due, _
                                               in self._retries) - now)
                    self._wake.wait(timeout=timeout)
            try:
                self._advance(active)
            except BaseException as e:          # defensive: fail loudly
                for slot in active:
                    if slot.active is not None:
                        self._retire(slot, error=e)

    def _advance(self, active: List[_Slot]) -> None:
        """One scheduler round: stage fresh requests, overlap host
        segments with accelerator segments, gang same-program
        same-segment requests, then retire finished ones."""
        # stage inputs of freshly admitted requests (swapping the slot's
        # resident session state first when the request belongs to a
        # different session than the last one served here).  step_idx is
        # COMMITTED under the pool lock only while the request still owns
        # the slot: a kill landing mid-staging retried/failed it already,
        # and its bytes went to a device the pool no longer serves from.
        for slot in active:
            req = slot.active
            if req is None or req.retired:
                continue
            if req.step_idx < 0:
                with self._lock:
                    if slot.dead or slot.active is not req or req.retired:
                        continue
                    device = slot.device
                try:
                    self._ensure_resident(slot, req)
                    staged = req.prog.stage_inputs(req.inputs,
                                                   device=device)
                except BaseException as e:
                    self._retire(slot, error=e)
                    continue
                with self._lock:
                    if slot.active is not req or req.retired:
                        continue          # killed/retried mid-staging
                    req.future.staging_bytes = staged
                    slot.stats.staging_bytes += staged
                    req.inputs = {}
                    req.step_idx = 0

        # split this round's work: host segments first (dispatched to a
        # worker thread so they overlap the accel gangs below — the GIL
        # drops while the gang's kernels run inside XLA)
        def step_of(s: _Slot):
            req = s.active
            if req is None or req.retired or req.step_idx < 0 or \
                    req.step_idx >= len(req.prog.steps):
                return None
            return req.prog.steps[req.step_idx]

        # accelerator work grouped up front: SAME-PROGRAM same-step
        # requests gang (streams must be identical for lockstep
        # execution; different programs never gang)
        host_slots: List[_Slot] = []
        by_key: Dict[Tuple[int, int], Tuple[CompiledProgram,
                                            List[_Slot]]] = {}
        for slot in active:
            st = step_of(slot)
            if isinstance(st, CpuStep):
                host_slots.append(slot)
            elif isinstance(st, AccelStep):
                req = slot.active
                key = (self._prog_key[id(req.prog)], req.step_idx)
                by_key.setdefault(key, (req.prog, []))[1].append(slot)

        # arm the segment watchdog: the round's budget sums the
        # TimingModel-predicted wall time of its DISTINCT accel segments
        # (a gang runs lockstep — one prediction covers it), padded by a
        # generous multiplier + floor so the slowest legitimate gang
        # never trips it
        rid = 0
        if self.watchdog is not None:
            budget = self.watchdog.floor_s
            for (pk, idx), (prog, _) in by_key.items():
                budget += self.watchdog.mult * \
                    self._accel_step_seconds(prog, pk, idx)
            with self._lock:
                self._round_id += 1
                rid = self._round_id
                self._round_watch = {s.id for s in host_slots} | {
                    s.id for _, grp in by_key.values() for s in grp}
                self._round_had_host = bool(host_slots)
                self._round_deadline = time.perf_counter() + budget

        host_errs: Dict[int, BaseException] = {}
        host_done: Optional[threading.Event] = None
        host_thread = self._host_thread   # watchdog may replace it
        if host_slots:
            host_done = threading.Event()
            with self._lock:
                # capture (device, step) per job NOW: a retried request
                # resets step_idx, a respawned slot replaces its device —
                # the worker must never chase either
                jobs = [(s, s.device, s.active, s.active.step_idx)
                        for s in host_slots
                        if not s.dead and s.active is not None
                        and not s.active.retired]
                if self.watchdog is not None:
                    self._round_watch.difference_update(
                        s.id for s in host_slots
                        if s.id not in {j[0].id for j in jobs})
            self._host_q.put((jobs, host_errs, host_done))

        accel_errs: Dict[int, BaseException] = {}
        try:
            for (_, idx), (prog, group) in by_key.items():
                try:
                    self._exec_accel(prog, prog.steps[idx], group)
                except BaseException as e:
                    # fail ONLY the gang that raised; other requests in
                    # this round proceed untouched
                    for slot in group:
                        accel_errs[slot.id] = e
                finally:
                    if self.watchdog is not None:
                        self._round_watch.difference_update(
                            s.id for s in group)
        finally:
            if host_done is not None:
                # a dead host worker must fail the round's host
                # requests, not deadlock the whole pool; a watchdog
                # abandonment already failed/retried them
                poll = 0.05 if self.watchdog is not None else 1.0
                while not host_done.wait(poll):
                    if self.watchdog is not None and \
                            self._round_abandoned >= rid:
                        break
                    if not host_thread.is_alive():
                        dead = PoolClosed(
                            "pool host worker died mid-round")
                        for slot in host_slots:
                            host_errs.setdefault(slot.id, dead)
                        break
            if self.watchdog is not None:
                with self._lock:
                    if self._round_id == rid:
                        self._round_deadline = None

        # advance + retire
        for slot in list(active):
            req = slot.active
            if req is None:
                continue
            if req.retired:                      # killed mid-round
                slot.active = None
                continue
            if req.step_idx < 0:                 # staging never landed
                continue
            err = host_errs.get(slot.id) or accel_errs.get(slot.id)
            if err is not None:
                self._retire(slot, error=err)
                continue
            req.step_idx += 1
            if req.step_idx >= len(req.prog.steps):
                self._retire(slot)

    def _exec_accel(self, prog: CompiledProgram, step: AccelStep,
                    group: List[_Slot]) -> None:
        """Run one accelerator segment for every slot in `group` — as a
        lockstep gang when the engine supports it (identical pre-staged
        stream on every slot), serially otherwise.  This is the pool's
        gang clock: scripted chaos faults fire here, integrity checks
        run before the gang touches DRAM, and the executing set is
        filtered + device-captured under the pool lock so a slot killed
        or respawned mid-round is never scribbled on."""
        gang_idx = next(self._gang_seq)
        if self.fault_plan is not None:
            self._apply_faults(gang_idx, prog, group)
        if self.integrity:
            self._check_constants(prog, group)
        with self._lock:
            trios = [(s, s.device, s.active) for s in group
                     if not s.dead and s.active is not None
                     and not s.active.retired]
        if not trios:
            return
        gang = getattr(self.engine, "execute_gang", None)
        prestaged = prog.prestage and step.staged_addr >= 0
        if gang is not None and len(trios) > 1 and prestaged:
            statss = gang(prog.spec, [d for _, d, _ in trios],
                          step.stream, timing=self.timing,
                          staged_addr=step.staged_addr)
            for (slot, _, req), stats in zip(trios, statss):
                stats.n_join_barriers = step.n_barriers
                stats.n_buffer_fences = step.n_fences
                stats.staging_bytes_per_call = req.future.staging_bytes
                req.future.stats.append(stats)
                slot.stats.accel_steps += 1
                slot.stats.ganged_steps += 1
                slot.stats.max_gang = max(slot.stats.max_gang, len(trios))
                slot.stats.tiles_resolved += stats.tiles_resolved
                slot.stats.tile_batches += stats.tile_batches
            return
        for slot, device, req in trios:
            stats = prog.exec_step(step, device, self.engine,
                                   timing=self.timing)
            stats.staging_bytes_per_call = req.future.staging_bytes
            req.future.stats.append(stats)
            slot.stats.accel_steps += 1
            slot.stats.max_gang = max(slot.stats.max_gang, 1)
            slot.stats.tiles_resolved += stats.tiles_resolved
            slot.stats.tile_batches += stats.tile_batches

    def _apply_faults(self, gang_idx: int, prog: CompiledProgram,
                      group: List[_Slot]) -> None:
        """Fire every scripted fault scheduled for this gang execution
        and log what actually happened (losses are accounted, never
        silent)."""
        for f in self.fault_plan.take(gang_idx):
            entry: Dict[str, Any] = {"kind": f.kind, "gang": gang_idx,
                                     "slot": f.slot}
            if f.kind == "delay":
                entry["delay_s"] = f.delay_s
                time.sleep(f.delay_s)
            elif f.kind == "kill":
                target = (f.slot if f.slot is not None
                          and 0 <= f.slot < len(self.slots)
                          else group[0].id)
                entry["slot"] = target
                entry["failed_or_retried"] = self.kill_slot(target)
            elif f.kind == "flip":
                slot = (self.slots[f.slot] if f.slot is not None
                        and 0 <= f.slot < len(self.slots) else group[0])
                if slot.dead:
                    slot = group[0]
                entry["slot"] = slot.id
                regions = prog.integrity_regions()
                total = sum(nb for _, _, nb in regions)
                if total == 0 or slot.dead:
                    entry["skipped"] = ("no constant regions"
                                        if total == 0 else "slot dead")
                else:
                    off = f.byte % total
                    for _, addr, nb in regions:
                        if off < nb:
                            slot.device.dram.mem[addr + off] ^= 0x55
                            slot.device.flush_cache(addr + off, 1)
                            entry["addr"] = int(addr + off)
                            break
                        off -= nb
            self.fault_log.append(entry)
            self.fault_plan.fired.append(entry)

    def _check_constants(self, prog: CompiledProgram,
                         group: List[_Slot]) -> None:
        """Pre-gang audit: constant regions of every executing slot must
        match the pristine image's checksum; a mismatch (bit-rot, DMA
        scribble, injected flip) restages the constants from the
        pristine device before the gang reads them."""
        want = self._const_crc[self._prog_key[id(prog)]]
        if want is None:
            return
        for slot in group:
            if slot.dead:
                continue
            if prog.integrity_checksum(device=slot.device) != want:
                prog.restage_constants(slot.device, pristine=self._dev)
                slot.stats.integrity_restages += 1

    def _retire(self, slot: _Slot, error: Optional[BaseException] = None
                ) -> None:
        req = slot.active
        with self._lock:
            slot.active = None
            if req is None or req.retired:
                return                          # killed while executing
            req.retired = True
        if error is not None:
            req.future._fail(error)
        else:
            try:
                outs = req.prog.read_outputs(device=slot.device)
                slot.stats.calls += 1
                # checkpoint BEFORE resolving the future: once wait()
                # returns under checkpoint_every=1 the call is durable —
                # a kill racing the caller can only roll back to it,
                # never behind it
                if req.session is not None:
                    sess = req.session
                    sess.calls += 1
                    if (self.checkpoint_every
                            and sess.calls % self.checkpoint_every == 0):
                        self._checkpoint(slot, sess)
                if self.integrity and req.prog.persistent_ids:
                    # record the post-call persistent snapshot so later
                    # audits can tell corruption from legitimate updates
                    slot.persist_crc[self._prog_key[id(req.prog)]] = \
                        req.prog.integrity_checksum(device=slot.device,
                                                    persistent=True)
                req.future._finish(outs)
            except BaseException as e:
                req.future._fail(e)
        with self._lock:
            self._inflight -= 1
            self._idle.notify_all()

    def _checkpoint(self, slot: _Slot, sess: _SessionState) -> None:
        """Snapshot the session's persistent bytes to host memory (the
        restore source when its slot dies).  Under the swap lock so the
        snapshot can never interleave with a swap or respawn."""
        with slot.swap_lock:
            if slot.dead:
                return
            key = self._prog_key[id(sess.prog)]
            if slot.resident.get(key) != sess.sid:
                return                       # swapped out: image IS the
            sess.ckpt = sess.prog.persistent_image(   # state already
                device=slot.device)
            sess.ckpt_step = sess.calls
            sess.stats.checkpoints += 1
            sess.stats.checkpoint_step = sess.calls

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def slot_stats(self) -> List[SlotStats]:
        return [s.stats for s in self.slots]

    def describe(self) -> str:
        """``CompiledProgram.describe()`` of every staged program
        (per-device invariants hold per slot) plus one serving line per
        slot, including live queue depth."""
        lines = [c.describe() for c in self.programs]
        lines.append(f"pool[{len(self.slots)} slots, {self.engine.name}, "
                     f"{self.policy}, {len(self.programs)} program(s)]")
        stateful = any(c.persistent_ids for c in self.programs)
        for s in self.slots:
            st = s.stats
            line = (
                f"  slot{s.id}: {st.calls} calls, {st.staging_bytes}B "
                f"staged, {st.accel_steps} accel steps "
                f"({st.ganged_steps} ganged, max gang {st.max_gang}), "
                f"{st.cpu_steps} host steps, "
                f"{st.tiles_resolved} tiles / {st.tile_batches} launches, "
                f"q{len(s.queue)} (hiwater {st.queue_hiwater})")
            if s.dead:
                line += " [DEAD]"
            if st.deaths:
                line += (f", {st.deaths} death(s)/"
                         f"{st.respawns} respawn(s)")
            if st.watchdog_kills:
                line += f", {st.watchdog_kills} watchdog kill(s)"
            if st.integrity_restages:
                line += f", {st.integrity_restages} integrity restage(s)"
            if stateful:
                homed = [x for x in self._sessions.values()
                         if x.slot_id == s.id]
                res = ",".join(f"sid{sid}" for sid in s.resident.values()) \
                    or "-"
                line += (f", {len(homed)} sessions ({res} resident, "
                         f"{st.session_swaps} swaps, "
                         f"{st.persist_hiwater}B hiwater)")
                restores = sum(x.stats.restores for x in homed)
                rehomes = sum(x.stats.rehomes for x in homed)
                lost = sum(1 for x in homed if x.lost)
                if restores or rehomes or lost:
                    line += (f", {restores} restore(s)/"
                             f"{rehomes} rehome(s)/{lost} lost")
            lines.append(line)
        return "\n".join(lines)


# ----------------------------------------------------------------------
# batch serving
# ----------------------------------------------------------------------
class BatchServer:
    """Shards a batch of requests across a DevicePool and gathers the
    results in submission order.

        server = BatchServer(pool)
        outs = server([{"x": x0}, {"x": x1}, ...])   # outs[i] <-> req i

    Construction can also own the pool: ``BatchServer.build(compiled,
    size=4, policy="least_loaded")``."""

    def __init__(self, pool: DevicePool):
        self.pool = pool

    @classmethod
    def build(cls, compiled: CompiledProgram, size: int = 2,
              **pool_kw) -> "BatchServer":
        return cls(DevicePool(compiled, size=size, **pool_kw))

    def __call__(self, requests: Sequence[Dict[str, np.ndarray]],
                 timeout: Optional[float] = None
                 ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
        futures = [self.pool.submit(**req) for req in requests]
        return [f.wait(timeout=timeout) for f in futures]

    def submit_all(self, requests: Sequence[Dict[str, np.ndarray]]
                   ) -> List[PoolFuture]:
        return [self.pool.submit(**req) for req in requests]

    def close(self) -> None:
        self.pool.close()

    def __enter__(self) -> "BatchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_batch(compiled: CompiledProgram,
                requests: Sequence[Dict[str, np.ndarray]],
                size: int = 2, **pool_kw
                ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
    """One-shot convenience: pool up, shard `requests`, gather in order,
    tear down."""
    with BatchServer.build(compiled, size=size, **pool_kw) as server:
        return server(requests)
