"""Async multi-stream serving: device pools, submit/wait futures, and
sharded batch dispatch.

The paper's task-ISA "explicitly orchestrates concurrent compute and
memory tasks" inside one device; this module orchestrates concurrency
ACROSS devices, which is how the runtime the paper sketches (and TVM's,
arXiv 1802.04799) serves real traffic: a compiled program is staged once,
cloned onto a pool of devices, and requests stream through an async
submit()/wait() API.

  * :class:`DevicePool` — N cloned, pre-staged devices per
    CompiledProgram (``Device.clone(trim=True)`` of the staged image:
    streams, constants and the recycled intermediate arena are already
    in DRAM, and a slot can never allocate — the zero-per-call-DRAM
    serving contract, now enforced per slot by construction).  Requests
    are assigned to slot queues at submit time by a round-robin or
    least-loaded policy.

  * a **worker-scheduler** (one thread) that advances every in-flight
    request step by step: host segments are dispatched to a host
    executor thread FIRST, then the accelerator segments of the other
    requests run — so one request's host work overlaps another's
    accelerator work — and requests sitting at the SAME accelerator
    segment execute as one lockstep **gang**
    (:meth:`PallasBackend.execute_gang`): every kernel launch batches
    the peer tiles of all gang members, so aggregate calls/sec scales
    with pool size instead of with the GIL.

  * :class:`BatchServer` — shards a batch of requests across the pool
    and gathers results in submission order.

  * :class:`Session` — persistent-state serving (``Program.persistent``
    buffers: KV caches, recurrent state).  ``pool.session()`` pins a
    session to one slot; its submits run in order on that slot, each
    call advancing the session's state in the slot's DRAM.  When several
    sessions share a slot the scheduler swaps the resident state — raw
    DRAM reads/writes at the stable persistent addresses, never an
    allocation, so the trimmed-clone zero-alloc contract survives
    arbitrary session interleavings.  The scheduler still gangs only
    same-program same-step requests, so concurrent decode sessions at
    the same step share kernel launches.

The simulator engine has no gang mode; a pool over ``backend=
"simulator"`` runs its slots serially and acts as the concurrency
oracle: the differential suite byte-diffs every pooled execution against
serial single-device runs on both engines.
"""
from __future__ import annotations

import itertools
import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from .backend import BackendLike, resolve_backend
from .compiler import AccelStep, CpuStep
from .program import CompiledProgram
from .simulator import RunStats

POLICIES = ("round_robin", "least_loaded")


class PoolClosed(RuntimeError):
    pass


# ----------------------------------------------------------------------
# futures
# ----------------------------------------------------------------------
class PoolFuture:
    """Handle to one submitted request.  ``wait()`` blocks until the
    scheduler finishes the request (in any order relative to other
    futures — waits may be out of submission order) and returns the
    program outputs; request-local stats ride on the future, never on
    shared CompiledProgram state."""

    def __init__(self, slot_id: int, seq: int):
        self.slot_id = slot_id          # which pool slot serves it
        self.seq = seq                  # global submission order
        self.stats: List[RunStats] = []  # per accel segment, this request
        self.staging_bytes = 0
        self._done = threading.Event()
        self._outputs: Any = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None
             ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request #{self.seq} (slot {self.slot_id}) not done "
                f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._outputs

    result = wait

    # scheduler side
    def _finish(self, outputs: Any) -> None:
        self._outputs = outputs
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._done.set()


@dataclass
class SlotStats:
    """Cumulative serving counters of one pool slot (touched only by the
    scheduler thread — per-slot by construction, so concurrent requests
    cannot cross-contaminate them)."""
    calls: int = 0
    staging_bytes: int = 0
    accel_steps: int = 0
    cpu_steps: int = 0
    ganged_steps: int = 0           # accel steps executed in a gang > 1
    tiles_resolved: int = 0
    tile_batches: int = 0
    # persistent-state serving: resident-session swaps performed on this
    # slot, and the high-water of persistent bytes this slot has held
    # for its sessions (resident + swapped-out store)
    session_swaps: int = 0
    persist_hiwater: int = 0


@dataclass
class _Slot:
    id: int
    device: Any
    stats: SlotStats = field(default_factory=SlotStats)
    queue: List["_Request"] = field(default_factory=list)
    active: Optional["_Request"] = None
    # sid of the session whose persistent state is materialized in this
    # slot's DRAM (None: virgin init state / slot-resident mode)
    resident: Optional[int] = None

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self.active is not None else 0)


@dataclass
class _SessionState:
    """Pool-internal record of one session: its sticky slot and, when
    NOT resident there, the swapped-out raw persistent image."""
    sid: int
    slot_id: int
    image: Optional[Dict[str, np.ndarray]] = None
    calls: int = 0


@dataclass
class _Request:
    future: PoolFuture
    inputs: Dict[str, np.ndarray]
    step_idx: int = -1              # -1: inputs not yet staged
    session: Optional[_SessionState] = None


class Session:
    """Handle to one persistent-state serving session on a DevicePool.

        sess = pool.session()
        for tok in prompt:
            y = sess.submit(x=tok).wait()    # state advances in DRAM

    Submits are sticky to one slot and run in submission order there;
    sessions sharing a slot are transparently swapped by the scheduler.
    ``state()``/``reset()`` inspect or rewind the session — call them
    only while the session has no in-flight requests (``pool.drain()``)."""

    def __init__(self, pool: "DevicePool", state: _SessionState):
        self.pool = pool
        self._state = state

    @property
    def sid(self) -> int:
        return self._state.sid

    @property
    def slot_id(self) -> int:
        return self._state.slot_id

    @property
    def calls(self) -> int:
        return self._state.calls

    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        return self.pool._enqueue(inputs, session=self._state)

    def state(self, name: str) -> np.ndarray:
        """Logical value of one persistent buffer as this session sees it
        (resident slot DRAM, swapped-out image, or the init image if the
        session never ran)."""
        return self.pool._session_state(self._state, name)

    def reset(self) -> None:
        """Rewind to the compile-time init images (a fresh dialogue on
        the same session handle)."""
        self.pool._session_reset(self._state)


# ----------------------------------------------------------------------
# the pool
# ----------------------------------------------------------------------
class DevicePool:
    """N cloned pre-staged devices serving one CompiledProgram through an
    async submit()/wait() API.

    Parameters
    ----------
    compiled: the staged artifact (``prestage=True`` recommended —
        trimmed slot clones cannot allocate DRAM).
    size: number of device slots.
    backend: engine every request runs on ("pallas" gangs lockstep
        requests; "simulator" is the serial oracle).  One engine
        instance is shared by the whole pool so jit/decode caches warm
        once.
    policy: "round_robin" assigns submits to slots cyclically;
        "least_loaded" picks the slot with the fewest queued + running
        requests (ties to the lowest slot id).
    trim: clone only the allocated DRAM image per slot (MemoryError on
        any per-call allocation instead of silent growth).  Defaults to
        ``compiled.prestage`` — a restaging (prestage=False) program
        legitimately allocates its stream every call and needs the full
        address space.
    """

    def __init__(self, compiled: CompiledProgram, size: int = 2,
                 backend: BackendLike = "pallas",
                 policy: str = "round_robin", timing: Any = None,
                 trim: Optional[bool] = None):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size}")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if trim is None:
            trim = compiled.prestage
        self.compiled = compiled
        self.engine = resolve_backend(backend)
        self.policy = policy
        self.timing = timing
        self.slots = [_Slot(id=i, device=compiled.device.clone(trim=trim))
                      for i in range(size)]
        self._rr = itertools.cycle(range(size))
        self._seq = itertools.count()
        self._sessions: Dict[int, _SessionState] = {}
        self._session_seq = itertools.count()
        self._session_rr = itertools.cycle(range(size))
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._closed = False
        self._inflight = 0
        self._idle = threading.Condition(self._lock)
        # persistent host worker: one long-lived thread consuming host
        # segment batches, so the hot serving path never pays per-round
        # thread creation
        self._host_q: "queue.Queue[Any]" = queue.Queue()
        self._host_thread = threading.Thread(
            target=self._run_host_worker, name="repro-pool-host",
            daemon=True)
        self._host_thread.start()
        self._scheduler = threading.Thread(
            target=self._run_scheduler, name="repro-pool-scheduler",
            daemon=True)
        self._scheduler.start()

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.slots)

    def __enter__(self) -> "DevicePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        """Enqueue one request; returns immediately with a future.
        Thread-safe: any thread may submit, waits may happen in any
        order.  Input arrays are validated here (fail fast, in the
        caller) and staged into the slot's DRAM by the scheduler.  For a
        program with persistent state, sessionless submits run in
        slot-resident mode (each slot IS one implicit session); use
        :meth:`session` for explicit, swappable sessions."""
        return self._enqueue(inputs, session=None)

    def _enqueue(self, inputs: Dict[str, np.ndarray],
                 session: Optional[_SessionState]) -> PoolFuture:
        self.compiled.check_inputs(inputs)
        with self._lock:
            if self._closed:
                raise PoolClosed("submit() on a closed DevicePool")
            if session is not None:
                slot = self.slots[session.slot_id]   # sticky: state lives
            elif self.policy == "round_robin":       # (or swaps) there
                slot = self.slots[next(self._rr)]
            else:
                slot = min(self.slots, key=lambda s: (s.load, s.id))
            fut = PoolFuture(slot_id=slot.id, seq=next(self._seq))
            slot.queue.append(_Request(future=fut, inputs=dict(inputs),
                                       session=session))
            self._inflight += 1
            self._wake.notify_all()
        return fut

    # ------------------------------------------------------------------
    # sessions (persistent-state serving)
    # ------------------------------------------------------------------
    def session(self, slot: Optional[int] = None) -> Session:
        """Open a new session: an independent copy of the program's
        persistent state, pinned to one slot (round-robin by default).
        Same-slot sessions are swapped in and out of the slot's DRAM by
        the scheduler; same-step submits of different sessions still
        gang across slots."""
        with self._lock:
            if self._closed:
                raise PoolClosed("session() on a closed DevicePool")
            sid = next(self._session_seq)
            slot_id = slot if slot is not None else next(self._session_rr)
            if not 0 <= slot_id < len(self.slots):
                raise ValueError(f"slot {slot_id} out of range")
            st = _SessionState(sid=sid, slot_id=slot_id)
            self._sessions[sid] = st
        return Session(self, st)

    def _ensure_resident(self, slot: _Slot, req: _Request) -> None:
        """Make `req`'s session state resident in `slot` before the
        request stages.  Swaps are raw DRAM reads/writes at the stable
        persistent addresses — NEVER an allocation, so trimmed clones
        stay within the zero-alloc contract.  Scheduler-thread only."""
        compiled = self.compiled
        sess = req.session
        if sess is None or not compiled.persistent_ids:
            return
        if slot.resident == sess.sid:
            return
        if slot.resident is not None:
            old = self._sessions.get(slot.resident)
            if old is not None:
                old.image = compiled.persistent_image(device=slot.device)
        if sess.image is not None:
            compiled.load_persistent_image(sess.image, device=slot.device)
            sess.image = None                      # resident now
        else:
            compiled.reset_persistent(device=slot.device)
        slot.resident = sess.sid
        slot.stats.session_swaps += 1
        held = compiled.persistent_bytes + sum(
            sum(a.nbytes for a in s.image.values())
            for s in self._sessions.values()
            if s.slot_id == slot.id and s.image is not None)
        slot.stats.persist_hiwater = max(slot.stats.persist_hiwater, held)

    def _session_state(self, st: _SessionState, name: str) -> np.ndarray:
        compiled = self.compiled
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident == st.sid:
                return compiled.read_persistent(name, device=slot.device)
            nid = compiled.input_ids[name]
            node = compiled.nodes[nid]
            if st.image is None:                   # never ran
                return np.array(node.const)
            raw = st.image[name]
            blocked = raw.view(node.meta.np_dtype()).reshape(
                node.meta.blocked_shape(compiled.spec))
            return node.meta.unpack(blocked, compiled.spec)

    def _session_reset(self, st: _SessionState) -> None:
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident == st.sid:
                self.compiled.reset_persistent(device=slot.device)
            else:
                st.image = None
            st.calls = 0

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        with self._lock:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError("DevicePool.drain timed out")

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Reject new submits, let in-flight requests finish, stop the
        scheduler and host-worker threads.  If the scheduler fails to
        drain within `timeout` (a wedged host fn or kernel), every
        still-pending future is FAILED with PoolClosed so no waiter
        blocks forever on a pool that will never answer."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._wake.notify_all()
        self._scheduler.join(timeout)
        if self._scheduler.is_alive():
            err = PoolClosed(
                f"DevicePool.close: scheduler did not drain within "
                f"{timeout}s; failing all pending futures")
            with self._lock:
                for slot in self.slots:
                    pending = list(slot.queue)
                    slot.queue.clear()
                    if slot.active is not None:
                        pending.append(slot.active)
                    for req in pending:
                        if not req.future.done():
                            req.future._fail(err)
        self._host_q.put(None)                  # stop the host worker
        self._host_thread.join(timeout)

    # ------------------------------------------------------------------
    # the worker-scheduler
    # ------------------------------------------------------------------
    def _run_host_worker(self) -> None:
        """Long-lived host-segment executor: the scheduler hands it the
        round's CpuStep batch, then runs the accelerator gangs while the
        host fns execute here — one request's host work overlaps other
        requests' accelerator work (the GIL drops inside the gangs' XLA
        kernels)."""
        compiled = self.compiled
        while True:
            item = self._host_q.get()
            if item is None:
                return
            host_slots, host_errs, done = item
            for slot in host_slots:
                step = compiled.steps[slot.active.step_idx]
                try:
                    compiled.exec_step(step, slot.device, self.engine,
                                       timing=self.timing)
                    slot.stats.cpu_steps += 1
                except BaseException as e:
                    host_errs[slot.id] = e
            done.set()

    def _run_scheduler(self) -> None:
        compiled = self.compiled
        steps = compiled.steps
        while True:
            with self._lock:
                self._wake.wait_for(
                    lambda: self._closed or self._inflight > 0)
                if self._closed and self._inflight == 0:
                    return
                # admit queued requests to their slots
                for slot in self.slots:
                    if slot.active is None and slot.queue:
                        slot.active = slot.queue.pop(0)
                active = [s for s in self.slots if s.active is not None]
                if not active:
                    # closed with queued-but-unadmittable? impossible —
                    # admission above always fills an empty slot
                    continue
            try:
                self._advance(active, steps)
            except BaseException as e:          # defensive: fail loudly
                with self._lock:
                    for slot in active:
                        if slot.active is not None:
                            slot.active.future._fail(e)
                            slot.active = None
                            self._inflight -= 1
                    self._idle.notify_all()

    def _advance(self, active: List[_Slot], steps: List[Any]) -> None:
        """One scheduler round: stage fresh requests, overlap host
        segments with accelerator segments, gang same-segment requests,
        then retire finished ones."""
        compiled = self.compiled

        # stage inputs of freshly admitted requests (swapping the slot's
        # resident session state first when the request belongs to a
        # different session than the last one served here)
        for slot in active:
            req = slot.active
            if req.step_idx < 0:
                try:
                    self._ensure_resident(slot, req)
                    req.future.staging_bytes = compiled.stage_inputs(
                        req.inputs, device=slot.device)
                    slot.stats.staging_bytes += req.future.staging_bytes
                    req.inputs = {}
                    req.step_idx = 0
                except BaseException as e:
                    self._retire(slot, error=e)
                    return

        # split this round's work: host segments first (dispatched to a
        # worker thread so they overlap the accel gangs below — the GIL
        # drops while the gang's kernels run inside XLA)
        host_slots = [s for s in active
                      if s.active is not None
                      and s.active.step_idx < len(steps)
                      and isinstance(steps[s.active.step_idx], CpuStep)]
        accel_slots = [s for s in active
                       if s.active is not None
                       and s.active.step_idx < len(steps)
                       and isinstance(steps[s.active.step_idx], AccelStep)]

        host_errs: Dict[int, BaseException] = {}
        host_done: Optional[threading.Event] = None
        if host_slots:
            host_done = threading.Event()
            self._host_q.put((host_slots, host_errs, host_done))

        # accelerator segments: group same-step requests into gangs
        accel_errs: Dict[int, BaseException] = {}
        try:
            by_step: Dict[int, List[_Slot]] = {}
            for slot in accel_slots:
                by_step.setdefault(slot.active.step_idx, []).append(slot)
            for idx, group in by_step.items():
                try:
                    self._exec_accel(steps[idx], group)
                except BaseException as e:
                    # fail ONLY the gang that raised; other requests in
                    # this round proceed untouched
                    for slot in group:
                        accel_errs[slot.id] = e
        finally:
            if host_done is not None:
                host_done.wait()

        # advance + retire
        for slot in list(active):
            if slot.active is None:
                continue
            err = host_errs.get(slot.id) or accel_errs.get(slot.id)
            if err is not None:
                self._retire(slot, error=err)
                continue
            slot.active.step_idx += 1
            if slot.active.step_idx >= len(steps):
                self._retire(slot)

    def _exec_accel(self, step: AccelStep, group: List[_Slot]) -> None:
        """Run one accelerator segment for every slot in `group` — as a
        lockstep gang when the engine supports it (identical pre-staged
        stream on every slot), serially otherwise."""
        compiled = self.compiled
        gang = getattr(self.engine, "execute_gang", None)
        prestaged = compiled.prestage and step.staged_addr >= 0
        if gang is not None and len(group) > 1 and prestaged:
            statss = gang(compiled.spec, [s.device for s in group],
                          step.stream, timing=self.timing,
                          staged_addr=step.staged_addr)
            for slot, stats in zip(group, statss):
                stats.n_join_barriers = step.n_barriers
                stats.n_buffer_fences = step.n_fences
                stats.staging_bytes_per_call = \
                    slot.active.future.staging_bytes
                slot.active.future.stats.append(stats)
                slot.stats.accel_steps += 1
                slot.stats.ganged_steps += 1
                slot.stats.tiles_resolved += stats.tiles_resolved
                slot.stats.tile_batches += stats.tile_batches
            return
        for slot in group:
            stats = compiled.exec_step(step, slot.device, self.engine,
                                       timing=self.timing)
            stats.staging_bytes_per_call = slot.active.future.staging_bytes
            slot.active.future.stats.append(stats)
            slot.stats.accel_steps += 1
            slot.stats.tiles_resolved += stats.tiles_resolved
            slot.stats.tile_batches += stats.tile_batches

    def _retire(self, slot: _Slot, error: Optional[BaseException] = None
                ) -> None:
        req = slot.active
        slot.active = None
        if error is not None:
            req.future._fail(error)
        else:
            try:
                req.future._finish(
                    self.compiled.read_outputs(device=slot.device))
                slot.stats.calls += 1
                if req.session is not None:
                    req.session.calls += 1
            except BaseException as e:
                req.future._fail(e)
        with self._lock:
            self._inflight -= 1
            self._idle.notify_all()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def slot_stats(self) -> List[SlotStats]:
        return [s.stats for s in self.slots]

    def describe(self) -> str:
        """``CompiledProgram.describe()`` (per-device invariants hold per
        slot) plus one serving line per slot."""
        lines = [self.compiled.describe(),
                 f"pool[{len(self.slots)} slots, {self.engine.name}, "
                 f"{self.policy}]"]
        stateful = bool(self.compiled.persistent_ids)
        for s in self.slots:
            st = s.stats
            line = (
                f"  slot{s.id}: {st.calls} calls, {st.staging_bytes}B "
                f"staged, {st.accel_steps} accel steps "
                f"({st.ganged_steps} ganged), {st.cpu_steps} host steps, "
                f"{st.tiles_resolved} tiles / {st.tile_batches} launches")
            if stateful:
                nsess = sum(1 for x in self._sessions.values()
                            if x.slot_id == s.id)
                res = "-" if s.resident is None else f"sid{s.resident}"
                line += (f", {nsess} sessions ({res} resident, "
                         f"{st.session_swaps} swaps, "
                         f"{st.persist_hiwater}B hiwater)")
            lines.append(line)
        return "\n".join(lines)


# ----------------------------------------------------------------------
# batch serving
# ----------------------------------------------------------------------
class BatchServer:
    """Shards a batch of requests across a DevicePool and gathers the
    results in submission order.

        server = BatchServer(pool)
        outs = server([{"x": x0}, {"x": x1}, ...])   # outs[i] <-> req i

    Construction can also own the pool: ``BatchServer.build(compiled,
    size=4, policy="least_loaded")``."""

    def __init__(self, pool: DevicePool):
        self.pool = pool

    @classmethod
    def build(cls, compiled: CompiledProgram, size: int = 2,
              **pool_kw) -> "BatchServer":
        return cls(DevicePool(compiled, size=size, **pool_kw))

    def __call__(self, requests: Sequence[Dict[str, np.ndarray]],
                 timeout: Optional[float] = None
                 ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
        futures = [self.pool.submit(**req) for req in requests]
        return [f.wait(timeout=timeout) for f in futures]

    def submit_all(self, requests: Sequence[Dict[str, np.ndarray]]
                   ) -> List[PoolFuture]:
        return [self.pool.submit(**req) for req in requests]

    def close(self) -> None:
        self.pool.close()

    def __enter__(self) -> "BatchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_batch(compiled: CompiledProgram,
                requests: Sequence[Dict[str, np.ndarray]],
                size: int = 2, **pool_kw
                ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
    """One-shot convenience: pool up, shard `requests`, gather in order,
    tear down."""
    with BatchServer.build(compiled, size=size, **pool_kw) as server:
        return server(requests)
