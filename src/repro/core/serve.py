"""Async multi-stream serving: device pools, submit/wait futures, and
sharded batch dispatch.

The paper's task-ISA "explicitly orchestrates concurrent compute and
memory tasks" inside one device; this module orchestrates concurrency
ACROSS devices, which is how the runtime the paper sketches (and TVM's,
arXiv 1802.04799) serves real traffic: a compiled program is staged once,
cloned onto a pool of devices, and requests stream through an async
submit()/wait() API.

  * :class:`DevicePool` — N cloned, pre-staged devices serving one
    CompiledProgram **or a co-staged program mix**
    (``program.compile_multi``: every program occupies a disjoint
    ``ImageRange`` of ONE resident image, so a single slot clone holds
    the whole heterogeneous mix with every baked address valid).
    ``Device.clone(trim=True)`` of the staged image means streams,
    constants and the recycled intermediate arenas are already in DRAM,
    and a slot can never allocate — the zero-per-call-DRAM serving
    contract, enforced per slot by construction.  Requests are assigned
    to slot queues at submit time by a round-robin or least-loaded
    policy.

  * a **worker-scheduler** (one thread) that advances every in-flight
    request step by step: host segments are dispatched to a host
    executor thread FIRST, then the accelerator segments of the other
    requests run — so one request's host work overlaps another's
    accelerator work — and requests sitting at the SAME program's SAME
    accelerator segment execute as one lockstep **gang**
    (:meth:`PallasBackend.execute_gang`): every kernel launch batches
    the peer tiles of all gang members, so aggregate calls/sec scales
    with pool size instead of with the GIL.  Different programs never
    gang (their streams differ); the continuous-batching admission
    layer (``core.sched``) exists to park and release same-program
    requests together so gangs actually form under open-loop traffic.

  * :class:`BatchServer` — shards a batch of requests across the pool
    and gathers results in submission order.

  * :class:`Session` — persistent-state serving (``Program.persistent``
    buffers: KV caches, recurrent state).  ``pool.session()`` pins a
    session to one slot; its submits run in order on that slot, each
    call advancing the session's state in the slot's DRAM.  When several
    sessions share a slot the scheduler swaps the resident state — raw
    DRAM reads/writes at the stable persistent addresses, never an
    allocation, so the trimmed-clone zero-alloc contract survives
    arbitrary session interleavings.  Residency is tracked per program:
    sessions of co-staged programs live at disjoint addresses and never
    evict each other.

Failure is loud, never a hang: a worker exception or a dead slot fails
the waiting future (the error carries the request id), the scheduler and
host-worker threads are watchdogged against each other, and
:meth:`DevicePool.kill_slot` is the chaos hook the regression suite uses
to prove it — every request parked on or active in a killed slot raises
:class:`SlotDied` immediately.

The simulator engine has no gang mode; a pool over ``backend=
"simulator"`` runs its slots serially and acts as the concurrency
oracle: the differential suite byte-diffs every pooled execution against
serial single-device runs on both engines.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .backend import BackendLike, resolve_backend
from .compiler import AccelStep, CpuStep
from .program import CompiledProgram
from .simulator import RunStats

POLICIES = ("round_robin", "least_loaded")


class PoolClosed(RuntimeError):
    pass


class SlotDied(RuntimeError):
    """A pool slot died (killed or crashed) with requests parked on or
    active in it; every affected future raises this, carrying the
    request id — never a silent hang."""
    pass


# ----------------------------------------------------------------------
# futures
# ----------------------------------------------------------------------
class PoolFuture:
    """Handle to one submitted request.  ``wait()`` blocks until the
    scheduler finishes the request (in any order relative to other
    futures — waits may be out of submission order) and returns the
    program outputs; request-local stats ride on the future, never on
    shared CompiledProgram state.  Errors propagate: a worker exception
    or slot death raises here (annotated with the request id), it never
    strands the waiter."""

    def __init__(self, slot_id: int, seq: int):
        self.slot_id = slot_id          # which pool slot serves it
        self.seq = seq                  # global submission order
        self.stats: List[RunStats] = []  # per accel segment, this request
        self.staging_bytes = 0
        self.done_at: Optional[float] = None  # perf_counter at completion
        self._done = threading.Event()
        self._outputs: Any = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None
             ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request #{self.seq} (slot {self.slot_id}) not done "
                f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._outputs

    result = wait

    # scheduler side; first outcome wins — a request can be failed by
    # kill_slot while its last gang is still retiring, and the late
    # result must not overwrite the death notice (or vice versa)
    def _finish(self, outputs: Any) -> bool:
        if self._done.is_set():
            return False
        self._outputs = outputs
        self.done_at = time.perf_counter()
        self._done.set()
        return True

    def _fail(self, exc: BaseException) -> bool:
        if self._done.is_set():
            return False
        if hasattr(exc, "add_note"):             # 3.11+: carry the id
            try:
                exc.add_note(f"[pool request #{self.seq} on slot "
                             f"{self.slot_id}]")
            except TypeError:                    # pragma: no cover
                pass
        self._exc = exc
        self.done_at = time.perf_counter()
        self._done.set()
        return True


@dataclass
class SlotStats:
    """Cumulative serving counters of one pool slot (touched only by the
    scheduler thread — per-slot by construction, so concurrent requests
    cannot cross-contaminate them)."""
    calls: int = 0
    staging_bytes: int = 0
    accel_steps: int = 0
    cpu_steps: int = 0
    ganged_steps: int = 0           # accel steps executed in a gang > 1
    max_gang: int = 0               # widest gang this slot took part in
    queue_hiwater: int = 0          # deepest the slot's submit queue got
    tiles_resolved: int = 0
    tile_batches: int = 0
    # persistent-state serving: resident-session swaps performed on this
    # slot, and the high-water of persistent bytes this slot has held
    # for its sessions (resident + swapped-out store)
    session_swaps: int = 0
    persist_hiwater: int = 0


@dataclass
class _Slot:
    id: int
    device: Any
    stats: SlotStats = field(default_factory=SlotStats)
    queue: List["_Request"] = field(default_factory=list)
    active: Optional["_Request"] = None
    dead: bool = False
    # per-program residency: prog key -> sid of the session whose
    # persistent state is materialized in this slot's DRAM (absent:
    # virgin init state / slot-resident mode).  Co-staged programs have
    # disjoint persistent addresses, so their residents never collide.
    resident: Dict[int, int] = field(default_factory=dict)

    @property
    def load(self) -> int:
        return len(self.queue) + (1 if self.active is not None else 0)


@dataclass
class _SessionState:
    """Pool-internal record of one session: its program, sticky slot
    and, when NOT resident there, the swapped-out raw persistent
    image."""
    sid: int
    slot_id: int
    prog: CompiledProgram
    image: Optional[Dict[str, np.ndarray]] = None
    calls: int = 0


@dataclass
class _Request:
    future: PoolFuture
    inputs: Dict[str, np.ndarray]
    prog: CompiledProgram
    step_idx: int = -1              # -1: inputs not yet staged
    session: Optional[_SessionState] = None
    retired: bool = False           # future resolved + inflight released


class Session:
    """Handle to one persistent-state serving session on a DevicePool.

        sess = pool.session()
        for tok in prompt:
            y = sess.submit(x=tok).wait()    # state advances in DRAM

    Submits are sticky to one slot and run in submission order there;
    sessions sharing a slot are transparently swapped by the scheduler.
    ``state()``/``reset()`` inspect or rewind the session — call them
    only while the session has no in-flight requests (``pool.drain()``)."""

    def __init__(self, pool: "DevicePool", state: _SessionState):
        self.pool = pool
        self._state = state

    @property
    def sid(self) -> int:
        return self._state.sid

    @property
    def slot_id(self) -> int:
        return self._state.slot_id

    @property
    def calls(self) -> int:
        return self._state.calls

    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        return self.pool._enqueue(inputs, session=self._state,
                                  prog=self._state.prog)

    def state(self, name: str) -> np.ndarray:
        """Logical value of one persistent buffer as this session sees it
        (resident slot DRAM, swapped-out image, or the init image if the
        session never ran)."""
        return self.pool._session_state(self._state, name)

    def reset(self) -> None:
        """Rewind to the compile-time init images (a fresh dialogue on
        the same session handle)."""
        self.pool._session_reset(self._state)


# ----------------------------------------------------------------------
# the pool
# ----------------------------------------------------------------------
class DevicePool:
    """N cloned pre-staged devices serving one CompiledProgram — or a
    co-staged mix of them — through an async submit()/wait() API.

    Parameters
    ----------
    compiled: the staged artifact (``prestage=True`` recommended —
        trimmed slot clones cannot allocate DRAM), or a SEQUENCE of
        artifacts produced by ``program.compile_multi``: they share one
        device image at disjoint DRAM ranges, and the pool serves the
        whole mix.  ``submit()`` targets the first program;
        ``submit_to(program, ...)`` targets any of them.  Only
        same-program same-segment requests gang.
    size: number of device slots.
    backend: engine every request runs on ("pallas" gangs lockstep
        requests; "simulator" is the serial oracle).  One engine
        instance is shared by the whole pool so jit/decode caches warm
        once.
    policy: "round_robin" assigns submits to slots cyclically;
        "least_loaded" picks the slot with the fewest queued + running
        requests (ties to the lowest slot id).
    trim: clone only the allocated DRAM image per slot (MemoryError on
        any per-call allocation instead of silent growth).  Defaults to
        every program being prestaged — a restaging (prestage=False)
        program legitimately allocates its stream every call and needs
        the full address space.
    """

    def __init__(self, compiled: Union[CompiledProgram,
                                       Sequence[CompiledProgram]],
                 size: int = 2,
                 backend: BackendLike = "pallas",
                 policy: str = "round_robin", timing: Any = None,
                 trim: Optional[bool] = None):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size}")
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        progs = (list(compiled)
                 if isinstance(compiled, (list, tuple)) else [compiled])
        if not progs:
            raise ValueError("DevicePool of zero programs")
        dev = progs[0].device
        for c in progs[1:]:
            if c.device is not dev:
                raise ValueError(
                    "multi-program pools require co-staged programs "
                    "(program.compile_multi) — these were compiled onto "
                    "different devices, their DRAM images cannot merge")
        if trim is None:
            trim = all(c.prestage for c in progs)
        self.programs: List[CompiledProgram] = progs
        self.compiled = progs[0]            # default-submit target
        self._prog_key = {id(c): i for i, c in enumerate(progs)}
        self.engine = resolve_backend(backend)
        self.policy = policy
        self.timing = timing
        self.slots = [_Slot(id=i, device=dev.clone(trim=trim))
                      for i in range(size)]
        self._rr = itertools.cycle(range(size))
        self._seq = itertools.count()
        self._sessions: Dict[int, _SessionState] = {}
        self._session_seq = itertools.count()
        self._session_rr = itertools.cycle(range(size))
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._closed = False
        self._inflight = 0
        self._idle = threading.Condition(self._lock)
        # persistent host worker: one long-lived thread consuming host
        # segment batches, so the hot serving path never pays per-round
        # thread creation
        self._host_q: "queue.Queue[Any]" = queue.Queue()
        self._host_thread = threading.Thread(
            target=self._run_host_worker, name="repro-pool-host",
            daemon=True)
        self._host_thread.start()
        self._scheduler = threading.Thread(
            target=self._run_scheduler, name="repro-pool-scheduler",
            daemon=True)
        self._scheduler.start()

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.slots)

    def __enter__(self) -> "DevicePool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _resolve_prog(self, program: Union[None, int, CompiledProgram]
                      ) -> CompiledProgram:
        if program is None:
            return self.compiled
        if isinstance(program, int):
            return self.programs[program]
        if id(program) not in self._prog_key:
            raise ValueError("program was not staged on this pool "
                             "(co-stage it with program.compile_multi)")
        return program

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, **inputs: np.ndarray) -> PoolFuture:
        """Enqueue one request against the pool's first (default)
        program; returns immediately with a future.  Thread-safe: any
        thread may submit, waits may happen in any order.  Input arrays
        are validated here (fail fast, in the caller) and staged into
        the slot's DRAM by the scheduler.  For a program with persistent
        state, sessionless submits run in slot-resident mode (each slot
        IS one implicit session); use :meth:`session` for explicit,
        swappable sessions."""
        return self._enqueue(inputs, session=None, prog=self.compiled)

    def submit_to(self, program: Union[int, CompiledProgram],
                  **inputs: np.ndarray) -> PoolFuture:
        """Enqueue one request against a specific co-staged program
        (index into ``self.programs`` or the artifact itself)."""
        return self._enqueue(inputs, session=None,
                             prog=self._resolve_prog(program))

    def _pick_slot(self, session: Optional[_SessionState],
                   avoid: frozenset = frozenset()) -> _Slot:
        """Pick the serving slot (lock held).  Dead slots are skipped;
        a session stays pinned and raises if its slot died.  `avoid`
        lists slots already claimed by the same atomic batch — prefer
        spreading a batch over distinct slots (so it can gang), falling
        back to doubling up only when the batch outsizes the pool."""
        if session is not None:
            slot = self.slots[session.slot_id]   # sticky: state lives
            if slot.dead:                        # (or swaps) there
                raise SlotDied(f"session {session.sid}'s slot "
                               f"{slot.id} died")
            return slot
        alive = [s for s in self.slots if not s.dead]
        if not alive:
            raise PoolClosed("every pool slot is dead")
        if self.policy == "round_robin":
            for prefer_fresh in (True, False):
                for _ in range(len(self.slots)):
                    slot = self.slots[next(self._rr)]
                    if slot.dead:
                        continue
                    if prefer_fresh and slot.id in avoid:
                        continue
                    return slot
            raise PoolClosed("every pool slot is dead")  # pragma: no cover
        fresh = [s for s in alive if s.id not in avoid] or alive
        return min(fresh, key=lambda s: (s.load, s.id))

    def _enqueue(self, inputs: Dict[str, np.ndarray],
                 session: Optional[_SessionState],
                 prog: CompiledProgram) -> PoolFuture:
        return self._enqueue_batch([(inputs, session, prog)])[0]

    def submit_batch(self, program: Union[None, int, CompiledProgram],
                     requests: Sequence[Dict[str, np.ndarray]]
                     ) -> List[PoolFuture]:
        """Enqueue several requests of one program ATOMICALLY: the
        scheduler observes all of them at the same admission point, so
        on an idle pool they land on distinct slots in the same round
        and stay lockstep (a gang) for the whole program.  Sequential
        ``submit()`` calls race the scheduler's round loop and can
        stagger — this is the release primitive the admission window
        (``core.sched``) is built on."""
        prog = self._resolve_prog(program)
        return self._enqueue_batch([(dict(r), None, prog)
                                    for r in requests])

    def _enqueue_batch(self, items: Sequence[Tuple[Dict[str, np.ndarray],
                                                   Optional[_SessionState],
                                                   CompiledProgram]]
                       ) -> List[PoolFuture]:
        for inputs, _, prog in items:
            prog.check_inputs(inputs)
        futs: List[PoolFuture] = []
        with self._lock:
            if self._closed:
                raise PoolClosed("submit() on a closed DevicePool")
            # validate before enqueuing anything: a mid-batch failure
            # must not leave a half-admitted gang behind
            for _, session, _ in items:
                if session is not None and \
                        self.slots[session.slot_id].dead:
                    raise SlotDied(f"session {session.sid}'s slot "
                                   f"{session.slot_id} died")
            if all(s.dead for s in self.slots):
                raise PoolClosed("every pool slot is dead")
            used: set = set()
            for inputs, session, prog in items:
                slot = self._pick_slot(session, avoid=frozenset(used))
                used.add(slot.id)
                fut = PoolFuture(slot_id=slot.id, seq=next(self._seq))
                slot.queue.append(_Request(future=fut,
                                           inputs=dict(inputs),
                                           prog=prog, session=session))
                slot.stats.queue_hiwater = max(slot.stats.queue_hiwater,
                                               len(slot.queue))
                self._inflight += 1
                futs.append(fut)
            self._wake.notify_all()
        return futs

    # ------------------------------------------------------------------
    # sessions (persistent-state serving)
    # ------------------------------------------------------------------
    def session(self, slot: Optional[int] = None,
                program: Union[None, int, CompiledProgram] = None
                ) -> Session:
        """Open a new session: an independent copy of one program's
        persistent state, pinned to one slot (round-robin by default).
        Same-slot sessions are swapped in and out of the slot's DRAM by
        the scheduler; same-step submits of different sessions still
        gang across slots."""
        prog = self._resolve_prog(program)
        with self._lock:
            if self._closed:
                raise PoolClosed("session() on a closed DevicePool")
            sid = next(self._session_seq)
            slot_id = slot if slot is not None else next(self._session_rr)
            if not 0 <= slot_id < len(self.slots):
                raise ValueError(f"slot {slot_id} out of range")
            if self.slots[slot_id].dead:
                raise SlotDied(f"slot {slot_id} is dead")
            st = _SessionState(sid=sid, slot_id=slot_id, prog=prog)
            self._sessions[sid] = st
        return Session(self, st)

    def _ensure_resident(self, slot: _Slot, req: _Request) -> None:
        """Make `req`'s session state resident in `slot` before the
        request stages.  Swaps are raw DRAM reads/writes at the stable
        persistent addresses — NEVER an allocation, so trimmed clones
        stay within the zero-alloc contract.  Residency is per program
        (disjoint address ranges under compile_multi).  Scheduler-thread
        only."""
        sess = req.session
        if sess is None or not sess.prog.persistent_ids:
            return
        key = self._prog_key[id(sess.prog)]
        if slot.resident.get(key) == sess.sid:
            return
        old_sid = slot.resident.get(key)
        if old_sid is not None:
            old = self._sessions.get(old_sid)
            if old is not None:
                old.image = old.prog.persistent_image(device=slot.device)
        if sess.image is not None:
            sess.prog.load_persistent_image(sess.image, device=slot.device)
            sess.image = None                      # resident now
        else:
            sess.prog.reset_persistent(device=slot.device)
        slot.resident[key] = sess.sid
        slot.stats.session_swaps += 1
        held = sess.prog.persistent_bytes + sum(
            sum(a.nbytes for a in s.image.values())
            for s in self._sessions.values()
            if s.slot_id == slot.id and s.image is not None)
        slot.stats.persist_hiwater = max(slot.stats.persist_hiwater, held)

    def _session_state(self, st: _SessionState, name: str) -> np.ndarray:
        prog = st.prog
        key = self._prog_key[id(prog)]
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident.get(key) == st.sid:
                return prog.read_persistent(name, device=slot.device)
            nid = prog.input_ids[name]
            node = prog.nodes[nid]
            if st.image is None:                   # never ran
                return np.array(node.const)
            raw = st.image[name]
            blocked = raw.view(node.meta.np_dtype()).reshape(
                node.meta.blocked_shape(prog.spec))
            return node.meta.unpack(blocked, prog.spec)

    def _session_reset(self, st: _SessionState) -> None:
        key = self._prog_key[id(st.prog)]
        with self._lock:
            slot = self.slots[st.slot_id]
            if slot.resident.get(key) == st.sid:
                st.prog.reset_persistent(device=slot.device)
            else:
                st.image = None
            st.calls = 0

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has completed."""
        with self._lock:
            if not self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout):
                raise TimeoutError("DevicePool.drain timed out")

    def kill_slot(self, slot_id: int) -> int:
        """Chaos/ops hook: declare one slot dead NOW.  Every request
        parked on or active in it fails immediately with
        :class:`SlotDied` (the error names the request), the slot leaves
        the submit rotation, and the scheduler discards any in-flight
        result it may still produce.  Returns the number of requests
        failed.  The regression suite kills a slot mid-flight to prove
        waits raise instead of hanging."""
        with self._lock:
            slot = self.slots[slot_id]
            if slot.dead:
                return 0
            slot.dead = True
            victims = list(slot.queue)
            slot.queue.clear()
            if slot.active is not None and not slot.active.retired:
                victims.append(slot.active)
            n = 0
            for req in victims:
                if req.retired:
                    continue
                req.retired = True
                self._inflight -= 1
                n += 1
                req.future._fail(SlotDied(
                    f"request #{req.future.seq} lost: slot {slot_id} "
                    f"died mid-flight"))
            self._idle.notify_all()
            self._wake.notify_all()
        return n

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Reject new submits, let in-flight requests finish, stop the
        scheduler and host-worker threads.  If the scheduler fails to
        drain within `timeout` (a wedged host fn or kernel), every
        still-pending future is FAILED with PoolClosed so no waiter
        blocks forever on a pool that will never answer."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._wake.notify_all()
        self._scheduler.join(timeout)
        if self._scheduler.is_alive():
            err = PoolClosed(
                f"DevicePool.close: scheduler did not drain within "
                f"{timeout}s; failing all pending futures")
            with self._lock:
                for slot in self.slots:
                    pending = list(slot.queue)
                    slot.queue.clear()
                    if slot.active is not None:
                        pending.append(slot.active)
                    for req in pending:
                        if not req.future.done():
                            req.future._fail(err)
        self._host_q.put(None)                  # stop the host worker
        self._host_thread.join(timeout)

    # ------------------------------------------------------------------
    # the worker-scheduler
    # ------------------------------------------------------------------
    def _run_host_worker(self) -> None:
        """Long-lived host-segment executor: the scheduler hands it the
        round's CpuStep batch, then runs the accelerator gangs while the
        host fns execute here — one request's host work overlaps other
        requests' accelerator work (the GIL drops inside the gangs' XLA
        kernels).  ``done.set()`` is unconditional: a raising host fn
        must never leave the scheduler waiting on the round."""
        while True:
            item = self._host_q.get()
            if item is None:
                return
            jobs, host_errs, done = item
            try:
                for slot, req in jobs:
                    if req.retired:               # killed mid-round
                        continue
                    step = req.prog.steps[req.step_idx]
                    try:
                        req.prog.exec_step(step, slot.device, self.engine,
                                           timing=self.timing)
                        slot.stats.cpu_steps += 1
                    except BaseException as e:
                        host_errs[slot.id] = e
            finally:
                done.set()

    def _run_scheduler(self) -> None:
        try:
            self._scheduler_loop()
        except BaseException as e:
            # nothing may escape the loop silently: a dead scheduler
            # thread would strand every current AND future waiter, so
            # fail everything in flight loudly before the thread exits
            with self._lock:
                for slot in self.slots:
                    victims = list(slot.queue)
                    slot.queue.clear()
                    if slot.active is not None:
                        victims.append(slot.active)
                        slot.active = None
                    for req in victims:
                        if req.retired:
                            continue
                        req.retired = True
                        self._inflight -= 1
                        req.future._fail(PoolClosed(
                            f"request #{req.future.seq} lost: pool "
                            f"scheduler died: {e!r}"))
                self._idle.notify_all()
            raise

    def _scheduler_loop(self) -> None:
        while True:
            with self._lock:
                self._wake.wait_for(
                    lambda: self._closed or self._inflight > 0)
                if self._closed and self._inflight == 0:
                    return
                # admit queued requests to their slots (dead slots are
                # drained by kill_slot, never admitted)
                for slot in self.slots:
                    if slot.dead:
                        continue
                    if slot.active is None and slot.queue:
                        slot.active = slot.queue.pop(0)
                active = [s for s in self.slots
                          if s.active is not None and not s.dead]
                if not active:
                    if self._inflight > 0 and not any(
                            s.active or s.queue for s in self.slots):
                        # inflight counter leaked (should be impossible)
                        self._inflight = 0
                        self._idle.notify_all()
                    continue
            try:
                self._advance(active)
            except BaseException as e:          # defensive: fail loudly
                for slot in active:
                    if slot.active is not None:
                        self._retire(slot, error=e)

    def _advance(self, active: List[_Slot]) -> None:
        """One scheduler round: stage fresh requests, overlap host
        segments with accelerator segments, gang same-program
        same-segment requests, then retire finished ones."""
        # stage inputs of freshly admitted requests (swapping the slot's
        # resident session state first when the request belongs to a
        # different session than the last one served here)
        for slot in active:
            req = slot.active
            if req.step_idx < 0:
                try:
                    self._ensure_resident(slot, req)
                    req.future.staging_bytes = req.prog.stage_inputs(
                        req.inputs, device=slot.device)
                    slot.stats.staging_bytes += req.future.staging_bytes
                    req.inputs = {}
                    req.step_idx = 0
                except BaseException as e:
                    self._retire(slot, error=e)
                    return

        # split this round's work: host segments first (dispatched to a
        # worker thread so they overlap the accel gangs below — the GIL
        # drops while the gang's kernels run inside XLA)
        def step_of(s: _Slot):
            req = s.active
            if req is None or req.retired or \
                    req.step_idx >= len(req.prog.steps):
                return None
            return req.prog.steps[req.step_idx]

        host_slots = [s for s in active
                      if isinstance(step_of(s), CpuStep)]
        accel_slots = [s for s in active
                       if isinstance(step_of(s), AccelStep)]

        host_errs: Dict[int, BaseException] = {}
        host_done: Optional[threading.Event] = None
        if host_slots:
            host_done = threading.Event()
            self._host_q.put(([(s, s.active) for s in host_slots],
                              host_errs, host_done))

        # accelerator segments: group SAME-PROGRAM same-step requests
        # into gangs (the streams must be identical for lockstep
        # execution; different programs never gang)
        accel_errs: Dict[int, BaseException] = {}
        try:
            by_key: Dict[Tuple[int, int], List[_Slot]] = {}
            for slot in accel_slots:
                key = (self._prog_key[id(slot.active.prog)],
                       slot.active.step_idx)
                by_key.setdefault(key, []).append(slot)
            for (_, idx), group in by_key.items():
                prog = group[0].active.prog
                try:
                    self._exec_accel(prog, prog.steps[idx], group)
                except BaseException as e:
                    # fail ONLY the gang that raised; other requests in
                    # this round proceed untouched
                    for slot in group:
                        accel_errs[slot.id] = e
        finally:
            if host_done is not None:
                # watchdog: a dead host worker must fail the round's
                # host requests, not deadlock the whole pool
                while not host_done.wait(1.0):
                    if not self._host_thread.is_alive():
                        dead = PoolClosed(
                            "pool host worker died mid-round")
                        for slot in host_slots:
                            host_errs.setdefault(slot.id, dead)
                        break

        # advance + retire
        for slot in list(active):
            req = slot.active
            if req is None:
                continue
            if req.retired:                      # killed mid-round
                slot.active = None
                continue
            err = host_errs.get(slot.id) or accel_errs.get(slot.id)
            if err is not None:
                self._retire(slot, error=err)
                continue
            req.step_idx += 1
            if req.step_idx >= len(req.prog.steps):
                self._retire(slot)

    def _exec_accel(self, prog: CompiledProgram, step: AccelStep,
                    group: List[_Slot]) -> None:
        """Run one accelerator segment for every slot in `group` — as a
        lockstep gang when the engine supports it (identical pre-staged
        stream on every slot), serially otherwise."""
        gang = getattr(self.engine, "execute_gang", None)
        prestaged = prog.prestage and step.staged_addr >= 0
        if gang is not None and len(group) > 1 and prestaged:
            statss = gang(prog.spec, [s.device for s in group],
                          step.stream, timing=self.timing,
                          staged_addr=step.staged_addr)
            for slot, stats in zip(group, statss):
                stats.n_join_barriers = step.n_barriers
                stats.n_buffer_fences = step.n_fences
                stats.staging_bytes_per_call = \
                    slot.active.future.staging_bytes
                slot.active.future.stats.append(stats)
                slot.stats.accel_steps += 1
                slot.stats.ganged_steps += 1
                slot.stats.max_gang = max(slot.stats.max_gang, len(group))
                slot.stats.tiles_resolved += stats.tiles_resolved
                slot.stats.tile_batches += stats.tile_batches
            return
        for slot in group:
            stats = prog.exec_step(step, slot.device, self.engine,
                                   timing=self.timing)
            stats.staging_bytes_per_call = slot.active.future.staging_bytes
            slot.active.future.stats.append(stats)
            slot.stats.accel_steps += 1
            slot.stats.max_gang = max(slot.stats.max_gang, 1)
            slot.stats.tiles_resolved += stats.tiles_resolved
            slot.stats.tile_batches += stats.tile_batches

    def _retire(self, slot: _Slot, error: Optional[BaseException] = None
                ) -> None:
        req = slot.active
        with self._lock:
            slot.active = None
            if req is None or req.retired:
                return                          # killed while executing
            req.retired = True
        if error is not None:
            req.future._fail(error)
        else:
            try:
                req.future._finish(
                    req.prog.read_outputs(device=slot.device))
                slot.stats.calls += 1
                if req.session is not None:
                    req.session.calls += 1
            except BaseException as e:
                req.future._fail(e)
        with self._lock:
            self._inflight -= 1
            self._idle.notify_all()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def slot_stats(self) -> List[SlotStats]:
        return [s.stats for s in self.slots]

    def describe(self) -> str:
        """``CompiledProgram.describe()`` of every staged program
        (per-device invariants hold per slot) plus one serving line per
        slot, including live queue depth."""
        lines = [c.describe() for c in self.programs]
        lines.append(f"pool[{len(self.slots)} slots, {self.engine.name}, "
                     f"{self.policy}, {len(self.programs)} program(s)]")
        stateful = any(c.persistent_ids for c in self.programs)
        for s in self.slots:
            st = s.stats
            line = (
                f"  slot{s.id}: {st.calls} calls, {st.staging_bytes}B "
                f"staged, {st.accel_steps} accel steps "
                f"({st.ganged_steps} ganged, max gang {st.max_gang}), "
                f"{st.cpu_steps} host steps, "
                f"{st.tiles_resolved} tiles / {st.tile_batches} launches, "
                f"q{len(s.queue)} (hiwater {st.queue_hiwater})")
            if s.dead:
                line += " [DEAD]"
            if stateful:
                nsess = sum(1 for x in self._sessions.values()
                            if x.slot_id == s.id)
                res = ",".join(f"sid{sid}" for sid in s.resident.values()) \
                    or "-"
                line += (f", {nsess} sessions ({res} resident, "
                         f"{st.session_swaps} swaps, "
                         f"{st.persist_hiwater}B hiwater)")
            lines.append(line)
        return "\n".join(lines)


# ----------------------------------------------------------------------
# batch serving
# ----------------------------------------------------------------------
class BatchServer:
    """Shards a batch of requests across a DevicePool and gathers the
    results in submission order.

        server = BatchServer(pool)
        outs = server([{"x": x0}, {"x": x1}, ...])   # outs[i] <-> req i

    Construction can also own the pool: ``BatchServer.build(compiled,
    size=4, policy="least_loaded")``."""

    def __init__(self, pool: DevicePool):
        self.pool = pool

    @classmethod
    def build(cls, compiled: CompiledProgram, size: int = 2,
              **pool_kw) -> "BatchServer":
        return cls(DevicePool(compiled, size=size, **pool_kw))

    def __call__(self, requests: Sequence[Dict[str, np.ndarray]],
                 timeout: Optional[float] = None
                 ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
        futures = [self.pool.submit(**req) for req in requests]
        return [f.wait(timeout=timeout) for f in futures]

    def submit_all(self, requests: Sequence[Dict[str, np.ndarray]]
                   ) -> List[PoolFuture]:
        return [self.pool.submit(**req) for req in requests]

    def close(self) -> None:
        self.pool.close()

    def __enter__(self) -> "BatchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_batch(compiled: CompiledProgram,
                requests: Sequence[Dict[str, np.ndarray]],
                size: int = 2, **pool_kw
                ) -> List[Union[np.ndarray, Dict[str, np.ndarray]]]:
    """One-shot convenience: pool up, shard `requests`, gather in order,
    tear down."""
    with BatchServer.build(compiled, size=size, **pool_kw) as server:
        return server(requests)
