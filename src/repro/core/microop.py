"""VTA RISC micro-ops (the lower level of the two-level ISA).

A micro-op is a 32-bit word holding three scratchpad indices
(dst = accumulator / register-file, src = input (GEMM) or accumulator
(ALU), wgt = weight).  The compute core executes a micro-op *sequence*
inside a 2-level nested loop; the effective index of each operand is an
affine function of the loop variables (§2.5):

    dst_idx = uop.dst + i0 * dst_factor_out + i1 * dst_factor_in
    src_idx = uop.src + i0 * src_factor_out + i1 * src_factor_in
    wgt_idx = uop.wgt + i0 * wgt_factor_out + i1 * wgt_factor_in

This loop compression keeps micro-kernels tiny (no control flow) while
covering matmul and 2D convolution access patterns.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from .hwspec import HardwareSpec


@dataclass(frozen=True)
class UOp:
    dst: int          # accumulator (register file) index
    src: int          # input-buffer index (GEMM) or accumulator index (ALU)
    wgt: int = 0      # weight-buffer index (GEMM only)


class UopLayout:
    def __init__(self, spec: HardwareSpec):
        self.dst_bits = spec.acc_addr_bits
        self.src_bits = max(spec.inp_addr_bits, spec.acc_addr_bits)
        self.wgt_bits = spec.wgt_addr_bits
        total = self.dst_bits + self.src_bits + self.wgt_bits
        if total > spec.uop_bits:
            raise ValueError(
                f"uop fields ({total} bits) exceed uop width {spec.uop_bits}; "
                "shrink SRAM depths or widen uops")

    def encode(self, u: UOp) -> int:
        for v, b, n in ((u.dst, self.dst_bits, "dst"),
                        (u.src, self.src_bits, "src"),
                        (u.wgt, self.wgt_bits, "wgt")):
            if v < 0 or v >= (1 << b):
                raise ValueError(f"uop field {n}={v} does not fit {b} bits")
        return u.dst | (u.src << self.dst_bits) | (
            u.wgt << (self.dst_bits + self.src_bits))

    def decode(self, word: int) -> UOp:
        word = int(word)
        dst = word & ((1 << self.dst_bits) - 1)
        src = (word >> self.dst_bits) & ((1 << self.src_bits) - 1)
        wgt = (word >> (self.dst_bits + self.src_bits)) & ((1 << self.wgt_bits) - 1)
        return UOp(dst, src, wgt)

    def encode_kernel(self, uops: List[UOp]) -> np.ndarray:
        return np.array([self.encode(u) for u in uops], dtype=np.uint32)

    def decode_kernel(self, words: np.ndarray) -> List[UOp]:
        return [self.decode(w) for w in np.asarray(words).ravel()]
