"""Program-level JIT: compile a multi-op graph into one task-ISA stream.

The paper's runtime is not a per-op affair: its JIT compiler lowers whole
model graphs into instruction streams and splits work heterogeneously
between CPU and accelerator (§3, Fig. 16; TVM, arXiv 1802.04799).  This
module is that module-level JIT for the port:

    prog = Program(spec)
    x = prog.input("x", (128, 256))
    w1 = prog.input("w1", (256, 256))
    w2 = prog.input("w2", (64, 256))
    h = prog.matmul(x, w1, epilogue=Epilogue(shift=7, relu=True))
    y = prog.matmul(h, w2, epilogue=Epilogue(shift=7))
    compiled = prog.compile()
    out = compiled(x=..., w1=..., w2=...)          # simulator
    out = compiled(backend="pallas", x=..., ...)   # same stream, fast path

``compile()`` runs the whole lowering once — SRAM liveness across ops,
cross-op WAR/RAW dependence tokens (buffer-granular fences by default,
``fence_mode="barrier"`` for the A/B baseline), stream segmentation
around ``cpu_only`` ops — and the result is cached by ``(spec, graph
signature, fence_mode, prestage)``: a second call with new data only
rebinds the DRAM input buffers and re-runs the already-encoded streams
(the paper's JIT-cost amortization).  Intermediate tensors chain through
DRAM in their blocked layouts; no host relayout happens between fused
ops.

The compiled artifact is serving-oriented: encoded streams and
``Program.constant`` (weight) tensors are staged into DRAM exactly once
at compile time, and a liveness pass recycles dead intermediate buffers
through a fixed-size arena — repeat calls perform zero DRAM allocation,
so the memory image stays constant across arbitrarily long serving loops
(counter-tested).

Three DRAM liveness classes exist:

  * **constants** (``Program.constant``) — staged once at compile time,
    read-only forever after;
  * **intermediates** — recycled through the arena, dead at their last
    reader within one call;
  * **persistent** state (``Program.persistent``) — buffers that survive
    ACROSS calls: KV caches, recurrent state, accumulators.  They are
    allocated once at stable addresses, excluded from arena recycling,
    excluded from per-call input staging, and mutated in place by host
    ops declared with ``Program.host(..., updates=(ref, ...))``.  A
    compiled program with persistent state is a *session*: calling it N
    times advances the state N steps, and ``serve.DevicePool`` clones
    give every pool slot its own independent session state.
"""
from __future__ import annotations

import hashlib
import threading
import zlib
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import hwspec as _hwspec, layout
from .backend import BackendLike, resolve_backend
from .compiler import (AccelStep, ArenaAllocator, CpuStep, ImageRange,
                       SegmentBuilder)
from .conv import (ConvShape, conv1x1_eligible, conv2d_reference,
                   lower_conv1x1, lower_conv2d, lower_conv_im2col,
                   select_conv_lowering)
from .hwspec import HardwareSpec
from .isa import AluOp, MemId
from .runtime import Runtime
from .scheduler import Epilogue, SramPartition, _ceil_div, lower_matmul, \
    lower_vector_binop
from .simulator import RunStats

# Counts every accelerator-segment build (scheduling + encoding).  Tests
# assert it stays flat across repeated CompiledProgram calls and cached
# compiles — the JIT-amortization contract.
STREAM_BUILDS = 0

_COMPILE_CACHE: Dict[Any, "CompiledProgram"] = {}


def clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()


# ----------------------------------------------------------------------
# tensor metadata: logical shape + blocked DRAM layout
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class TensorMeta:
    """How a graph tensor lives in DRAM.

    kind: "mat"  — (M, C) blocked (Mb, Cb, BATCH, block)
          "wgt"  — (N, K) blocked (Nb, Kb, BLOCK_OUT, BLOCK_IN)
          "conv" — (N, C, H, W) blocked (Nb, Cb, H, W, BATCH, block)
          "cwgt" — (OC, IC, KH, KW) blocked (OCb, Cb, KH, KW, B_OUT, B_IN)
          "vec"  — (n,) blocked (ne, BATCH, BLOCK_OUT)
    block: the channel/column block size (BLOCK_IN for accelerator inputs,
    BLOCK_OUT for accelerator outputs — compatible when they are equal,
    which is what lets op outputs chain into op inputs with zero copies).
    """
    kind: str
    shape: Tuple[int, ...]
    dtype: str            # "int8" | "int32"
    block: int = 0

    def np_dtype(self):
        return np.int8 if self.dtype == "int8" else np.int32

    def blocked_shape(self, spec: HardwareSpec) -> Tuple[int, ...]:
        if self.kind == "mat":
            M, C = self.shape
            return (_ceil_div(M, spec.batch), _ceil_div(C, self.block),
                    spec.batch, self.block)
        if self.kind == "wgt":
            N, K = self.shape
            return (_ceil_div(N, spec.block_out), _ceil_div(K, spec.block_in),
                    spec.block_out, spec.block_in)
        if self.kind == "conv":
            N, C, H, W = self.shape
            return (_ceil_div(N, spec.batch), _ceil_div(C, self.block),
                    H, W, spec.batch, self.block)
        if self.kind == "cwgt":
            OC, IC, KH, KW = self.shape
            return (_ceil_div(OC, spec.block_out),
                    _ceil_div(IC, spec.block_in),
                    KH, KW, spec.block_out, spec.block_in)
        if self.kind == "vec":
            (n,) = self.shape
            lane = spec.batch * spec.block_out
            return (_ceil_div(n, lane), spec.batch, spec.block_out)
        raise ValueError(self.kind)

    def is_packed(self, spec: HardwareSpec) -> bool:
        """Sub-byte DRAM storage: weight kinds under a wgt_bits<8 spec
        store b-bit packed bytes (``layout.pack_bits``) instead of one
        int8 per value.  Activations/accumulators never pack."""
        return self.kind in ("wgt", "cwgt") and spec.wgt_packed

    def storage_shape(self, spec: HardwareSpec) -> Tuple[int, ...]:
        """Shape of the array actually living in DRAM: the blocked shape,
        except packed weights collapse the trailing (BLOCK_OUT, BLOCK_IN)
        element into `wgt_elem_bytes` packed bytes."""
        bs = self.blocked_shape(spec)
        if self.is_packed(spec):
            return bs[:-2] + (spec.wgt_elem_bytes,)
        return bs

    def storage_dtype(self, spec: HardwareSpec):
        return np.uint8 if self.is_packed(spec) else self.np_dtype()

    def nbytes(self, spec: HardwareSpec) -> int:
        return int(np.prod(self.storage_shape(spec))) \
            * np.dtype(self.storage_dtype(spec)).itemsize

    def elem_bytes(self, spec: HardwareSpec) -> int:
        """Bytes per DMA element (one tensor-register row) of this layout —
        the buffer's required DRAM alignment.  For weight kinds this is
        `spec.wgt_elem_bytes`, which already shrinks with wgt_bits."""
        if self.kind in ("wgt", "cwgt"):
            return spec.wgt_elem_bytes
        bs = self.blocked_shape(spec)
        return int(np.prod(bs[-2:])) * np.dtype(self.np_dtype()).itemsize

    # ---- host <-> blocked DRAM image ----
    def pack(self, arr: np.ndarray, spec: HardwareSpec) -> np.ndarray:
        arr = np.asarray(arr, self.np_dtype())
        if arr.shape != self.shape:
            raise ValueError(f"expected shape {self.shape}, got {arr.shape}")
        if self.kind == "mat":
            blocked = layout.block2d(arr, spec.batch, self.block)
        elif self.kind == "wgt":
            blocked = layout.block2d(arr, spec.block_out, spec.block_in)
        elif self.kind == "conv":
            blocked = layout.block_nchw(arr, spec.batch, self.block)
        elif self.kind == "cwgt":
            blocked = layout.block_nchw(arr, spec.block_out, spec.block_in)
        elif self.kind == "vec":
            blocked = np.zeros(self.blocked_shape(spec), self.np_dtype())
            blocked.reshape(-1)[:arr.size] = arr
        else:
            raise ValueError(self.kind)
        if self.is_packed(spec):
            return layout.pack_wgt_elems(blocked, spec.wgt_bits)
        return blocked

    def unpack(self, blocked: np.ndarray, spec: HardwareSpec) -> np.ndarray:
        if self.is_packed(spec):
            blocked = layout.unpack_wgt_elems(
                blocked, spec.wgt_bits, spec.block_out, spec.block_in)
        if self.kind in ("mat", "wgt"):
            return layout.unblock2d(blocked, *self.shape)
        if self.kind in ("conv", "cwgt"):
            return layout.unblock_nchw(blocked, self.shape[0], self.shape[1])
        if self.kind == "vec":
            return blocked.reshape(-1)[:self.shape[0]].copy()
        raise ValueError(self.kind)


@dataclass(frozen=True)
class TensorRef:
    """Handle to a graph tensor (input or op result)."""
    idx: int
    program: "Program" = field(repr=False, compare=False)

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.program.nodes[self.idx].shape


@dataclass
class Node:
    idx: int
    op: str                      # input | matmul | conv2d | vbinop | cpu
    name: str
    inputs: Tuple[int, ...] = ()
    shape: Tuple[int, ...] = ()
    meta: Optional[TensorMeta] = None
    epilogue: Optional[Epilogue] = None
    conv: Optional[ConvShape] = None
    alu_op: Optional[AluOp] = None
    lowering: Optional[str] = None  # resolved conv mode (see conv.py rules)
    declared_dtype: str = "int8"
    fn: Optional[Callable] = None
    fn_key: Optional[str] = None   # stable cache key for host fns
    const: Optional[np.ndarray] = None  # graph constant: staged at compile
    # persistent liveness class: the buffer survives across calls (its
    # init image is in `const`); `updates` on a cpu node names the
    # persistent nodes its fn mutates in place each call
    persistent: bool = False
    updates: Tuple[int, ...] = ()


def _epilogue_sig(ep: Optional[Epilogue]):
    if ep is None:
        return None
    bias = None
    if ep.bias_blocked is not None:
        bias = hashlib.sha1(
            np.ascontiguousarray(ep.bias_blocked, np.int32).tobytes()
        ).hexdigest()
    return (ep.shift, ep.clip_lo, ep.clip_hi, ep.relu, bias)


# ----------------------------------------------------------------------
# the graph builder
# ----------------------------------------------------------------------
class Program:
    """Declarative multi-op graph over one VTA template instance."""

    def __init__(self, spec: Optional[HardwareSpec] = None,
                 virtual_threads: int = 2):
        self.spec = spec or _hwspec.pynq()
        self.virtual_threads = virtual_threads
        self.nodes: List[Node] = []
        self._outputs: List[int] = []

    # ------------------------------------------------------------------
    def _add(self, node: Node) -> TensorRef:
        if any(n.name == node.name for n in self.nodes):
            raise ValueError(f"duplicate node name {node.name!r}")
        self.nodes.append(node)
        return TensorRef(node.idx, self)

    def _node(self, ref: TensorRef) -> Node:
        if ref.program is not self:
            raise ValueError("TensorRef belongs to a different Program")
        return self.nodes[ref.idx]

    def _require(self, ref: TensorRef, meta: TensorMeta, role: str) -> Node:
        """Bind (for inputs) or check (for op results) a tensor's layout."""
        node = self._node(ref)
        if node.meta is None:
            if node.op == "input" and node.declared_dtype != meta.dtype:
                raise ValueError(
                    f"input {node.name!r} declared {node.declared_dtype} "
                    f"but {role} consumes {meta.dtype}")
            node.meta = meta
            return node
        m = node.meta
        if (m.kind, m.dtype) != (meta.kind, meta.dtype) or \
                (meta.block and m.block != meta.block):
            raise ValueError(
                f"node {node.name!r} has layout {m} but {role} needs "
                f"{meta}; chain through a host op to relayout")
        return node

    # ------------------------------------------------------------------
    # graph construction
    # ------------------------------------------------------------------
    def input(self, name: str, shape: Sequence[int],
              dtype: str = "int8") -> TensorRef:
        return self._add(Node(idx=len(self.nodes), op="input", name=name,
                              shape=tuple(shape), declared_dtype=dtype))

    def constant(self, name: str, value: np.ndarray,
                 dtype: Optional[str] = None) -> TensorRef:
        """Graph-constant input (weights, lookup tables): packed and
        staged into DRAM once at compile time.  Calls neither pass nor
        re-pack it — the serving fast path pays zero per-call staging for
        constants.  The value participates in the compile-cache signature
        (content hash)."""
        arr = np.asarray(value)
        if dtype is None:
            dtype = "int32" if arr.dtype == np.int32 else "int8"
        arr = arr.astype(np.int32 if dtype == "int32" else np.int8,
                         copy=False)
        return self._add(Node(idx=len(self.nodes), op="input", name=name,
                              shape=tuple(arr.shape), declared_dtype=dtype,
                              const=arr))

    def persistent(self, name: str, shape: Sequence[int],
                   dtype: str = "int8", kind: Optional[str] = None,
                   block: Optional[int] = None,
                   init: Optional[np.ndarray] = None) -> TensorRef:
        """Persistent-state buffer: DRAM that SURVIVES across calls.

        The buffer is allocated once at a stable address (outside the
        intermediate arena, never recycled), its init image (`init`, or
        zeros) is staged at compile time like a constant, and calls
        neither stage nor require it as an input.  Accelerator ops may
        read it like any graph tensor; host ops mutate it in place via
        ``host(..., updates=(ref, ...))``.  This is the liveness class a
        KV cache or recurrent state lives in: zero per-call allocation,
        state advancing call over call, per-device-clone isolation (each
        ``serve.DevicePool`` slot owns its own copy = its own session).

        kind/block fix the DRAM layout up front (host ops require a
        bound layout): by default 2-D int8 buffers are "mat" blocked by
        BLOCK_IN (consumable as a matmul A operand), 1-D buffers are
        "vec" lanes, 4-D are "conv"."""
        spec = self.spec
        shape = tuple(shape)
        if kind is None:
            kind = {1: "vec", 2: "mat", 4: "conv"}.get(len(shape))
            if kind is None:
                raise ValueError(f"cannot infer layout kind for a "
                                 f"{len(shape)}-D persistent buffer; "
                                 "pass kind=")
        if block is None:
            block = spec.block_out if kind == "vec" else spec.block_in
        meta = TensorMeta(kind, shape, dtype, block)
        if init is None:
            init = np.zeros(shape, meta.np_dtype())
        init = np.asarray(init, meta.np_dtype())
        if init.shape != shape:
            raise ValueError(f"persistent {name!r} init shape {init.shape}"
                             f" != {shape}")
        return self._add(Node(idx=len(self.nodes), op="input", name=name,
                              shape=shape, declared_dtype=dtype, meta=meta,
                              const=init, persistent=True))

    def matmul(self, a: TensorRef, w: TensorRef,
               epilogue: Optional[Epilogue] = None,
               name: Optional[str] = None) -> TensorRef:
        """C[M,N] = clip((A[M,K] @ W[N,K]^T + bias) >> shift)."""
        spec = self.spec
        M, K = self._node(a).shape
        N, K2 = self._node(w).shape
        if K != K2:
            raise ValueError(f"matmul K mismatch: {K} vs {K2}")
        self._require(a, TensorMeta("mat", (M, K), "int8",
                                    spec.block_in), "matmul A")
        self._require(w, TensorMeta("wgt", (N, K), "int8"), "matmul W")
        idx = len(self.nodes)
        return self._add(Node(
            idx=idx, op="matmul", name=name or f"matmul{idx}",
            inputs=(a.idx, w.idx), shape=(M, N),
            meta=TensorMeta("mat", (M, N), "int8", spec.block_out),
            epilogue=epilogue))

    def conv2d(self, x: TensorRef, w: TensorRef, shape: ConvShape,
               epilogue: Optional[Epilogue] = None, cpu_only: bool = False,
               fast_1x1: bool = True, name: Optional[str] = None,
               lowering: Optional[str] = None) -> TensorRef:
        """y = conv2d(x, w) (+epilogue).  cpu_only ops run host-side between
        accelerator segments (the paper's C1 split).

        lowering selects the accelerator schedule ("direct" | "im2col" |
        "via_matmul"; None auto-selects per the rules in conv.py).  An
        explicit request is validated HERE, at graph-build time, so an
        infeasible choice fails with an actionable message instead of a
        generic error deep inside a lowering pass.  Auto resolves the
        structural pointwise fast path here too; every OTHER auto shape
        stays pending (node.lowering=None) until ``compile()``, which
        consults the tuning cache and falls back to the replayed-cycle
        comparison (see conv.select_conv_lowering) — so a tuned record
        can steer the pick without rebuilding the graph.  The resolved
        mode shows up in ``CompiledProgram.describe()``.  fast_1x1=False
        is the legacy spelling of lowering="direct"."""
        spec = self.spec
        if cpu_only:
            if lowering is not None:
                raise ValueError("cpu_only conv2d nodes run host-side; "
                                 "lowering= does not apply")
        else:
            req = (lowering if lowering is not None
                   else (None if fast_1x1 else "direct"))
            if req in (None, "auto"):
                lowering = ("via_matmul"
                            if conv1x1_eligible(shape, spec) else None)
            else:
                lowering = select_conv_lowering(shape, spec, req)
        if self._node(x).shape != (shape.n, shape.ic, shape.h, shape.w):
            raise ValueError(f"conv input shape {self._node(x).shape} != "
                             f"{(shape.n, shape.ic, shape.h, shape.w)}")
        if self._node(w).shape != (shape.oc, shape.ic, shape.kh, shape.kw):
            raise ValueError("conv weight shape mismatch")
        self._require(x, TensorMeta("conv", self._node(x).shape, "int8",
                                    spec.block_in), "conv2d x")
        self._require(w, TensorMeta("cwgt", self._node(w).shape, "int8"),
                      "conv2d w")
        idx = len(self.nodes)
        out_shape = (shape.n, shape.oc, shape.oh, shape.ow)
        if cpu_only:
            ep = epilogue
            return self._add(Node(
                idx=idx, op="cpu", name=name or f"cpu_conv{idx}",
                inputs=(x.idx, w.idx), shape=out_shape,
                # host output is packed consumer-ready (BLOCK_IN channels)
                meta=TensorMeta("conv", out_shape, "int8", spec.block_in),
                conv=shape, epilogue=epilogue,
                fn=lambda xv, wv, _s=shape, _e=ep: conv2d_reference(
                    xv, wv, _s, epilogue=_e),
                fn_key=f"conv2d_reference.{shape}.{_epilogue_sig(epilogue)}"))
        return self._add(Node(
            idx=idx, op="conv2d", name=name or f"conv{idx}",
            inputs=(x.idx, w.idx), shape=out_shape,
            meta=TensorMeta("conv", out_shape, "int8", spec.block_out),
            epilogue=epilogue, conv=shape, lowering=lowering))

    def vector_binop(self, a: TensorRef, b: TensorRef,
                     op: AluOp = AluOp.ADD,
                     name: Optional[str] = None) -> TensorRef:
        """c = a (op) b over int32 vectors through the tensor ALU; the
        result is the narrowed int8 out-store (Listing 1 semantics)."""
        spec = self.spec
        (n,) = self._node(a).shape
        if self._node(b).shape != (n,):
            raise ValueError("vector_binop length mismatch")
        self._require(a, TensorMeta("vec", (n,), "int32",
                                    spec.block_out), "vector a")
        self._require(b, TensorMeta("vec", (n,), "int32",
                                    spec.block_out), "vector b")
        idx = len(self.nodes)
        return self._add(Node(
            idx=idx, op="vbinop", name=name or f"vec{idx}",
            inputs=(a.idx, b.idx), shape=(n,),
            meta=TensorMeta("vec", (n,), "int8", spec.block_out),
            alu_op=op))

    def add(self, a: TensorRef, b: TensorRef, **kw) -> TensorRef:
        return self.vector_binop(a, b, op=AluOp.ADD, **kw)

    def host(self, fn: Callable, *args: TensorRef,
             shape: Sequence[int], kind: str = "conv", dtype: str = "int8",
             name: Optional[str] = None, key: Optional[str] = None,
             updates: Sequence[TensorRef] = ()) -> TensorRef:
        """Arbitrary host-side op on logical numpy arrays; splits the
        stream into accelerator segments around it.  Inputs must already
        have a bound layout (consume them with a typed op first, or use
        typed inputs).  Programs containing keyless host fns are not
        eligible for the compile cache.

        ``updates`` names persistent buffers this op mutates: the fn must
        then return ``(out, new_value, ...)`` — one extra array per
        update target, written back into the persistent buffer in place
        before the next step runs.  This is how a KV cache appends: pass
        the cache ref in ``args`` (to read it) AND in ``updates`` (to
        write the appended image back)."""
        spec = self.spec
        for r in args:
            if self._node(r).meta is None:
                raise ValueError(
                    f"host-op input {self._node(r).name!r} has no bound "
                    "layout yet — consume it with a typed op first")
        for r in updates:
            if not self._node(r).persistent:
                raise ValueError(
                    f"host-op update target {self._node(r).name!r} is not "
                    "a persistent buffer — only Program.persistent() "
                    "state may be mutated across calls")
        block = spec.block_out if kind == "vec" else spec.block_in
        idx = len(self.nodes)
        return self._add(Node(
            idx=idx, op="cpu", name=name or f"host{idx}",
            inputs=tuple(r.idx for r in args), shape=tuple(shape),
            meta=TensorMeta(kind, tuple(shape), dtype, block),
            fn=fn, fn_key=key, updates=tuple(r.idx for r in updates)))

    def output(self, ref: TensorRef) -> TensorRef:
        self._node(ref)
        if ref.idx not in self._outputs:
            self._outputs.append(ref.idx)
        return ref

    # ------------------------------------------------------------------
    # signature + compile
    # ------------------------------------------------------------------
    def signature(self):
        """Hashable description of (spec, graph); None if uncacheable
        (keyless host fns)."""
        rows = []
        for n in self.nodes:
            if n.op == "cpu" and n.fn_key is None:
                return None
            const_sig = None
            if n.const is not None:
                const_sig = hashlib.sha1(
                    np.ascontiguousarray(n.const).tobytes()).hexdigest()
            rows.append((n.op, n.name, n.inputs, n.shape,
                         n.meta, _epilogue_sig(n.epilogue), n.conv,
                         n.alu_op, n.lowering, n.fn_key, const_sig,
                         n.persistent, n.updates))
        return (self.spec, self.virtual_threads, tuple(rows),
                tuple(self._outputs))

    def compile(self, use_cache: bool = True, fence_mode: str = "buffer",
                prestage: bool = True,
                device: Any = None) -> "CompiledProgram":
        """Lower the graph into encoded stream segments.

        Consults the global :class:`autotune.TuningCache` first: every
        accelerator op node looks up its per-(spec, op-signature) record
        — a hit steers pending conv lowerings (and is counted on
        ``CompiledProgram.tune_hits``; misses fall back to the
        replayed-cycle comparison and count on ``tune_misses``).  The
        resolved decisions are part of the compile-cache key, so a
        tuning record landing between two compiles of the same graph
        changes the artifact instead of hitting a stale cache entry.

        fence_mode: "buffer" (default) separates dependent ops with
        buffer-granular fences (only the consumer's loads of the produced
        buffer wait on the producer's final store — dependent layers
        double-buffer across the op boundary); "barrier" keeps the full
        join_barrier rendezvous as the A/B baseline.  prestage: stage the
        encoded streams into DRAM at compile time so repeat calls perform
        zero DRAM allocation (False re-stages per call — the pre-PR
        behavior, kept for A/B benchmarking).  device: stage into an
        EXISTING device instead of a fresh one — the bump allocator
        continues above whatever is already staged there, so several
        programs co-stage at disjoint DRAM ranges in one image (see
        :func:`compile_multi`).  Co-staged artifacts are device-bound
        and therefore never enter the compile cache."""
        sig = self.signature()
        tuned = _resolve_tuning(self)
        key = None if sig is None or device is not None \
            else (sig, fence_mode, prestage, tuned.decisions)
        if use_cache and key is not None and key in _COMPILE_CACHE:
            return _COMPILE_CACHE[key]
        compiled = _build(self, fence_mode=fence_mode, prestage=prestage,
                          device=device, tuned=tuned)
        if use_cache and key is not None:
            _COMPILE_CACHE[key] = compiled
        return compiled


def compile_multi(progs: Sequence[Program], fence_mode: str = "buffer",
                  prestage: bool = True) -> List["CompiledProgram"]:
    """Co-stage several programs into ONE resident DRAM image.

    Each program compiles against the same device, so the shared bump
    allocator hands every program a disjoint :class:`ImageRange` —
    constants, arena, persistent buffers and pre-staged streams of all
    programs coexist with every baked address valid.  A ``DevicePool``
    built from the returned list clones this one image per slot and
    serves the heterogeneous program mix; the continuous-batching
    scheduler (``core.sched``) gangs only same-program requests.

    Co-staged artifacts are device-bound: they bypass the compile cache
    and must not be mixed with independently compiled programs in one
    pool."""
    if not progs:
        raise ValueError("compile_multi of zero programs")
    out: List[CompiledProgram] = []
    device = None
    for p in progs:
        c = _build(p, fence_mode=fence_mode, prestage=prestage,
                   device=device)
        device = c.device
        out.append(c)
    for a, b in zip(out, out[1:]):
        assert not a.image_range.overlaps(b.image_range), \
            "co-staged programs overlap in DRAM — allocator invariant broken"
    return out


# ----------------------------------------------------------------------
# tuning-cache consultation (compile-time schedule resolution)
# ----------------------------------------------------------------------
def op_signature(program: Program, n: Node) -> str:
    """Stable per-op tuning key: what the node computes plus the schedule
    knobs that shape its stream — shape-level, never data-level, so two
    graphs differing only in weight values share tuning records, and
    string-valued so a persisted TuningCache can use it as a JSON key."""
    ep = n.epilogue.n_alu_passes if n.epilogue is not None else 0
    vt = program.virtual_threads
    if n.op == "conv2d":
        s = n.conv
        return (f"conv2d:n{s.n}.ic{s.ic}.h{s.h}.w{s.w}.k{s.kh}x{s.kw}"
                f".s{s.stride}.p{s.pad}.oc{s.oc}:ep{ep}:vt{vt}")
    if n.op == "matmul":
        a, w = (program.nodes[i] for i in n.inputs)
        return f"matmul:m{a.shape[0]}.k{a.shape[1]}.n{w.shape[0]}:ep{ep}:vt{vt}"
    if n.op == "vbinop":
        return f"vbinop:{n.shape[0]}.{n.alu_op}:vt{vt}"
    return f"{n.op}:{n.shape}"


@dataclass(frozen=True)
class _ResolvedTuning:
    """Outcome of one tuning-cache consultation: the graph's nodes with
    pending conv lowerings resolved, the (node-idx, mode) decisions (part
    of the compile-cache key), and the hit/miss tallies surfaced on the
    CompiledProgram."""
    nodes: Tuple[Node, ...]
    decisions: Tuple[Tuple[int, str], ...]
    hits: int
    misses: int


def _resolve_tuning(program: Program) -> _ResolvedTuning:
    """Consult the global :class:`autotune.TuningCache` for every
    accelerator op node and resolve pending (auto) conv lowerings.

    Lookup is per (spec, op-signature) — a different spec is a different
    key, so spec changes invalidate naturally.  A hit with a usable
    lowering steers a pending conv node; a miss (or a record whose mode
    the shape cannot take) falls back to the replayed-cycle comparison
    in ``conv.select_conv_lowering``.  Explicit user requests are never
    overridden."""
    from .autotune import global_cache
    cache = global_cache()
    hits = misses = 0
    nodes = list(program.nodes)
    decisions = []
    for i, n in enumerate(nodes):
        if n.op not in ("conv2d", "matmul"):
            continue
        rec = cache.lookup(program.spec, op_signature(program, n))
        if rec is not None:
            hits += 1
        else:
            misses += 1
        if n.op != "conv2d" or n.lowering is not None:
            continue
        mode = None
        if rec is not None and rec.lowering:
            try:
                mode = select_conv_lowering(n.conv, program.spec,
                                            rec.lowering)
            except ValueError:
                mode = None     # stale/shape-incompatible record
        if mode is None:
            mode = select_conv_lowering(
                n.conv, program.spec, None, epilogue=n.epilogue,
                virtual_threads=program.virtual_threads)
        nodes[i] = replace(n, lowering=mode)
        decisions.append((i, mode))
    return _ResolvedTuning(tuple(nodes), tuple(decisions), hits, misses)


# ----------------------------------------------------------------------
# compilation: graph -> buffers + encoded stream segments
# ----------------------------------------------------------------------
def _build(prog: Program, fence_mode: str = "buffer",
           prestage: bool = True, device: Any = None,
           tuned: Optional[_ResolvedTuning] = None) -> "CompiledProgram":
    global STREAM_BUILDS
    spec = prog.spec
    vt = prog.virtual_threads
    if tuned is None:
        tuned = _resolve_tuning(prog)
    # every decision below reads the RESOLVED node list: pending conv
    # lowerings are fixed modes by now, and the CompiledProgram carries
    # these copies so describe() shows what was actually lowered
    pnodes = list(tuned.nodes)
    rt = Runtime(spec, device=device)
    image_lo = rt.device.dram._next
    addrs: Dict[int, int] = {}

    # resolve output set first: a never-consumed input has no layout
    out_ids = list(prog._outputs)
    if not out_ids:
        non_inputs = [n.idx for n in pnodes if n.op != "input"]
        if not non_inputs:
            raise ValueError("empty program")
        out_ids = [non_inputs[-1]]

    # ---- DRAM liveness over intermediates (the serving arena) ----
    # last graph-order reader of each op result; inputs and program
    # outputs are persistent (rebound / read back every call)
    last_use: Dict[int, int] = {}
    for n in pnodes:
        for i in n.inputs:
            last_use[i] = n.idx
    stable = {n.idx for n in pnodes if n.op == "input"} | set(out_ids)
    arena_align = max(spec.inp_elem_bytes, spec.wgt_elem_bytes,
                      spec.acc_elem_bytes, spec.out_elem_bytes)
    arena = ArenaAllocator(lambda nb, al: rt.buffer_alloc(nb, align=al),
                           arena_align)

    def alloc_node(n: Node, sync: bool) -> int:
        """Assign node n's output DRAM buffer (idempotent).  sync=True
        marks a fence/barrier/segment placement — the arena may recycle
        dead intermediates (see ArenaAllocator.release_dead); only there
        is every earlier op's load ordered before any later op's store,
        so recycling cannot race through DRAM.  Inputs, program outputs
        and persistent buffers are stable: fresh, arena-exempt
        addresses."""
        if sync:
            arena.release_dead(n.idx)
        if n.idx in addrs:
            return addrs[n.idx]
        nbytes = n.meta.nbytes(spec)
        if n.idx in stable:
            addr = rt.buffer_alloc(nbytes, align=n.meta.elem_bytes(spec))
        else:
            addr = arena.alloc(nbytes, last_use.get(n.idx, 1 << 30))
        addrs[n.idx] = addr
        return addr

    for n in pnodes:
        if n.meta is None:
            raise ValueError(f"input {n.name!r} is never consumed — "
                             "its DRAM layout is undetermined")
        if n.op == "input":
            addrs[n.idx] = rt.buffer_alloc(n.meta.nbytes(spec),
                                           align=n.meta.elem_bytes(spec))
            if n.const is not None:
                # constants are staged exactly once, at compile time
                packed = n.meta.pack(n.const, spec)
                rt.device.dram.write(addrs[n.idx], packed)
                rt.device.flush_cache(addrs[n.idx], packed.nbytes)

    def elem(nid: int) -> int:
        n = pnodes[nid]
        return addrs[nid] // n.meta.elem_bytes(spec)

    # bias constants are part of the graph: staged at compile time
    bias_base: Dict[int, int] = {}
    for n in pnodes:
        if n.op in ("matmul", "conv2d") and n.epilogue is not None \
                and n.epilogue.bias_blocked is not None:
            addr = rt.copy_to_device(
                np.ascontiguousarray(n.epilogue.bias_blocked, np.int32),
                align=spec.acc_elem_bytes)
            bias_base[n.idx] = rt.to_elem_addr(addr, MemId.ACC)

    op_outputs = {n.idx for n in pnodes if n.op != "input"}

    # the accelerator node following each accelerator node *within its
    # segment* — a cpu step in between closes the stream, so ops separated
    # by one can never overlap and must not hedge SRAM for it
    next_in_segment: Dict[int, Node] = {}
    prev_accel: Optional[Node] = None
    for n in pnodes:
        if n.op == "cpu":
            prev_accel = None
        elif n.op in ("matmul", "conv2d", "vbinop"):
            if prev_accel is not None:
                next_in_segment[prev_accel.idx] = n
            prev_accel = n

    def make_lower(n: Node) -> Callable[..., None]:
        if n.op == "matmul":
            a, w = (pnodes[i] for i in n.inputs)
            Mb = _ceil_div(a.shape[0], spec.batch)
            Kb = _ceil_div(a.shape[1], spec.block_in)
            Nb = _ceil_div(w.shape[0], spec.block_out)

            def lower(sram, fenced=False, n=n, a=a, w=w, Mb=Mb, Nb=Nb,
                      Kb=Kb):
                lower_matmul(rt, a_base=elem(a.idx), w_base=elem(w.idx),
                             c_base=elem(n.idx), Mb=Mb, Nb=Nb, Kb=Kb,
                             epilogue=n.epilogue,
                             bias_base=bias_base.get(n.idx, -1),
                             virtual_threads=vt, sram=sram, fenced=fenced)
            return lower
        if n.op == "conv2d":
            x, w = (pnodes[i] for i in n.inputs)
            f = {"via_matmul": lower_conv1x1,
                 "im2col": lower_conv_im2col,
                 "direct": lower_conv2d}[n.lowering]

            def lower(sram, fenced=False, n=n, x=x, w=w, f=f):
                f(rt, x_base=elem(x.idx), w_base=elem(w.idx),
                  y_base=elem(n.idx), shape=n.conv, epilogue=n.epilogue,
                  bias_base=bias_base.get(n.idx, -1),
                  virtual_threads=vt, sram=sram, fenced=fenced)
            return lower
        if n.op == "vbinop":
            a, b = (pnodes[i] for i in n.inputs)
            ne = n.meta.blocked_shape(spec)[0]

            def lower(sram, fenced=False, n=n, a=a, b=b, ne=ne):
                lower_vector_binop(rt, a_base=elem(a.idx), b_base=elem(b.idx),
                                   c_base=elem(n.idx), ne=ne, op=n.alu_op,
                                   sram=sram)
            return lower
        raise ValueError(n.op)

    steps: List[Union[AccelStep, CpuStep]] = []
    seg = SegmentBuilder(rt, fence_mode=fence_mode)
    for n in pnodes:
        if n.op == "input":
            continue
        if n.op == "cpu":
            step = seg.finish()
            if step is not None:
                steps.append(step)
                STREAM_BUILDS += 1
            # the previous segment fully retires before the host step
            # runs, so this is a DRAM liveness point too
            alloc_node(n, sync=True)
            steps.append(CpuStep(node_id=n.idx))
            continue
        nxt = next_in_segment.get(n.idx)
        reads = {addrs[i] for i in n.inputs if i in op_outputs}
        seg.place(n.idx, reads=reads,
                  out_alloc=lambda sync, n=n: alloc_node(n, sync),
                  lower=make_lower(n),
                  wants_overlap=(nxt is not None
                                 and n.idx not in nxt.inputs),
                  succ_dependent=(nxt is not None
                                  and n.idx in nxt.inputs),
                  uses_load_queue=(n.op != "vbinop"))
    step = seg.finish()
    if step is not None:
        steps.append(step)
        STREAM_BUILDS += 1

    # ---- pre-stage the encoded streams (once, at compile time) ----
    staged_bytes = 0
    if prestage:
        for st in steps:
            if isinstance(st, AccelStep):
                st.staged_addr = rt.device.dram.alloc(st.stream.nbytes)
                rt.device.dram.write(st.staged_addr, st.stream)
                rt.device.flush_cache(st.staged_addr, st.stream.nbytes)
                staged_bytes += st.stream.nbytes

    input_ids = {n.name: n.idx for n in pnodes if n.op == "input"}
    const_names = {n.name for n in pnodes
                   if n.op == "input" and n.const is not None}
    persistent_ids = [n.idx for n in pnodes if n.persistent]
    const_bytes = sum(n.meta.nbytes(spec) for n in pnodes
                      if n.op == "input" and n.const is not None
                      and not n.persistent)
    return CompiledProgram(spec=spec, nodes=list(pnodes), addrs=addrs,
                           tune_hits=tuned.hits, tune_misses=tuned.misses,
                           steps=steps, input_ids=input_ids,
                           output_ids=out_ids, device=rt.device,
                           image_range=ImageRange(image_lo,
                                                  rt.device.dram._next),
                           fence_mode=fence_mode, prestage=prestage,
                           const_names=const_names,
                           staged_bytes=staged_bytes,
                           const_bytes=const_bytes,
                           arena_bytes=arena.bytes,
                           arena_blocks=arena.blocks,
                           arena_reuse_hits=arena.reuse_hits,
                           arena_splits=arena.splits,
                           n_intermediates=arena.intermediates,
                           persistent_ids=persistent_ids,
                           persistent_bytes=sum(
                               pnodes[i].meta.nbytes(spec)
                               for i in persistent_ids))


# ----------------------------------------------------------------------
# the compiled artifact
# ----------------------------------------------------------------------
@dataclass
class RunResult:
    """One execution of a CompiledProgram against SOME device: outputs,
    the per-segment RunStats, and the bytes staged for the call.  The
    value object the serving layer (``repro.core.serve``) passes around
    so concurrent requests never share mutable state."""
    outputs: Union[np.ndarray, Dict[str, np.ndarray]]
    stats: List[RunStats]
    staging_bytes: int


@dataclass
class CompiledProgram:
    """Encoded stream segments + bound DRAM buffers: call with new input
    data as many times as you like — no re-scheduling happens, and with
    ``prestage`` (default) no per-call DRAM allocation either: the DRAM
    image size is constant over arbitrarily long serving loops.

    Thread-safety: ``__call__`` serializes fully under ``_lock`` (it
    stages into the ONE shared compile-time device, so interleaving two
    calls would corrupt inputs mid-run); true concurrency goes through
    :meth:`run_on`, which executes against a caller-owned device clone
    and touches NO shared state — the entry point ``serve.DevicePool``
    uses, one clone per slot."""
    spec: HardwareSpec
    nodes: List[Node]
    addrs: Dict[int, int]
    steps: List[Union[AccelStep, CpuStep]]
    input_ids: Dict[str, int]
    output_ids: List[int]
    device: Any
    fence_mode: str = "buffer"
    prestage: bool = True
    const_names: set = field(default_factory=set)
    staged_bytes: int = 0          # encoded streams staged at compile time
    const_bytes: int = 0           # constants staged at compile time (as
    #                                stored: sub-byte weights count packed)
    arena_bytes: int = 0           # fresh DRAM backing the intermediate arena
    arena_blocks: int = 0
    arena_reuse_hits: int = 0      # intermediates served from a dead block
    arena_splits: int = 0          # free blocks split on best-fit reuse
    n_intermediates: int = 0
    persistent_ids: List[int] = field(default_factory=list)
    persistent_bytes: int = 0      # cross-call state at stable addresses
    # DRAM span this program's staged image occupies; co-staged programs
    # (compile_multi) get pairwise-disjoint ranges in one shared device
    image_range: Optional[ImageRange] = None
    calls: int = 0
    last_staging_bytes: int = 0    # bytes staged by the most recent call
    last_stats: List[RunStats] = field(default_factory=list)
    # tuning-cache consultation at compile time: how many accelerator op
    # nodes resolved from a TuningCache record (hits) vs fell back to
    # the default / cycle-compare path (misses)
    tune_hits: int = 0
    tune_misses: int = 0
    # per-(timing-model) memo of sched.stream_costs: ISA decode +
    # timing replay run once per program, shared by the Scheduler's
    # gang-width tuner and the autotuner's cycle oracle
    _cost_cache: Dict[Any, Any] = field(default_factory=dict, repr=False,
                                        compare=False)
    # serializes __call__ end to end: staging + execution share the one
    # compile-time device, and the mirrors above must match the call
    # that produced them.  run_on never takes it.
    _lock: Any = field(default_factory=threading.Lock, repr=False,
                       compare=False)

    # ---- introspection -------------------------------------------------
    @property
    def accel_steps(self) -> List[AccelStep]:
        return [s for s in self.steps if isinstance(s, AccelStep)]

    @property
    def cpu_steps(self) -> List[CpuStep]:
        return [s for s in self.steps if isinstance(s, CpuStep)]

    @property
    def insn_count(self) -> int:
        return sum(s.insn_count for s in self.accel_steps)

    @property
    def n_barriers(self) -> int:
        return sum(s.n_barriers for s in self.accel_steps)

    @property
    def n_fences(self) -> int:
        return sum(s.n_fences for s in self.accel_steps)

    @property
    def persistent_names(self) -> List[str]:
        return [self.nodes[i].name for i in self.persistent_ids]

    def describe(self) -> str:
        """One line per step; conv nodes carry their resolved lowering
        mode (direct | im2col | via_matmul), fenced producer->consumer
        edges are listed per segment, and the arena/staging summary shows
        what the serving fast path reuses.

        Everything in this line is per-DEVICE state: a
        ``serve.DevicePool`` clones the staged image once per slot, so
        the arena/staging figures hold for every slot independently —
        ``DevicePool.describe()`` prefixes this summary and appends one
        line per slot (calls served, staged bytes, tiles/launches, gang
        share); ``BatchServer`` shards across those slots."""
        def label(i: int) -> str:
            n = self.nodes[i]
            return f"{n.name}:{n.lowering}" if n.lowering else n.name

        parts = []
        for s in self.steps:
            if isinstance(s, AccelStep):
                names = ",".join(label(i) for i in s.node_ids)
                edges = ""
                if s.fence_edges:
                    edges = " (" + ",".join(
                        f"{self.nodes[p].name}->{self.nodes[c].name}"
                        for p, c in s.fence_edges) + ")"
                parts.append(f"accel[{names}: {s.insn_count} insns, "
                             f"{s.n_barriers} barriers, "
                             f"{s.n_fences} fences{edges}]")
            else:
                parts.append(f"cpu[{self.nodes[s.node_id].name}]")
        chain = " -> ".join(parts)
        tail = (f" | arena {self.arena_bytes}B/{self.arena_blocks} blocks "
                f"for {self.n_intermediates} intermediates "
                f"({self.arena_reuse_hits} reused, "
                f"{self.arena_splits} split)"
                f" | staged {self.staged_bytes}B"
                f" | tune {self.tune_hits} hit/"
                f"{self.tune_misses} miss")
        if self.const_bytes:
            tail += f" | constants {self.const_bytes}B"
            if self.spec.wgt_packed:
                tail += f" (wgt int{self.spec.wgt_bits} packed)"
        if self.persistent_ids:
            names = ",".join(
                f"{self.nodes[i].name}@{self.addrs[i]:#x}"
                for i in self.persistent_ids)
            tail += f" | persistent {self.persistent_bytes}B ({names})"
        if (self.image_range is not None
                and self.image_range.lo > self.device.dram.align):
            # co-staged above another program's image: show the range so
            # the multi-program layout is inspectable
            tail += (f" | image [{self.image_range.lo:#x},"
                     f"{self.image_range.hi:#x})")
        return chain + tail

    # ---- data movement -------------------------------------------------
    def _write(self, nid: int, arr: np.ndarray,
               device: Any = None) -> int:
        """Pack + stage one logical tensor into `device` (default: the
        compile-time device).  Pool slots pass their own clone — every
        buffer address is identical across clones of the staged image."""
        dev = device if device is not None else self.device
        node = self.nodes[nid]
        packed = node.meta.pack(arr, self.spec)
        dev.dram.write(self.addrs[nid], packed)
        dev.flush_cache(self.addrs[nid], packed.nbytes)
        return packed.nbytes

    def _read(self, nid: int, device: Any = None) -> np.ndarray:
        dev = device if device is not None else self.device
        node = self.nodes[nid]
        meta = node.meta
        blocked = dev.dram.read(
            self.addrs[nid], meta.nbytes(self.spec),
            dtype=meta.storage_dtype(self.spec),
            shape=meta.storage_shape(self.spec))
        return meta.unpack(blocked, self.spec)

    # ---- persistent state (sessions) -----------------------------------
    def read_persistent(self, name: str, device: Any = None) -> np.ndarray:
        """Logical (unpacked) value of one persistent buffer on `device`."""
        nid = self.input_ids[name]
        if not self.nodes[nid].persistent:
            raise ValueError(f"{name!r} is not a persistent buffer")
        return self._read(nid, device=device)

    def write_persistent(self, name: str, arr: np.ndarray,
                         device: Any = None) -> None:
        nid = self.input_ids[name]
        if not self.nodes[nid].persistent:
            raise ValueError(f"{name!r} is not a persistent buffer")
        self._write(nid, arr, device=device)

    def reset_persistent(self, device: Any = None) -> None:
        """Rewind `device`'s session state to the compile-time init
        images (a fresh session on the same slot)."""
        for nid in self.persistent_ids:
            self._write(nid, self.nodes[nid].const, device=device)

    def persistent_image(self, device: Any = None) -> Dict[str, np.ndarray]:
        """Raw blocked bytes of every persistent buffer on `device` — the
        portable session state.  Paired with :meth:`load_persistent_image`
        this is how the serving layer swaps sessions on a slot: plain
        DRAM writes at stable addresses, never an allocation, so the
        trimmed-clone zero-alloc contract holds across swaps."""
        dev = device if device is not None else self.device
        img = {}
        for nid in self.persistent_ids:
            n = self.nodes[nid]
            img[n.name] = dev.dram.read(
                self.addrs[nid], n.meta.nbytes(self.spec))
        return img

    def load_persistent_image(self, image: Dict[str, np.ndarray],
                              device: Any = None) -> None:
        dev = device if device is not None else self.device
        for nid in self.persistent_ids:
            n = self.nodes[nid]
            raw = image[n.name]
            dev.dram.write(self.addrs[nid], raw)
            dev.flush_cache(self.addrs[nid], raw.nbytes)

    # ---- DRAM integrity (self-healing serving) -------------------------
    def integrity_regions(self, persistent: bool = False
                          ) -> List[Tuple[str, int, int]]:
        """(name, addr, nbytes) of every checksummed DRAM region:
        compile-time constants by default (immutable for the program's
        lifetime — any change is corruption), or the persistent buffers
        with ``persistent=True`` (mutable only at call boundaries, so a
        checksum recorded after a call must still hold before the
        next)."""
        if persistent:
            ids = list(self.persistent_ids)
        else:
            ids = [n.idx for n in self.nodes
                   if n.op == "input" and n.const is not None
                   and not n.persistent]
        return [(self.nodes[i].name, self.addrs[i],
                 self.nodes[i].meta.nbytes(self.spec)) for i in ids]

    def integrity_checksum(self, device: Any = None,
                           persistent: bool = False) -> int:
        """CRC32 over the (fixed-order) concatenation of the integrity
        regions on `device`.  A mismatch against the pristine compile-
        time device (constants) or the last recorded post-call value
        (persistent) means the DRAM image was corrupted — the serving
        layer restages from pristine / restores from a session
        checkpoint instead of computing on flipped bits."""
        dev = device if device is not None else self.device
        crc = 0
        for _, addr, nbytes in self.integrity_regions(persistent):
            crc = zlib.crc32(dev.dram.read(addr, nbytes).tobytes(), crc)
        return crc

    def restage_constants(self, device: Any, pristine: Any = None) -> int:
        """Copy every constant region from the `pristine` device (default:
        the compile-time device) onto `device` — the repair action after
        an integrity failure.  Raw same-address writes, never an
        allocation.  Returns bytes restaged."""
        src = pristine if pristine is not None else self.device
        total = 0
        for _, addr, nbytes in self.integrity_regions():
            device.dram.write(addr, src.dram.read(addr, nbytes))
            device.flush_cache(addr, nbytes)
            total += nbytes
        return total

    # ---- execution -----------------------------------------------------
    def check_inputs(self, inputs: Dict[str, np.ndarray]) -> None:
        required = set(self.input_ids) - self.const_names
        missing = required - set(inputs)
        extra = set(inputs) - required
        if missing or extra:
            raise ValueError(f"inputs mismatch: missing {sorted(missing)}, "
                             f"unexpected {sorted(extra)}")

    def stage_inputs(self, inputs: Dict[str, np.ndarray],
                     device: Any = None) -> int:
        """Validate + write the call's activations into `device`; returns
        the staged byte count."""
        self.check_inputs(inputs)
        return sum(self._write(self.input_ids[name], arr, device=device)
                   for name, arr in inputs.items())

    def exec_step(self, step: Union[AccelStep, CpuStep], device: Any,
                  eng: Any, timing: Any = None) -> Optional[RunStats]:
        """Run ONE step of the program against `device`: accelerator
        segments hand the encoded stream to `eng` (kicking the pre-staged
        copy when available), host steps run the node's fn on logical
        arrays read from/written to the same device.  Returns the
        segment's RunStats (None for host steps).  Touches no shared
        mutable state — the pool scheduler interleaves steps of different
        requests through this hook."""
        if isinstance(step, AccelStep):
            if self.prestage and step.staged_addr >= 0:
                stats = eng.execute(self.spec, device, step.stream,
                                    timing=timing,
                                    staged_addr=step.staged_addr)
            else:
                stats = eng.execute(self.spec, device, step.stream,
                                    timing=timing)
            stats.n_join_barriers = step.n_barriers
            stats.n_buffer_fences = step.n_fences
            stats.persistent_bytes = self.persistent_bytes
            stats.tune_cache_hits = self.tune_hits
            stats.tune_cache_misses = self.tune_misses
            return stats
        node = self.nodes[step.node_id]
        args = [self._read(i, device=device) for i in node.inputs]
        res = node.fn(*args)
        if node.updates:
            # fn returned (out, new_state, ...): write each new state
            # image back into its persistent buffer IN PLACE — same
            # stable address every call, never an allocation
            out, *new_state = res
            for nid, arr in zip(node.updates, new_state):
                self._write(nid, arr, device=device)
        else:
            out = res
        self._write(step.node_id, out, device=device)
        return None

    def read_outputs(self, device: Any = None
                     ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        outs = {self.nodes[i].name: self._read(i, device=device)
                for i in self.output_ids}
        if len(outs) == 1:
            return next(iter(outs.values()))
        return outs

    def run_on(self, device: Any, backend: BackendLike = None,
               timing: Any = None,
               inputs: Optional[Dict[str, np.ndarray]] = None) -> RunResult:
        """Execute the whole program serially against an arbitrary device
        clone of the staged image.  Reentrant: shares NOTHING mutable
        with other run_on calls, so pool slots may run it concurrently —
        the per-slot invariant behind the serving layer."""
        staging = self.stage_inputs(dict(inputs or {}), device=device)
        eng = resolve_backend(backend)
        stats_list: List[RunStats] = []
        for step in self.steps:
            stats = self.exec_step(step, device, eng, timing=timing)
            if stats is not None:
                if not (self.prestage and step.staged_addr >= 0):
                    staging += step.stream.nbytes  # re-staged every call
                stats_list.append(stats)
        for s in stats_list:
            s.staging_bytes_per_call = staging
        return RunResult(outputs=self.read_outputs(device=device),
                         stats=stats_list, staging_bytes=staging)

    def __call__(self, backend: BackendLike = None, timing: Any = None,
                 **inputs: np.ndarray) -> Union[np.ndarray,
                                                Dict[str, np.ndarray]]:
        # the WHOLE call serializes under _lock, not just the mirror
        # update: the synchronous path shares ONE device image, so two
        # interleaved calls would stage over each other's inputs and
        # race the control registers.  Concurrency lives in
        # serve.DevicePool, which gives every request its own device
        # clone through run_on and never takes this lock.
        with self._lock:
            res = self.run_on(self.device, backend=backend, timing=timing,
                              inputs=inputs)
            self.calls += 1
            self.last_stats = res.stats
            self.last_staging_bytes = res.staging_bytes
        return res.outputs
