"""Design-space autotuner over the parametrizable VTA template (§4).

The paper's Section-4 flow: the accelerator is a *template*, so finding a
good deployment means searching jointly over hardware geometry and
schedule knobs — not hand-picking either.  This module is that search,
built on the calibrated cycle oracle the repo already trusts:

  (a) **hwspec geometry** — scratchpad splits (``inp/wgt/acc_buff_bytes``
      re-partitioned inside the base spec's fixed SRAM budget) and GEMM
      tile shape (``batch``/``block_in``/``block_out``), gated by
      :func:`hwspec.spec_feasible` (power-of-two depths, derived ISA
      field widths, the 32-bit uop-address budget);
  (b) **lowering choice** — conv nodes force ``direct``/``im2col`` or
      leave the per-node replayed-cycle auto pick
      (:func:`conv.select_conv_lowering`);
  (c) **per-op knobs** — ``virtual_threads``;
  (d) **serving knobs** — ``SchedConfig.gang_width`` (via the shared
      :func:`sched.stream_costs` evaluation) and ``window_us``.

Two-stage evaluation keeps it cheap: every candidate is priced by
TimingModel replay (the oracle); only the top-N by predicted cycles are
measured for wall time, and every measured candidate is byte-validated —
``CrossBackendChecker`` across both engines per accelerator segment plus
exact equality against the numpy reference — before it can win.  An
unvalidated candidate NEVER becomes a winner or a tuning record.

Winners land in a persistent per-(spec-key, op-signature)
:class:`TuningCache` that ``Program.compile`` consults transparently
(``CompiledProgram.tune_hits``/``tune_misses``, also on ``RunStats`` and
``describe()``).  ``tools/autotune.py`` is the CLI;
``benchmarks.bench_program.run_autotune`` publishes the search
trajectory to ``benchmarks/BENCH_autotune.json``.
"""
from __future__ import annotations

import itertools
import json
import os
import time
from dataclasses import asdict, dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .backend import CrossBackendChecker
from .compiler import AccelStep, CpuStep
from .conv import ConvShape, conv2d_reference
from .hwspec import HardwareSpec, pynq, spec_feasible
from .program import CompiledProgram, Program, op_signature
from .sched import SchedConfig, auto_gang_width, stream_costs
from .scheduler import Epilogue, matmul_reference
from .simulator import TimingModel


class ValidationError(RuntimeError):
    """A candidate's execution diverged — engines disagreed byte-wise or
    the output mismatched the numpy reference.  The candidate is dropped
    from the search; it can never become a winner or a tuning record."""
    pass


# ----------------------------------------------------------------------
# tuning cache: per-(spec-key, op-signature) records
# ----------------------------------------------------------------------
def spec_key(spec: HardwareSpec) -> str:
    """Stable string identity of everything that shapes a spec's streams
    and timing.  Two specs differing in ANY of these fields are different
    cache keys — which is exactly how records invalidate on spec change."""
    return (f"g{spec.batch}x{spec.block_in}x{spec.block_out}"
            f".i{spec.inp_buff_bytes}.w{spec.wgt_buff_bytes}"
            f".a{spec.acc_buff_bytes}.o{spec.out_buff_bytes}"
            f".u{spec.uop_buff_bytes}.wb{spec.wgt_bits}"
            f".f{spec.freq_mhz:g}.rd{spec.dram_rd_bytes_per_cycle:g}"
            f".wr{spec.dram_wr_bytes_per_cycle:g}"
            f".lat{spec.dram_latency_cycles}")


@dataclass
class TuningRecord:
    """One tuned decision set for one (spec, op-signature) pair."""
    lowering: Optional[str] = None        # conv nodes: the winning mode
    virtual_threads: Optional[int] = None
    gang_width: Optional[int] = None      # serving knobs of the winning
    window_us: Optional[float] = None     # program (program-level ops)
    predicted_cycles: Optional[float] = None
    measured_s: Optional[float] = None
    validated: bool = False
    source: str = "search"                # search | manual


class TuningCache:
    """Persistent per-(spec-key, op-signature) store of tuned decisions.

    ``Program.compile`` consults the global instance through
    :meth:`lookup` (counted — hit/miss totals feed the per-compile
    ``tune_hits``/``tune_misses``); the autotuner fills it through
    :meth:`put` after validation.  JSON round-trips with :meth:`save` /
    :meth:`load`, so a tuned deployment survives process restarts
    (``REPRO_TUNE_CACHE=path`` auto-loads into the global cache)."""

    def __init__(self, path: Optional[str] = None):
        self.entries: Dict[Tuple[str, str], TuningRecord] = {}
        self.hits = 0
        self.misses = 0
        if path and os.path.exists(path):
            self.load(path)

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(self, spec: HardwareSpec,
               op_sig: str) -> Optional[TuningRecord]:
        rec = self.entries.get((spec_key(spec), op_sig))
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def put(self, spec: HardwareSpec, op_sig: str,
            record: TuningRecord) -> None:
        self.entries[(spec_key(spec), op_sig)] = record

    def clear(self) -> None:
        self.entries.clear()
        self.hits = self.misses = 0

    def to_json(self) -> dict:
        return {"version": 1,
                "entries": [{"spec": sk, "op": op, **asdict(rec)}
                            for (sk, op), rec in sorted(self.entries.items())]}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")

    def load(self, path: str) -> int:
        """Merge records from a saved cache file; returns how many."""
        with open(path) as f:
            data = json.load(f)
        n = 0
        for row in data.get("entries", []):
            row = dict(row)
            sk, op = row.pop("spec"), row.pop("op")
            self.entries[(sk, op)] = TuningRecord(**row)
            n += 1
        return n


_GLOBAL_CACHE = TuningCache(path=os.environ.get("REPRO_TUNE_CACHE"))


def global_cache() -> TuningCache:
    """The process-wide TuningCache every ``Program.compile`` consults."""
    return _GLOBAL_CACHE


# ----------------------------------------------------------------------
# workloads: spec -> (Program, feeds, references)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Workload:
    """A tunable workload: ``build(spec, virtual_threads, lowering)``
    returns a fresh ``(Program, feeds, refs)`` triple for one candidate
    configuration.  Layouts are spec-dependent, so the graph must be
    rebuilt per candidate — only the *data* (seeded) stays fixed."""
    name: str
    kind: str          # "conv" | "matmul"
    build: Callable[[HardwareSpec, int, Optional[str]],
                    Tuple[Program, Dict[str, np.ndarray],
                          Dict[str, np.ndarray]]]


def conv_workload(shape: ConvShape, seed: int = 0,
                  epilogue: Optional[Epilogue] = None,
                  name: Optional[str] = None) -> Workload:
    ep = epilogue if epilogue is not None else Epilogue(shift=5, relu=True)
    rng = np.random.default_rng(seed)
    x = rng.integers(-64, 64, size=(shape.n, shape.ic, shape.h, shape.w),
                     dtype=np.int8)
    k = rng.integers(-16, 16, size=(shape.oc, shape.ic, shape.kh, shape.kw),
                     dtype=np.int8)
    ref = conv2d_reference(x, k, shape, epilogue=ep)

    def build(spec, virtual_threads, lowering):
        p = Program(spec, virtual_threads=virtual_threads)
        p.conv2d(p.input("x", x.shape), p.input("k", k.shape), shape,
                 epilogue=ep, lowering=lowering, name="y")
        return p, {"x": x, "k": k}, {"y": ref}

    return Workload(name or f"conv{shape.kh}x{shape.kw}_"
                            f"{shape.h}x{shape.w}x{shape.ic}-{shape.oc}",
                    "conv", build)


def matmul_workload(m: int = 64, k: int = 256, n: int = 256, seed: int = 0,
                    epilogue: Optional[Epilogue] = None,
                    name: Optional[str] = None) -> Workload:
    ep = epilogue if epilogue is not None else Epilogue(shift=7, relu=True)
    rng = np.random.default_rng(seed)
    a = rng.integers(-64, 64, size=(m, k), dtype=np.int8)
    w = rng.integers(-16, 16, size=(n, k), dtype=np.int8)

    def build(spec, virtual_threads, lowering):
        p = Program(spec, virtual_threads=virtual_threads)
        p.matmul(p.input("a", a.shape), p.input("w", w.shape),
                 epilogue=ep, name="y")
        ref = matmul_reference(a, w, epilogue=ep, spec=spec)
        return p, {"a": a, "w": w}, {"y": ref}

    return Workload(name or f"matmul{m}x{k}x{n}", "matmul", build)


# ----------------------------------------------------------------------
# candidate space
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class Candidate:
    """One point of the design space: a template instance + schedule
    knobs.  ``lowering=None`` leaves conv nodes on the per-node
    replayed-cycle auto pick; "direct"/"im2col" force one mode."""
    spec: HardwareSpec
    virtual_threads: int = 2
    lowering: Optional[str] = None

    def label(self) -> str:
        s = self.spec
        lw = self.lowering or "auto"
        return (f"{s.batch}x{s.block_in}x{s.block_out}"
                f"/i{s.inp_buff_bytes >> 10}k.w{s.wgt_buff_bytes >> 10}k"
                f".a{s.acc_buff_bytes >> 10}k/vt{self.virtual_threads}"
                f"/{lw}")


def enumerate_candidates(base: HardwareSpec,
                         vts: Sequence[int] = (1, 2),
                         lowerings: Sequence[Optional[str]] = (None,),
                         tile_shapes: Optional[Sequence[Tuple[int, int, int]]]
                         = None,
                         sram_splits: bool = True) -> List[Candidate]:
    """The full (deterministic-order) candidate grid around `base`.

    Geometry: GEMM tile shapes from a power-of-two neighbourhood of the
    base intrinsic, crossed with scratchpad re-partitions (each buffer
    halved/kept/doubled) whose total stays inside the base SRAM budget.
    Every spec passes :func:`hwspec.spec_feasible` — infeasible geometry
    (uop-budget overflow, non-power-of-two depths) never reaches a
    compile.  Candidate 0 is always the unmodified base configuration,
    the search's baseline."""
    tiles: List[Tuple[int, int, int]] = \
        [(base.batch, base.block_in, base.block_out)]
    if tile_shapes is not None:
        for t in tile_shapes:
            if t not in tiles:
                tiles.append(t)
    else:
        for b, bi, bo in itertools.product((1, 2), (8, 16, 32),
                                           (8, 16, 32)):
            if (b, bi, bo) not in tiles:
                tiles.append((b, bi, bo))

    budget = base.inp_buff_bytes + base.wgt_buff_bytes + base.acc_buff_bytes
    splits = [(base.inp_buff_bytes, base.wgt_buff_bytes,
               base.acc_buff_bytes)]
    if sram_splits:
        for fi, fw, fa in itertools.product((1, 2, 4), repeat=3):
            cand = (base.inp_buff_bytes * fi // 2,
                    base.wgt_buff_bytes * fw // 2,
                    base.acc_buff_bytes * fa // 2)
            if sum(cand) <= budget and cand not in splits:
                splits.append(cand)

    cands: List[Candidate] = []
    for (b, bi, bo), (ib, wb, ab) in itertools.product(tiles, splits):
        sp = base.replace(batch=b, block_in=bi, block_out=bo,
                          inp_buff_bytes=ib, wgt_buff_bytes=wb,
                          acc_buff_bytes=ab)
        if spec_feasible(sp) is not None:
            continue
        for vt, lw in itertools.product(vts, lowerings):
            cands.append(Candidate(sp, vt, lw))
    # candidate 0: the exact base configuration (vt/lowering defaults)
    base_cand = Candidate(base, 2, None)
    if base_cand in cands:
        cands.remove(base_cand)
    return [base_cand] + cands


# ----------------------------------------------------------------------
# two-stage evaluation
# ----------------------------------------------------------------------
@dataclass
class Trial:
    """One evaluated candidate: oracle prediction for everyone, measured
    wall + validation verdict only for the top-N."""
    candidate: Candidate
    predicted_cycles: Optional[float] = None
    predicted_s: Optional[float] = None
    measured_s: Optional[float] = None
    validated: Optional[bool] = None      # None = never measured
    gang_width: Optional[int] = None
    window_us: Optional[float] = None
    error: Optional[str] = None

    def to_json(self) -> dict:
        return {"candidate": self.candidate.label(),
                "virtual_threads": self.candidate.virtual_threads,
                "lowering": self.candidate.lowering,
                "predicted_cycles": self.predicted_cycles,
                "predicted_s": self.predicted_s,
                "measured_s": self.measured_s,
                "validated": self.validated,
                "gang_width": self.gang_width,
                "window_us": self.window_us,
                "error": self.error}


def predict_program_cycles(compiled: CompiledProgram,
                           timing: Optional[TimingModel] = None) -> float:
    """Oracle stage: total replayed cycles over every accelerator
    segment, through the SAME memoized :func:`sched.stream_costs` the
    gang-width tuner uses — one decode + replay per compiled program."""
    return float(sum(f + l for f, l, _ in stream_costs(compiled, timing)))


def measure_wall_s(compiled: CompiledProgram,
                   feeds: Dict[str, np.ndarray],
                   backend: str = "simulator", repeats: int = 3) -> float:
    """Measure stage: best-of-`repeats` wall seconds of one call (after
    one warm-up call, so jit/layout setup is excluded)."""
    compiled(backend=backend, **feeds)
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        compiled(backend=backend, **feeds)
        best = min(best, time.perf_counter() - t0)
    return best


def validate_candidate(compiled: CompiledProgram,
                       feeds: Dict[str, np.ndarray],
                       refs: Dict[str, np.ndarray]) -> None:
    """Differential validation of one candidate, the fuzzer's flow: every
    accelerator segment runs on BOTH engines against cloned devices and
    the DRAM images must match byte-for-byte; host steps execute in
    between; final outputs must equal the numpy reference exactly.
    Raises :class:`ValidationError` on any divergence."""
    for name, arr in feeds.items():
        compiled._write(compiled.input_ids[name], arr)
    checker = CrossBackendChecker()
    for step in compiled.steps:
        if isinstance(step, CpuStep):
            node = compiled.nodes[step.node_id]
            args = [compiled._read(i) for i in node.inputs]
            compiled._write(step.node_id, node.fn(*args))
            continue
        assert isinstance(step, AccelStep)
        report = checker.run(compiled.spec, compiled.device, step.stream)
        if not report.matches:
            raise ValidationError(
                f"{report.mismatched_bytes} DRAM bytes differ between "
                f"engines on segment {step}")
        compiled.device.copy_from(report.device_for("simulator"))
    outs = {compiled.nodes[i].name: compiled._read(i)
            for i in compiled.output_ids}
    for name, ref in refs.items():
        if not np.array_equal(outs[name], ref):
            raise ValidationError(
                f"output {name!r} mismatches the numpy reference "
                f"({int(np.count_nonzero(outs[name] != ref))} elements)")


# ----------------------------------------------------------------------
# the search
# ----------------------------------------------------------------------
@dataclass
class SearchResult:
    workload: str
    seed: int
    trials: List[Trial]
    baseline: Trial
    winner: Optional[Trial]
    candidates_total: int = 0      # full grid size before seeded sampling
    records_written: int = 0

    @property
    def speedup_predicted(self) -> Optional[float]:
        if (self.winner is None or not self.winner.predicted_cycles
                or not self.baseline.predicted_cycles):
            return None
        return self.baseline.predicted_cycles / self.winner.predicted_cycles

    @property
    def speedup_measured(self) -> Optional[float]:
        if (self.winner is None or not self.winner.measured_s
                or not self.baseline.measured_s):
            return None
        return self.baseline.measured_s / self.winner.measured_s

    def sched_config(self, **kw) -> SchedConfig:
        """Serving knobs of the winner as a ready SchedConfig."""
        w = self.winner or self.baseline
        cfg = dict(gang_width=w.gang_width, window_us=w.window_us or 500.0)
        cfg.update(kw)
        return SchedConfig(**cfg)

    def to_json(self) -> dict:
        return {"workload": self.workload, "seed": self.seed,
                "candidates_total": self.candidates_total,
                "candidates_evaluated": len(self.trials),
                "baseline": self.baseline.to_json(),
                "winner": self.winner.to_json() if self.winner else None,
                "speedup_predicted": self.speedup_predicted,
                "speedup_measured": self.speedup_measured,
                "records_written": self.records_written,
                "trials": [t.to_json() for t in self.trials]}


def search(workload: Workload, *, base_spec: Optional[HardwareSpec] = None,
           seed: int = 0, n_candidates: int = 24, top_n: int = 4,
           repeats: int = 3, backend: str = "simulator",
           vts: Sequence[int] = (1, 2),
           lowerings: Sequence[Optional[str]] = (None,),
           tile_shapes: Optional[Sequence[Tuple[int, int, int]]] = None,
           sram_splits: bool = True, max_gang_width: int = 4,
           cache: Optional[TuningCache] = None,
           log: Optional[Callable[[str], None]] = None) -> SearchResult:
    """Seeded two-stage design-space search for one workload.

    Stage 1 prices every sampled candidate on the TimingModel replay
    (compile + :func:`predict_program_cycles`); stage 2 takes the
    baseline plus the top-`top_n` by predicted cycles, byte-validates
    each (both engines + numpy reference — a candidate failing
    validation is disqualified, never silently kept), and measures wall
    time.  The measured-fastest validated candidate wins; its schedule
    decisions (lowering, virtual_threads) and serving knobs (gang_width
    from the shared cost evaluation, window_us from predicted service
    time) are written into `cache` (default: the global TuningCache that
    ``Program.compile`` consults) for every accelerator op of the
    winning program.  Deterministic for a fixed seed."""
    base_spec = base_spec or pynq()
    say = log or (lambda s: None)
    rng = np.random.default_rng(seed)
    grid = enumerate_candidates(base_spec, vts=vts, lowerings=lowerings,
                                tile_shapes=tile_shapes,
                                sram_splits=sram_splits)
    total = len(grid)
    if total > n_candidates:
        rest = grid[1:]
        pick = rng.choice(len(rest), size=max(0, n_candidates - 1),
                          replace=False)
        grid = [grid[0]] + [rest[i] for i in sorted(pick)]
    say(f"{workload.name}: {len(grid)} candidates "
        f"(of {total} feasible grid points), oracle stage...")

    trials: List[Trial] = []
    arts: Dict[int, Tuple[Program, CompiledProgram,
                          Dict[str, np.ndarray], Dict[str, np.ndarray]]] = {}
    for cand in grid:
        t = Trial(candidate=cand)
        trials.append(t)
        try:
            prog, feeds, refs = workload.build(cand.spec,
                                               cand.virtual_threads,
                                               cand.lowering)
            compiled = prog.compile(use_cache=False)
            t.predicted_cycles = predict_program_cycles(compiled)
            t.predicted_s = t.predicted_cycles / (cand.spec.freq_mhz * 1e6)
            arts[id(t)] = (prog, compiled, feeds, refs)
        except (ValueError, MemoryError) as e:
            t.error = f"{type(e).__name__}: {e}"
    baseline = trials[0]
    if baseline.error is not None:
        raise RuntimeError(f"baseline configuration failed to compile: "
                           f"{baseline.error}")

    ranked = sorted((t for t in trials[1:] if t.error is None),
                    key=lambda t: (t.predicted_cycles,
                                   t.candidate.label()))
    stage2 = [baseline] + ranked[:top_n]
    say(f"measuring + validating {len(stage2)} of {len(trials)} "
        f"(baseline + top-{top_n} predicted)...")
    for t in stage2:
        prog, compiled, feeds, refs = arts[id(t)]
        try:
            validate_candidate(compiled, feeds, refs)
            t.validated = True
        except ValidationError as e:
            t.validated = False
            t.error = f"ValidationError: {e}"
            say(f"  DROP {t.candidate.label()}: {t.error}")
            continue
        t.measured_s = measure_wall_s(compiled, feeds, backend=backend,
                                      repeats=repeats)
        t.gang_width = auto_gang_width(compiled, max_gang_width)
        # admission window: half a gang's predicted service time, inside
        # sane serving bounds
        t.window_us = float(min(5000.0, max(
            50.0, t.predicted_s * 1e6 * t.gang_width / 2)))
        say(f"  {t.candidate.label()}: predicted {t.predicted_cycles:.0f} "
            f"cyc, measured {t.measured_s * 1e3:.2f} ms, "
            f"gang {t.gang_width}")

    measured = [t for t in stage2 if t.validated and t.measured_s]
    winner = min(measured, key=lambda t: t.measured_s) if measured else None

    result = SearchResult(workload=workload.name, seed=seed, trials=trials,
                          baseline=baseline, winner=winner,
                          candidates_total=total)
    if winner is not None:
        cache = cache if cache is not None else global_cache()
        prog, compiled, _, _ = arts[id(winner)]
        for n in prog.nodes:
            if n.op not in ("conv2d", "matmul"):
                continue
            cache.put(winner.candidate.spec, op_signature(prog, n),
                      TuningRecord(
                          lowering=compiled.nodes[n.idx].lowering,
                          virtual_threads=winner.candidate.virtual_threads,
                          gang_width=winner.gang_width,
                          window_us=winner.window_us,
                          predicted_cycles=winner.predicted_cycles,
                          measured_s=winner.measured_s,
                          validated=True))
            result.records_written += 1
        say(f"winner {winner.candidate.label()}: "
            f"{result.speedup_measured:.2f}x measured over baseline, "
            f"{result.records_written} tuning record(s) written")
    return result
