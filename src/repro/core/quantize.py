"""Post-training quantization (§5: "post-training adjustments on the
parameters to convert them to 8-bit weights from 32-bit floating point").

Symmetric int8 quantization with power-of-two requantization shifts so the
entire inference pipeline maps onto VTA's integer datapath: int8 x int8
GEMM -> int32 accumulate -> (+bias) -> arithmetic-shift-right -> clip.
The same scheme drives the LM serving path's `vta_int8` GEMM backend.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class QuantParams:
    scale: float          # real_value ~= scale * q
    bits: int = 8

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1))


def calibrate(x: np.ndarray, bits: int = 8,
              percentile: float = 100.0) -> QuantParams:
    """Symmetric scale from the max-abs (or percentile) statistic."""
    a = np.abs(np.asarray(x, np.float64)).ravel()
    # both branches must tolerate size-0 input (an empty calibration batch
    # yields the 1e-8 floor): np.percentile raises on empty arrays, so it
    # gets the same guard the max branch has via max(initial=0.0)
    amax = (float(np.percentile(a, percentile))
            if percentile < 100.0 and a.size
            else float(a.max(initial=0.0)))
    amax = max(amax, 1e-8)
    return QuantParams(scale=amax / ((1 << (bits - 1)) - 1), bits=bits)


def quantize(x: np.ndarray, qp: QuantParams) -> np.ndarray:
    q = np.round(np.asarray(x, np.float64) / qp.scale)
    return np.clip(q, qp.qmin, qp.qmax).astype(np.int8)


def dequantize(q: np.ndarray, qp: QuantParams) -> np.ndarray:
    return q.astype(np.float32) * qp.scale


def per_channel_scales(w: np.ndarray, axis: int = 0, bits: int = 8) -> np.ndarray:
    """One symmetric scale per output channel (weights)."""
    a = np.abs(np.asarray(w, np.float64))
    red = tuple(i for i in range(w.ndim) if i != axis)
    amax = np.maximum(a.max(axis=red), 1e-8)
    return (amax / ((1 << (bits - 1)) - 1)).astype(np.float32)


def quantize_per_channel(w: np.ndarray, scales: np.ndarray,
                         axis: int = 0, bits: int = 8) -> np.ndarray:
    """Quantize with per-channel scales; `bits` must match the value the
    scales were computed for (``per_channel_scales(bits=...)``) — clipping
    to the b-bit range, not a hard-coded int8 one, so sub-byte scales
    don't silently saturate at the int8 boundary."""
    shape = [1] * w.ndim
    shape[axis] = -1
    q = np.round(np.asarray(w, np.float64) / scales.reshape(shape))
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return np.clip(q, qmin, qmax).astype(np.int8)


def choose_requant_shift(sx: float, sw: float, sy: float,
                         max_shift: int = 24) -> int:
    """Pick s with 2^-s ~= (sx*sw)/sy, so  y_q ~= (acc >> s)."""
    ratio = (sx * sw) / max(sy, 1e-30)
    s = int(round(-math.log2(max(ratio, 1e-30))))
    return int(np.clip(s, 0, max_shift))


def fold_batchnorm(gamma: np.ndarray, beta: np.ndarray, mean: np.ndarray,
                   var: np.ndarray, eps: float = 1e-5
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Fold BN into per-channel (w_scale, bias) applied post-conv."""
    inv = gamma / np.sqrt(var + eps)
    return inv, beta - mean * inv


def quantize_bias(bias_f: np.ndarray, sx: float, sw: float) -> np.ndarray:
    """Bias is added in the int32 accumulator domain: b_q = b / (sx*sw).

    The clip happens in the FLOAT domain: a pathological sx*sw (tiny
    product scale) can push b/(sx*sw) past int64 range, where a cast
    before the clip is undefined-overflow (wraps to INT64_MIN on most
    platforms) instead of saturating."""
    q = np.round(np.asarray(bias_f, np.float64) / max(sx * sw, 1e-30))
    return np.clip(q, -(1 << 31), (1 << 31) - 1).astype(np.int32)
