"""VTA core: the paper's contribution (template, ISA, runtime, simulator,
scheduler) as a composable package."""
from . import conv, driver, hwspec, isa, layout, microop, pipeline_model  # noqa: F401
from . import quantize, runtime, scheduler, simulator, workloads  # noqa: F401
from .hwspec import HardwareSpec, pynq, pynq_batch2, tpu_like  # noqa: F401
from .runtime import Runtime  # noqa: F401
