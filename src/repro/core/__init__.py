"""VTA core: the paper's contribution (template, ISA, runtime, simulator,
scheduler) as a composable package."""
from . import backend, conv, driver, hwspec, isa, layout, microop  # noqa: F401
from . import pipeline_model, quantize, runtime, scheduler  # noqa: F401
from . import simulator, workloads  # noqa: F401
from .backend import (CrossBackendChecker, ExecutionBackend,  # noqa: F401
                      PallasBackend, SimulatorBackend, resolve_backend)
from .hwspec import HardwareSpec, pynq, pynq_batch2, tpu_like  # noqa: F401
from .runtime import Runtime  # noqa: F401
