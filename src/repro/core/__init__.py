"""VTA core: the paper's contribution (template, ISA, runtime, simulator,
scheduler, program-level JIT) as a composable package."""
from . import autotune, backend, chaos, compiler, conv, driver  # noqa: F401
from . import hwspec, isa, layout, microop, pipeline_model, program  # noqa: F401
from . import quantize, runtime, sched, scheduler, serve  # noqa: F401
from . import simulator, workloads  # noqa: F401
from .autotune import TuningCache, TuningRecord  # noqa: F401
from .chaos import Fault, FaultPlan  # noqa: F401
from .backend import (CrossBackendChecker, ExecutionBackend,  # noqa: F401
                      PallasBackend, SimulatorBackend, assert_fast_path,
                      decode_cache_info, resolve_backend,
                      set_decode_cache_cap)
from .conv import ConvShape, select_conv_lowering  # noqa: F401
from .hwspec import HardwareSpec, pynq, pynq_batch2, tpu_like  # noqa: F401
from .program import (CompiledProgram, Program, TensorRef,  # noqa: F401
                      compile_multi)
from .runtime import Runtime  # noqa: F401
from .sched import (DeadlineExpired, QueueFull, SchedConfig,  # noqa: F401
                    SchedFuture, Scheduler, Shed, auto_gang_width)
from .scheduler import Epilogue, SramPartition  # noqa: F401
from .serve import (BatchServer, DevicePool, IntegrityError,  # noqa: F401
                    PoolFuture, SessionStats, SlotDied, WaitTimeout,
                    WatchdogConfig, WatchdogTimeout, serve_batch)
