"""Simulated device driver: DRAM address space + control handshake.

Models the pieces of the FPGA platform the runtime needs (§3.2): a
physically-contiguous DRAM allocator (VTABufferAlloc), typed load/store
views for DMA, and the fetch-module control registers (§2.4: `control`,
`insn_count`, `insns`).  On real hardware these are AXI/MMIO; here they
drive the behavioural simulator.  Cache flush/invalidate (non-coherent
SoCs) are modelled as no-op hooks with counters so the runtime code path
stays faithful and testable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


class Dram:
    """Flat byte-addressed DRAM with a bump allocator (physically contiguous
    buffers, as required by VTA's DMA engines)."""

    def __init__(self, size: int = 1 << 28, align: int = 64):
        self.size = size
        self.align = align
        self.mem = np.zeros(size, dtype=np.uint8)
        self._next = align  # keep address 0 as a null sentinel
        self._allocs: Dict[int, int] = {}

    def alloc(self, nbytes: int, align: int | None = None) -> int:
        a = max(self.align, align or 1)
        addr = (self._next + a - 1) // a * a
        if addr + nbytes > self.size:
            raise MemoryError(f"DRAM exhausted: {addr + nbytes} > {self.size}")
        self._next = addr + nbytes
        self._allocs[addr] = nbytes
        return addr

    def free(self, addr: int) -> None:
        self._allocs.pop(addr, None)  # bump allocator: bookkeeping only

    def clone(self, trim: bool = False) -> "Dram":
        """Independent copy of the DRAM state.  With ``trim`` the copy
        keeps only the ALLOCATED image (every byte below the bump
        pointer, rounded up to alignment): reads and writes of existing
        buffers behave identically, but any further ``alloc`` raises
        MemoryError — exactly the contract of a pooled serving device,
        whose pre-staged CompiledProgram must never allocate per call.
        A pool of trimmed clones costs O(used bytes) each instead of the
        full address-space image."""
        c = Dram.__new__(Dram)
        c.align = self.align
        if trim:
            used = (self._next + self.align - 1) // self.align * self.align
            c.size = used
            c.mem = self.mem[:used].copy()
        else:
            c.size = self.size
            c.mem = self.mem.copy()
        c._next = self._next
        c._allocs = dict(self._allocs)
        return c

    def copy_from(self, other: "Dram") -> None:
        """Adopt another DRAM's full state (same-size images only)."""
        if other.size != self.size:
            raise ValueError(f"DRAM size mismatch: {other.size} != {self.size}")
        self.mem[:] = other.mem
        self._next = other._next
        self._allocs = dict(other._allocs)

    # -- typed access ---------------------------------------------------
    def write(self, addr: int, arr: np.ndarray) -> None:
        b = np.ascontiguousarray(arr).view(np.uint8).ravel()
        self.mem[addr:addr + b.size] = b

    def read(self, addr: int, nbytes: int, dtype=np.uint8, shape=None) -> np.ndarray:
        raw = self.mem[addr:addr + nbytes]
        out = raw.view(dtype).copy()
        return out.reshape(shape) if shape is not None else out


@dataclass
class ControlRegisters:
    """fetch-module MMIO registers (§2.4)."""
    control: int = 0       # bit0 = start, bit1 = done
    insn_count: int = 0
    insns: int = 0         # DRAM physical address of the instruction stream

    def start(self) -> None:
        self.control |= 1
        self.control &= ~2

    def set_done(self) -> None:
        self.control &= ~1
        self.control |= 2

    @property
    def done(self) -> bool:
        return bool(self.control & 2)


class Device:
    """One simulated VTA device: DRAM + control registers + cache model."""

    def __init__(self, dram_size: int = 1 << 28):
        self.dram = Dram(dram_size)
        self.regs = ControlRegisters()
        self.cache_flushes = 0
        self.cache_invalidates = 0

    def clone(self, trim: bool = False) -> "Device":
        """Independent copy of the full device state — the cross-backend
        checker runs each engine against its own clone and diffs the
        resulting DRAM images.  ``trim`` clones only the allocated DRAM
        image and forbids further allocation (see :meth:`Dram.clone`) —
        the device-pool slot configuration."""
        c = Device.__new__(Device)
        c.dram = self.dram.clone(trim=trim)
        c.regs = ControlRegisters(self.regs.control, self.regs.insn_count,
                                  self.regs.insns)
        c.cache_flushes = self.cache_flushes
        c.cache_invalidates = self.cache_invalidates
        return c

    def copy_from(self, other: "Device") -> None:
        """Adopt another device's state (used to fold a checker clone's
        results back into the runtime's live device)."""
        self.dram.copy_from(other.dram)
        self.regs.control = other.regs.control
        self.regs.insn_count = other.regs.insn_count
        self.regs.insns = other.regs.insns
        self.cache_flushes = other.cache_flushes
        self.cache_invalidates = other.cache_invalidates

    def stage_stream(self, stream: np.ndarray) -> int:
        """DMA an encoded instruction stream to DRAM and kick the fetch
        registers (§2.4) — the shared handshake every execution engine
        performs before running to FINISH.  Returns the stream address."""
        addr = self.dram.alloc(stream.nbytes)
        self.dram.write(addr, stream)
        self.kick_stream(addr, stream.shape[0])
        return addr

    def kick_stream(self, addr: int, insn_count: int) -> None:
        """Point the fetch registers at an ALREADY-staged instruction
        stream and start the engine — the repeat-call handshake of a
        pre-staged CompiledProgram (zero per-call DRAM allocation)."""
        self.regs.insns = addr
        self.regs.insn_count = insn_count
        self.regs.start()

    # non-coherent-SoC cache maintenance hooks (§3.2)
    def flush_cache(self, addr: int, nbytes: int) -> None:
        self.cache_flushes += 1

    def invalidate_cache(self, addr: int, nbytes: int) -> None:
        self.cache_invalidates += 1
