"""Paper workloads: the ResNet-18 conv2d table (Table 1) and the e2e graph.

All twelve conv operators, with "SAME" padding as stated.  C1 is evaluated
on the CPU in the paper (3 input channels — shallow depth); we keep it in
the table and mark it `cpu_only` for the Fig. 16 offload study.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from .conv import ConvShape


@dataclass(frozen=True)
class ResnetLayer:
    name: str
    shape: ConvShape
    cpu_only: bool = False
    repeat: int = 1      # how many times the op appears in ResNet-18


def _c(h, ic, oc, k, s) -> ConvShape:
    # "SAME" padding: pad = k // 2
    return ConvShape(n=1, h=h, w=h, ic=ic, oc=oc, kh=k, kw=k, stride=s,
                     pad=k // 2)


def resnet18_table1() -> List[ResnetLayer]:
    return [
        ResnetLayer("C1", _c(224, 3, 64, 7, 2), cpu_only=True, repeat=1),
        ResnetLayer("C2", _c(56, 64, 64, 3, 1), repeat=4),
        ResnetLayer("C3", _c(56, 64, 64, 1, 1), repeat=1),
        ResnetLayer("C4", _c(56, 64, 128, 3, 2), repeat=1),
        ResnetLayer("C5", _c(56, 64, 128, 1, 2), repeat=1),
        ResnetLayer("C6", _c(28, 128, 128, 3, 1), repeat=3),
        ResnetLayer("C7", _c(28, 128, 256, 3, 2), repeat=1),
        ResnetLayer("C8", _c(28, 128, 256, 1, 2), repeat=1),
        ResnetLayer("C9", _c(14, 256, 256, 3, 1), repeat=3),
        ResnetLayer("C10", _c(14, 256, 512, 3, 2), repeat=1),
        ResnetLayer("C11", _c(14, 256, 512, 1, 2), repeat=1),
        ResnetLayer("C12", _c(7, 512, 512, 3, 1), repeat=3),
    ]


def layer_by_name(name: str) -> ResnetLayer:
    for l in resnet18_table1():
        if l.name == name:
            return l
    raise KeyError(name)


# rough ARM Cortex-A9 (dual, 667 MHz, NEON) effective conv throughput used
# for the Fig. 16 CPU-side model; the paper measures >3 s full-CPU ResNet-18
# inference (~3.6 GOP of conv work => ~1.2 GOPS effective).
CPU_EFFECTIVE_GOPS = 1.2
# non-conv CPU residue (pooling, fc, residual adds, data layout): Fig. 16
# shows ~0.4 s of the offloaded pipeline remaining on the CPU.
CPU_RESIDUE_SECONDS = 0.40
