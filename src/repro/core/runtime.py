"""VTA JIT runtime (§3): instruction-stream + micro-kernel generation.

Python port of the paper's C++ runtime API.  Responsibilities (§3.2):
  * dynamic memory allocation / buffer management (physically contiguous);
  * 2D DMA instruction generation (`load_buffer_2d` / `store_buffer_2d`,
    i.e. VTALoadBuffer2D / VTAStoreBuffer2D);
  * micro-op kernel generation + DRAM caching + LRU residency management of
    the on-chip uop cache (VTAUopLoopBegin/Push/LoopEnd);
  * explicit dependence management (VTADepPush / VTADepPop, Fig. 12);
  * CPU↔accelerator synchronization (VTASynchronize → runs the simulator).

The runtime *adapts to the HardwareSpec*: all encodings, element sizes and
SRAM budgets are derived from the spec instance, mirroring the paper's
co-design fluidity.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .driver import Device
from .hwspec import HardwareSpec
from .isa import (AluInsn, AluOp, DepFlags, DEP_IN_EDGES, DEP_OUT_EDGES,
                  FinishInsn, GemmInsn, Insn, IsaLayout, LoadStoreInsn,
                  MemId, Opcode, route_queue, LOAD_Q, COMPUTE_Q, STORE_Q)
from .microop import UOp, UopLayout
from .simulator import RunStats, TimingModel, _MODULE_NAMES


# ----------------------------------------------------------------------
# micro-kernel construction (VTAUopLoopBegin / VTAUopPush / VTAUopLoopEnd)
# ----------------------------------------------------------------------
@dataclass
class LoopLevel:
    extent: int
    dst_factor: int
    src_factor: int
    wgt_factor: int


@dataclass
class UopKernel:
    """A micro-coded kernel: a uop sequence + up to two affine loop levels."""
    uops: List[UOp]
    loops: List[LoopLevel]
    key: str = ""
    dram_addr: int = -1          # where the encoded uops live in DRAM
    sram_base: int = -1          # uop-cache residency (managed by runtime)

    @property
    def iter_out(self) -> int:
        return self.loops[0].extent if len(self.loops) >= 1 else 1

    @property
    def iter_in(self) -> int:
        return self.loops[1].extent if len(self.loops) >= 2 else 1

    def factors(self) -> Tuple[int, int, int, int, int, int]:
        l0 = self.loops[0] if len(self.loops) >= 1 else LoopLevel(1, 0, 0, 0)
        l1 = self.loops[1] if len(self.loops) >= 2 else LoopLevel(1, 0, 0, 0)
        return (l0.dst_factor, l1.dst_factor, l0.src_factor,
                l1.src_factor, l0.wgt_factor, l1.wgt_factor)


class UopBuilder:
    def __init__(self):
        self._loops: List[LoopLevel] = []
        self._uops: List[UOp] = []

    def loop_begin(self, extent: int, dst_factor: int, src_factor: int,
                   wgt_factor: int = 0) -> None:
        if len(self._loops) >= 2:
            raise ValueError("VTA supports at most 2 uop loop levels")
        self._loops.append(LoopLevel(extent, dst_factor, src_factor, wgt_factor))

    def loop_end(self) -> None:
        if not self._loops:
            raise ValueError("loop_end without loop_begin")
        # loops stay recorded; end just closes nesting for API symmetry

    def push(self, dst: int, src: int, wgt: int = 0) -> None:
        self._uops.append(UOp(dst, src, wgt))

    def build(self) -> UopKernel:
        if not self._uops:
            raise ValueError("empty micro-kernel")
        return UopKernel(uops=self._uops, loops=list(self._loops))


# ----------------------------------------------------------------------
# the runtime
# ----------------------------------------------------------------------
class Runtime:
    def __init__(self, spec: HardwareSpec, device: Optional[Device] = None):
        self.spec = spec
        self.device = device or Device()
        self.isa = IsaLayout(spec)
        self.uop_layout = UopLayout(spec)

        self._stream: List[Insn] = []
        # DepPop is recorded *before* the target instruction is pushed
        self._pending_pop: Dict[int, Dict[str, bool]] = {
            LOAD_Q: {}, COMPUTE_Q: {}, STORE_Q: {}}
        # index of last instruction per queue (for DepPush)
        self._last_in_queue: Dict[int, Optional[int]] = {
            LOAD_Q: None, COMPUTE_Q: None, STORE_Q: None}

        # uop cache management
        self._kernel_cache: Dict[str, UopKernel] = {}
        self._resident: Dict[str, UopKernel] = {}   # key -> kernel, LRU order
        self._uop_cursor = 0                        # bump allocator in uop SRAM

        # profiling
        self.stats_history: List[RunStats] = []

    # ------------------------------------------------------------------
    # buffer management (VTABufferAlloc / VTABufferCopy)
    # ------------------------------------------------------------------
    def buffer_alloc(self, nbytes: int, align: int = 64) -> int:
        return self.device.dram.alloc(nbytes, align=align)

    def copy_to_device(self, arr: np.ndarray, align: int = 256) -> int:
        addr = self.device.dram.alloc(arr.nbytes, align=align)
        self.device.dram.write(addr, arr)
        self.device.flush_cache(addr, arr.nbytes)
        return addr

    def copy_from_device(self, addr: int, nbytes: int, dtype, shape,
                         device: Optional[Device] = None) -> np.ndarray:
        """`device` overrides the runtime's own device so results can be
        read from a cross-backend checker clone."""
        dev = device if device is not None else self.device
        dev.invalidate_cache(addr, nbytes)
        return dev.dram.read(addr, nbytes, dtype=dtype, shape=shape)

    def elem_bytes(self, mem: MemId) -> int:
        s = self.spec
        return {MemId.UOP: s.uop_elem_bytes, MemId.WGT: s.wgt_elem_bytes,
                MemId.INP: s.inp_elem_bytes, MemId.ACC: s.acc_elem_bytes,
                MemId.OUT: s.out_elem_bytes}[mem]

    def to_elem_addr(self, byte_addr: int, mem: MemId) -> int:
        eb = self.elem_bytes(mem)
        if byte_addr % eb:
            raise ValueError(f"address {byte_addr} not aligned to {mem.name} "
                             f"element size {eb}")
        return byte_addr // eb

    # ------------------------------------------------------------------
    # dependence management (VTADepPush / VTADepPop)
    # ------------------------------------------------------------------
    @staticmethod
    def _edge(from_q: int, to_q: int) -> Tuple[str, str]:
        """Returns (push_flag_on_from, pop_flag_on_to)."""
        if (from_q, to_q) == (LOAD_Q, COMPUTE_Q):
            return "push_next", "pop_prev"
        if (from_q, to_q) == (COMPUTE_Q, LOAD_Q):
            return "push_prev", "pop_next"
        if (from_q, to_q) == (COMPUTE_Q, STORE_Q):
            return "push_next", "pop_prev"
        if (from_q, to_q) == (STORE_Q, COMPUTE_Q):
            return "push_prev", "pop_next"
        raise ValueError(f"no dependence edge between queues {from_q}->{to_q}")

    def dep_push(self, from_q: int, to_q: int) -> None:
        """Token will be *produced* by the most recent instruction of from_q."""
        push_flag, _ = self._edge(from_q, to_q)
        idx = self._last_in_queue[from_q]
        if idx is None:
            raise ValueError("dep_push before any instruction in source queue")
        setattr(self._stream[idx].dep, push_flag, True)

    def dep_pop(self, from_q: int, to_q: int) -> None:
        """Token will be *consumed* by the next instruction pushed to to_q."""
        _, pop_flag = self._edge(from_q, to_q)
        self._pending_pop[to_q][pop_flag] = True

    def _push_insn(self, insn: Insn) -> int:
        q = route_queue(insn)
        for flag, v in self._pending_pop[q].items():
            if v:
                setattr(insn.dep, flag, True)
        self._pending_pop[q] = {}
        self._stream.append(insn)
        idx = len(self._stream) - 1
        self._last_in_queue[q] = idx
        return idx

    def noop(self, queue: int) -> int:
        """Zero-extent instruction: no memory effect, but it occupies a slot
        in its module's FIFO and can carry dependence flags — the program
        compiler's barrier primitive for cross-op WAR/RAW joins in a
        composed stream."""
        if queue == LOAD_Q:
            return self._push_insn(LoadStoreInsn(
                opcode=Opcode.LOAD, dep=DepFlags(), memory_type=MemId.INP,
                sram_base=0, dram_base=0, y_size=0, x_size=0, x_stride=0))
        if queue == STORE_Q:
            return self._push_insn(LoadStoreInsn(
                opcode=Opcode.STORE, dep=DepFlags(), memory_type=MemId.OUT,
                sram_base=0, dram_base=0, y_size=0, x_size=0, x_stride=0))
        if queue == COMPUTE_Q:
            return self._push_insn(GemmInsn(
                dep=DepFlags(), reset=False, uop_bgn=0, uop_end=0,
                iter_out=0, iter_in=0, dst_factor_out=0, dst_factor_in=0,
                src_factor_out=0, src_factor_in=0, wgt_factor_out=0,
                wgt_factor_in=0))
        raise ValueError(f"unknown queue {queue}")

    def token_balance(self, start: int = 0) -> Dict[str, int]:
        """Net token count per dependence FIFO over the stream suffix —
        the tokens that would remain unconsumed if the suffix ran alone."""
        bal = {"l2c": 0, "c2l": 0, "c2s": 0, "s2c": 0}
        for insn in self._stream[start:]:
            q = route_queue(insn)
            for fifo, flag in DEP_IN_EDGES[q]:
                if getattr(insn.dep, flag):
                    bal[fifo] -= 1
            for fifo, flag in DEP_OUT_EDGES[q]:
                if getattr(insn.dep, flag):
                    bal[fifo] += 1
        return bal

    # dependence FIFO -> (producer queue, consumer queue); the consumer
    # queue is where a drain noop must live to pop the token
    _FIFO_EDGE = {"c2l": (COMPUTE_Q, LOAD_Q), "c2s": (COMPUTE_Q, STORE_Q),
                  "l2c": (LOAD_Q, COMPUTE_Q), "s2c": (STORE_Q, COMPUTE_Q)}

    def _drain_fifo(self, fifo: str, count: int) -> None:
        """Consume `count` tokens from one dependence FIFO on noops of
        its consumer queue — the stale-token-pairing primitive shared by
        drain_dep_tokens, join_barrier, and buffer_fence."""
        from_q, to_q = self._FIFO_EDGE[fifo]
        for _ in range(count):
            self.dep_pop(from_q, to_q)
            self.noop(to_q)

    def drain_dep_tokens(self) -> None:
        """Consume every unmatched dependence token in the four FIFOs.

        Required between schedules composed into one stream: tokens are
        information-less, so a schedule's k-th pop pairs with the k-th
        push in FIFO order.  Stale tokens from a predecessor shift that
        pairing one generation early and silently break the successor's
        own WAR protocol — drain first, then compose."""
        if any(self._pending_pop[q] for q in self._pending_pop):
            raise RuntimeError(
                "drain_dep_tokens called with an un-attached dep_pop pending")
        bal = self.token_balance()
        for fifo in ("c2l", "c2s", "l2c", "s2c"):
            self._drain_fifo(fifo, bal[fifo])

    def clear_pending_pop(self, queue: int) -> None:
        """Cancel dep_pops registered for `queue` but not yet attached to
        an instruction (the compiler's fence fallback path)."""
        self._pending_pop[queue] = {}

    def buffer_fence(self, consumer_loads: bool = True) -> None:
        """Buffer-granular producer->consumer fence: the cheap alternative
        to ``join_barrier`` for dependent ops in one composed stream.

        Serializes one edge only — instructions that pop the fence token
        wait until every STORE emitted so far has completed (the
        producer's DRAM image is final); nothing else rendezvouses.
        Construction::

            store-noop ──s2c──> compute-noop [──c2l──> first fenced LOAD]

        The store noop sits behind every producer store in the store
        FIFO, so its s2c push publishes "all stores done"; the compute
        noop(s) pop it — stale s2c tokens are consumed first so the FIFO
        pairing stays aligned (tokens are information-less, see
        ``drain_dep_tokens``).  With ``consumer_loads`` the last compute
        noop also pushes c2l and the *caller* chooses which load pops it
        (``dep_pop(COMPUTE_Q, LOAD_Q)`` immediately before emitting the
        consumer's first load of the produced buffer).  Loads emitted
        before that pop — e.g. the consumer's first weight tile — run
        while the producer's epilogue and store tail are still draining,
        which is what lets dependent layers double-buffer across the op
        boundary.  Unlike ``join_barrier``, the consumer's stores are
        never gated and no load/compute rendezvous is inserted.
        """
        if not self._stream:
            return
        if any(self._pending_pop[q] for q in self._pending_pop):
            raise RuntimeError(
                "buffer_fence called with an un-attached dep_pop pending")
        bal = self.token_balance()
        # stale WAR tokens would shift the consumer's own push/pop pairing
        # one generation early; consume them on noops that retire as soon
        # as their producing instruction completes
        for fifo in ("c2l", "l2c", "c2s"):
            self._drain_fifo(fifo, bal[fifo])
        # the fence proper: one store noop behind every producer store...
        self.noop(STORE_Q)
        self.dep_push(STORE_Q, COMPUTE_Q)
        # ...whose token the LAST of these compute noops pops (the first
        # bal["s2c"] pops consume the producers' own trailing WAR pushes)
        self._drain_fifo("s2c", bal["s2c"] + 1)
        if consumer_loads:
            self.dep_push(COMPUTE_Q, LOAD_Q)

    def join_barrier(self) -> None:
        """Full cross-module rendezvous: every instruction emitted after
        the barrier starts only after every instruction before it has
        completed, on all three modules.

        Construction (compute is the hub — the only module with edges to
        and from both others): drain stale tokens so the FIFOs are empty,
        then  load-noop ─l2c→ ┐
              store-noop─s2c→ ┼→ compute-join ─c2l→ load-noop
                              └────────────────c2s→ store-noop
        FIFO order serializes each module's later instructions behind its
        resume noop, hence behind the join, hence behind everything."""
        if not self._stream:
            return
        self.drain_dep_tokens()
        self.noop(LOAD_Q)
        self.dep_push(LOAD_Q, COMPUTE_Q)
        self.noop(STORE_Q)
        self.dep_push(STORE_Q, COMPUTE_Q)
        self.dep_pop(LOAD_Q, COMPUTE_Q)
        self.dep_pop(STORE_Q, COMPUTE_Q)
        self.noop(COMPUTE_Q)
        self.dep_push(COMPUTE_Q, LOAD_Q)
        self.dep_push(COMPUTE_Q, STORE_Q)
        self.dep_pop(COMPUTE_Q, LOAD_Q)
        self.noop(LOAD_Q)
        self.dep_pop(COMPUTE_Q, STORE_Q)
        self.noop(STORE_Q)

    # ------------------------------------------------------------------
    # DMA instruction generation
    # ------------------------------------------------------------------
    def load_buffer_2d(self, mem: MemId, sram_base: int, dram_elem_base: int,
                       y_size: int, x_size: int, x_stride: int,
                       y_pad_0: int = 0, y_pad_1: int = 0,
                       x_pad_0: int = 0, x_pad_1: int = 0) -> int:
        return self._push_insn(LoadStoreInsn(
            opcode=Opcode.LOAD, dep=DepFlags(), memory_type=mem,
            sram_base=sram_base, dram_base=dram_elem_base,
            y_size=y_size, x_size=x_size, x_stride=x_stride,
            y_pad_0=y_pad_0, y_pad_1=y_pad_1, x_pad_0=x_pad_0, x_pad_1=x_pad_1))

    def store_buffer_2d(self, sram_base: int, dram_elem_base: int,
                        y_size: int, x_size: int, x_stride: int) -> int:
        return self._push_insn(LoadStoreInsn(
            opcode=Opcode.STORE, dep=DepFlags(), memory_type=MemId.OUT,
            sram_base=sram_base, dram_base=dram_elem_base,
            y_size=y_size, x_size=x_size, x_stride=x_stride))

    # ------------------------------------------------------------------
    # micro-kernel generation + uop-cache residency (LRU, §3.2)
    # ------------------------------------------------------------------
    def uop_kernel(self, builder_fn: Callable[[UopBuilder], None],
                   key: Optional[str] = None) -> UopKernel:
        """JIT a micro-kernel; cached in DRAM for the program lifetime."""
        b = UopBuilder()
        builder_fn(b)
        kernel = b.build()
        if key is None:
            sig = repr([(l.extent, l.dst_factor, l.src_factor, l.wgt_factor)
                        for l in kernel.loops] + kernel.uops)
            key = hashlib.sha1(sig.encode()).hexdigest()[:16]
        if key in self._kernel_cache:
            return self._kernel_cache[key]
        kernel.key = key
        words = self.uop_layout.encode_kernel(kernel.uops)
        kernel.dram_addr = self.copy_to_device(
            words, align=self.spec.uop_elem_bytes)
        self._kernel_cache[key] = kernel
        return kernel

    def _ensure_resident(self, kernel: UopKernel) -> None:
        """Make the kernel resident in uop SRAM, LRU-evicting as needed.
        Safe because uop LOADs and compute ops share the compute queue
        (FIFO order ⇒ no hazard)."""
        n = len(kernel.uops)
        if kernel.key in self._resident:
            self._resident.pop(kernel.key)          # refresh LRU position
            self._resident[kernel.key] = kernel
            return
        if n > self.spec.uop_depth:
            raise ValueError(f"micro-kernel of {n} uops exceeds uop cache "
                             f"depth {self.spec.uop_depth}")
        if self._uop_cursor + n > self.spec.uop_depth:
            # wrap-around: invalidate everything (simple two-space LRU à la VTA)
            self._resident.clear()
            self._uop_cursor = 0
        kernel.sram_base = self._uop_cursor
        self._uop_cursor += n
        self._resident[kernel.key] = kernel
        self.load_buffer_2d(
            MemId.UOP, sram_base=kernel.sram_base,
            dram_elem_base=self.to_elem_addr(kernel.dram_addr, MemId.UOP),
            y_size=1, x_size=n, x_stride=n)

    # ------------------------------------------------------------------
    # compute instruction generation
    # ------------------------------------------------------------------
    def push_gemm(self, kernel: UopKernel, reset: bool = False) -> int:
        self._ensure_resident(kernel)
        dfo, dfi, sfo, sfi, wfo, wfi = kernel.factors()
        return self._push_insn(GemmInsn(
            dep=DepFlags(), reset=reset,
            uop_bgn=kernel.sram_base, uop_end=kernel.sram_base + len(kernel.uops),
            iter_out=kernel.iter_out, iter_in=kernel.iter_in,
            dst_factor_out=dfo, dst_factor_in=dfi,
            src_factor_out=sfo, src_factor_in=sfi,
            wgt_factor_out=wfo, wgt_factor_in=wfi))

    def push_alu(self, kernel: UopKernel, op: AluOp, imm: int = 0,
                 use_imm: bool = True, reset: bool = False) -> int:
        self._ensure_resident(kernel)
        dfo, dfi, sfo, sfi, _, _ = kernel.factors()
        return self._push_insn(AluInsn(
            dep=DepFlags(), reset=reset,
            uop_bgn=kernel.sram_base, uop_end=kernel.sram_base + len(kernel.uops),
            iter_out=kernel.iter_out, iter_in=kernel.iter_in,
            dst_factor_out=dfo, dst_factor_in=dfi,
            src_factor_out=sfo, src_factor_in=sfi,
            alu_opcode=op, use_imm=use_imm, imm=imm))

    # ------------------------------------------------------------------
    # stream validation + synchronize
    # ------------------------------------------------------------------
    def validate_stream(self, require_net_zero: bool = False,
                        start: int = 0) -> None:
        """Exact static deadlock check: replay the stream the way the three
        modules execute it — each consumes its command queue in FIFO order,
        predicated on the four dependence-token FIFOs.  Greedy replay is
        exact here because the modules consume from disjoint FIFO sets
        (firing an enabled instruction can never disable another), so a
        stuck replay == guaranteed deadlock.  Unlike the old net-balance
        check this also rejects streams where a pop precedes its matching
        push in module order.  With require_net_zero, additionally reject
        streams that leave unconsumed tokens behind — schedules that close
        over their own WAR/RAW protocol (e.g. the vector-binop path) must
        end with every FIFO drained.  `start` restricts the check to the
        stream suffix emitted from that index on, so a self-contained
        schedule can be validated even when composed after others."""
        queues: Dict[int, List[Insn]] = {LOAD_Q: [], COMPUTE_Q: [],
                                         STORE_Q: []}
        for insn in self._stream[start:]:
            queues[route_queue(insn)].append(insn)
        tokens = {"l2c": 0, "c2l": 0, "c2s": 0, "s2c": 0}
        pc = {LOAD_Q: 0, COMPUTE_Q: 0, STORE_Q: 0}
        remaining = sum(len(v) for v in queues.values())
        while remaining:
            progressed = False
            for q in (LOAD_Q, COMPUTE_Q, STORE_Q):
                while pc[q] < len(queues[q]):
                    insn = queues[q][pc[q]]
                    needs = [fifo for fifo, flag in DEP_IN_EDGES[q]
                             if getattr(insn.dep, flag)]
                    if any(tokens[f] == 0 for f in needs):
                        break
                    for f in needs:
                        tokens[f] -= 1
                    for fifo, flag in DEP_OUT_EDGES[q]:
                        if getattr(insn.dep, flag):
                            tokens[fifo] += 1
                    pc[q] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                state = {_MODULE_NAMES[q]: f"{pc[q]}/{len(queues[q])}"
                         for q in pc}
                raise ValueError(
                    f"dependence deadlock: no module can issue; pcs={state} "
                    f"tokens={tokens} — a pop precedes its matching push")
        if require_net_zero:
            for k, v in tokens.items():
                if v != 0:
                    raise ValueError(
                        f"dependence FIFO {k} balance {v} != 0: "
                        "stream leaves unconsumed tokens")

    def finalize_stream(self) -> np.ndarray:
        """Append FINISH, validate token balance, and encode the stream to
        its binary task-ISA form — the single artifact every execution
        backend consumes."""
        if any(self._pending_pop[q] for q in self._pending_pop):
            raise ValueError(
                "finalize_stream with un-attached dep_pop(s): a fence token "
                "pop was registered but never claimed by an instruction")
        self._push_insn(FinishInsn(dep=DepFlags()))
        self.validate_stream()
        return self.isa.encode_stream(self._stream)

    def synchronize(self, timing: Optional[TimingModel] = None,
                    keep_stream: bool = False,
                    backend: "object | str | None" = None) -> RunStats:
        """VTASynchronize: finalize the stream, hand off to an execution
        backend, block until FINISH.

        backend: None (default) runs the cycle-capable numpy simulator;
        "pallas" routes the *same* encoded stream through the TPU-native
        Pallas engine; any ExecutionBackend instance is used as-is.
        """
        from .backend import resolve_backend
        stream = self.finalize_stream()
        stats = resolve_backend(backend).execute(
            self.spec, self.device, stream, timing=timing)
        self.stats_history.append(stats)
        if not keep_stream:
            self.reset_stream()
        return stats

    def reset_stream(self) -> None:
        self._stream = []
        self._pending_pop = {LOAD_Q: {}, COMPUTE_Q: {}, STORE_Q: {}}
        self._last_in_queue = {LOAD_Q: None, COMPUTE_Q: None, STORE_Q: None}
        # kernels stay JIT-cached in DRAM for the program lifetime (§3.2),
        # but the simulator starts each run with cold SRAM, so uop-cache
        # residency must be rebuilt on the next stream.
        self._resident.clear()
        self._uop_cursor = 0

    @property
    def stream(self) -> List[Insn]:
        return list(self._stream)

    @property
    def stream_len(self) -> int:
        """O(1) pending-instruction count (the `stream` property copies)."""
        return len(self._stream)
