"""Continuous-batching control plane on top of :class:`DevicePool`.

The pool (``core.serve``) gangs requests that happen to sit at the same
program's same accelerator segment — but greedy ``submit()`` fires the
moment a slot frees, so open-loop arrivals stagger the slots' step
offsets and, because the pool advances round by round, the stagger
persists for the whole program: gangs almost never form under real
traffic.  This module adds the classic LM-serving admission layer that
makes them form on purpose:

  * **bounded admission window** — requests park in per-program queues;
    a batch is released when it reaches the gang width K *or* its oldest
    request has waited T µs (so a lone request still runs after one
    window: the gang-of-1 path).  A released batch lands on distinct
    idle slots together, stays lockstep for every segment, and therefore
    gangs end to end.

  * **gang-width auto-tuning** — :func:`auto_gang_width` prices a
    program's streams on the calibrated :class:`TimingModel` and picks
    the width where predicted per-call cycles stop improving (< 5 %
    marginal gain), respecting the vmap interpret-mode cliff measured in
    PR 5 (per-launch tile count beyond ~:data:`VMAP_INTERPRET_CLIFF`
    stops amortizing).  DMA setup latency is the amortizable term — a
    gang's batched launches pay it once per launch instead of once per
    request — while compute cycles replicate per member.

  * **multi-program pools** — co-staged programs
    (``program.compile_multi``) occupy disjoint DRAM ranges of one
    resident image; the scheduler keeps one admission queue per program
    and never releases a mixed batch, so only same-program requests
    gang (their streams are identical; a mixed gang would be
    semantically wrong and the pool refuses it anyway).

  * **backpressure, typed and loud** — queues are bounded
    (``queue_cap``).  On overflow the ``"reject"`` policy raises
    :class:`QueueFull` at submit; ``"shed_oldest"`` admits the newcomer
    and fails the oldest parked future with :class:`Shed`.  A per-
    request (or config-default) deadline fails a still-parked request
    with :class:`DeadlineExpired` the moment it lapses.  Nothing is ever
    dropped silently: every outcome is a typed exception on a future or
    at the submit site.

Determinism contract: admission changes WHEN a request runs, never what
it computes — every released request executes the same pre-staged stream
on its own slot device, so results are byte-identical to serial
execution.  The fuzzer's ``sched`` flavor byte-diffs random graphs
through randomized window/backpressure configs against serial runs.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from queue import Queue as _Queue
from dataclasses import dataclass, replace
from typing import Any, Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from .isa import GemmInsn, IsaLayout, LoadStoreInsn
from .program import CompiledProgram
from .serve import (DevicePool, PoolClosed, PoolFuture, Session, SlotDied,
                    WaitTimeout)
from .simulator import TimingModel, replay_timing

#: vmap interpret-mode cliff measured in PR 5: batching more than ~24
#: tiles into one interpreted vmap launch stops amortizing dispatch
#: overhead (BENCH_tiles.json, T=24).  The auto-tuner penalizes gang
#: widths that push a segment's tiles-per-launch past this knee.
VMAP_INTERPRET_CLIFF = 24

SCHED_POLICIES = ("reject", "shed_oldest")


class QueueFull(RuntimeError):
    """``policy="reject"``: the program's admission queue is at
    ``queue_cap``; the submit is refused (raised at the submit site,
    nothing was enqueued)."""
    pass


class Shed(RuntimeError):
    """``policy="shed_oldest"``: this parked request was evicted to
    admit a newer one; raised by the shed request's ``wait()``."""
    pass


class DeadlineExpired(RuntimeError):
    """The request's deadline lapsed while it was still parked in the
    admission queue; raised by its ``wait()``."""
    pass


# ----------------------------------------------------------------------
# gang-width auto-tuning
# ----------------------------------------------------------------------
def _stream_costs(compiled: CompiledProgram,
                  timing: Optional[TimingModel] = None
                  ) -> List[Tuple[int, int, int]]:
    """Per accelerator segment: (amortizable_cycles, lockstep_cycles,
    gemm_tiles).  Amortizable = fixed DMA setup latency, paid once per
    batched launch by a gang instead of once per member; lockstep =
    everything else (compute + streaming bytes), replicated per member."""
    spec = compiled.spec
    tm = timing or TimingModel(spec)
    isa = IsaLayout(spec)
    out = []
    for step in compiled.accel_steps:
        insns = isa.decode_stream(np.ascontiguousarray(step.stream))
        total = replay_timing(spec, insns, tm).total_cycles
        fixed = sum(spec.dram_latency_cycles for i in insns
                    if isinstance(i, LoadStoreInsn)
                    and i.y_size * i.x_size > 0)
        fixed = min(fixed, total)   # pipeline overlap can hide setup
        tiles = sum(1 for i in insns if isinstance(i, GemmInsn))
        out.append((fixed, total - fixed, tiles))
    return out


def stream_costs(compiled: CompiledProgram,
                 timing: Optional[TimingModel] = None
                 ) -> List[Tuple[int, int, int]]:
    """Memoized :func:`_stream_costs`.  A TimingModel's latencies are
    fully determined by its class and its (frozen, hashable) spec, so
    the memo key is exactly that pair — a fresh ``TimingModel(spec)``
    per call still hits.  The cache lives on the CompiledProgram
    (``_cost_cache``), so the Scheduler's gang-width tuner and the
    autotuner's cycle oracle share ONE decode + replay per program."""
    tm = timing or TimingModel(compiled.spec)
    key = (type(tm).__name__, tm.spec)
    got = compiled._cost_cache.get(key)
    if got is None:
        got = _stream_costs(compiled, tm)
        compiled._cost_cache[key] = got
    return got


def predict_gang_cycles(compiled: CompiledProgram, width: int,
                        timing: Optional[TimingModel] = None,
                        cliff: int = VMAP_INTERPRET_CLIFF,
                        costs: Optional[List[Tuple[int, int, int]]] = None
                        ) -> float:
    """Predicted per-call cycles when `width` requests run as one gang.
    Fixed DMA setup amortizes across the gang (one batched launch per
    segment); lockstep cycles replicate, degraded by the interpret-mode
    penalty once a segment's tiles-per-launch exceed the cliff.  Pass
    precomputed ``costs`` when sweeping widths — the costs depend only
    on the program, not the width."""
    cost = 0.0
    for fixed, lockstep, tiles in (costs if costs is not None
                                   else stream_costs(compiled, timing)):
        penalty = max(1.0, (tiles * width) / cliff) if tiles else 1.0
        cost += lockstep * penalty + fixed / width
    return cost


def auto_gang_width(compiled: CompiledProgram, max_width: int,
                    timing: Optional[TimingModel] = None,
                    cliff: int = VMAP_INTERPRET_CLIFF,
                    eps: float = 0.05) -> int:
    """Widest gang that still pays: walk the width up from 1 and stop
    at the first step whose predicted per-call cycles improve by less
    than `eps` (the knee), never exceeding `max_width` (the pool size —
    a gang wider than the pool cannot be scheduled in one round).

    One alignment override: gangs NARROWER than the pool can never
    double-buffer behind each other (a partial-width release strands the
    remaining slots and would desync the next batch), so if full width
    is predicted no worse per call than the knee, take full width — the
    only reason to stay narrow is the vmap recompile cliff actually
    making wider gangs more expensive."""
    if max_width <= 1:
        return max(1, max_width)
    # one decode + replay for the whole sweep: the per-segment costs do
    # not depend on the candidate width
    costs = stream_costs(compiled, timing)
    best = 1
    prev = predict_gang_cycles(compiled, 1, timing, cliff, costs=costs)
    for w in range(2, max_width + 1):
        cur = predict_gang_cycles(compiled, w, timing, cliff, costs=costs)
        if cur >= prev * (1.0 - eps):
            break
        best, prev = w, cur
    if best < max_width:
        full = predict_gang_cycles(compiled, max_width, timing, cliff,
                                   costs=costs)
        if full <= prev:
            return max_width
    return best


# ----------------------------------------------------------------------
# config / stats / futures
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SchedConfig:
    """Admission-control knobs.  ``gang_width=None`` auto-tunes per
    program from the TimingModel; an explicit width is clamped to the
    pool size."""
    window_us: float = 500.0            # max parking time before release
    gang_width: Optional[int] = None    # None -> auto_gang_width per prog
    queue_cap: int = 256                # per-program parked-request bound
    policy: str = "reject"              # overflow: reject | shed_oldest
    default_deadline_us: Optional[float] = None  # parked-request deadline
    vmap_cliff: int = VMAP_INTERPRET_CLIFF
    autotune_eps: float = 0.05
    # released gangs in flight at once: 2 double-buffers the pool (one
    # gang executing while the next parks on the slot queues — still
    # lockstep, since the pool admits at round boundaries); 1 serializes
    # releases (simplest to reason about, idle pool between gangs)
    pipeline_depth: int = 2

    def __post_init__(self):
        if self.policy not in SCHED_POLICIES:
            raise ValueError(
                f"policy {self.policy!r} not in {SCHED_POLICIES}")
        if self.window_us <= 0:
            raise ValueError("window_us must be > 0")
        if self.gang_width is not None and self.gang_width < 1:
            raise ValueError("gang_width must be >= 1 (or None to "
                             "auto-tune)")
        if self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")


@dataclass
class ProgStats:
    """Admission counters for one program's queue (dispatcher-thread
    owned; read via :meth:`Scheduler.stats`)."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0             # released but the pool run errored
    rejected: int = 0           # QueueFull at submit
    shed: int = 0               # evicted by shed_oldest
    expired: int = 0            # deadline lapsed while parked
    releases: int = 0           # batches handed to the pool
    full_releases: int = 0      # released because gang width was reached
    window_timeouts: int = 0    # released because the window expired
    flush_releases: int = 0     # released by flush()/close()
    max_gang: int = 0           # widest observed executed gang
    queue_hiwater: int = 0


class SchedFuture:
    """Handle to one admitted request.  Resolves when the pool finishes
    the released batch; fails with :class:`Shed` /
    :class:`DeadlineExpired` if backpressure claimed it while parked, or
    with the worker's error if execution failed."""

    def __init__(self, seq: int, prog_idx: int):
        self.seq = seq
        self.prog_idx = prog_idx
        self.submit_at = time.perf_counter()
        self.released_at: Optional[float] = None
        self.done_at: Optional[float] = None
        self.gang_size = 0              # widest gang this request rode
        self.pool_future: Optional[PoolFuture] = None
        self._done = threading.Event()
        self._outputs: Any = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    @property
    def latency_s(self) -> Optional[float]:
        """Arrival-to-completion latency (open-loop: includes parking)."""
        if self.done_at is None:
            return None
        return self.done_at - self.submit_at

    def wait(self, timeout: Optional[float] = None
             ) -> Union[np.ndarray, Dict[str, np.ndarray]]:
        if not self._done.wait(timeout):
            raise WaitTimeout(
                f"sched request #{self.seq} not done within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._outputs

    result = wait

    def _finish(self, outputs: Any) -> None:
        if self._done.is_set():
            return
        self._outputs = outputs
        self.done_at = time.perf_counter()
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        if self._done.is_set():
            return
        if hasattr(exc, "add_note"):
            try:
                exc.add_note(f"[sched request #{self.seq}, program "
                             f"{self.prog_idx}]")
            except TypeError:               # pragma: no cover
                pass
        self._exc = exc
        self.done_at = time.perf_counter()
        self._done.set()


@dataclass
class _Parked:
    future: SchedFuture
    inputs: Dict[str, np.ndarray]
    session: Optional[Session] = None
    deadline_at: Optional[float] = None   # perf_counter absolute


class SchedSession:
    """A pool :class:`Session` whose submits go through the admission
    window: token-step submits of concurrent sessions park together and
    release as one gang (same program, same segment, distinct slots —
    the continuous-batching decode pattern)."""

    def __init__(self, scheduler: "Scheduler", session: Session,
                 prog_idx: int):
        self.scheduler = scheduler
        self.session = session
        self._prog_idx = prog_idx

    @property
    def sid(self) -> int:
        return self.session.sid

    @property
    def slot_id(self) -> int:
        return self.session.slot_id

    def submit(self, deadline_us: Optional[float] = None,
               **inputs: np.ndarray) -> SchedFuture:
        return self.scheduler._submit(self._prog_idx, inputs,
                                      session=self.session,
                                      deadline_us=deadline_us)

    def state(self, name: str) -> np.ndarray:
        return self.session.state(name)

    def reset(self) -> None:
        self.session.reset()


# ----------------------------------------------------------------------
# the scheduler
# ----------------------------------------------------------------------
class Scheduler:
    """Continuous-batching admission control over one DevicePool.

        pool = DevicePool(compile_multi([p1, p2]), size=4)
        sched = Scheduler(pool, SchedConfig(window_us=800))
        fut = sched.submit(x=arr)                  # default program
        fut2 = sched.submit(program=1, x=arr2)     # co-staged peer
        y = fut.wait()

    The scheduler OWNS pool submission: callers must not call
    ``pool.submit*`` directly while a Scheduler is attached, or released
    batches would interleave with stragglers and desync the gangs.
    ``close()`` drains the admission queues; the pool itself stays open
    (the caller created it, the caller closes it)."""

    def __init__(self, pool: DevicePool,
                 config: Optional[SchedConfig] = None,
                 timing: Optional[TimingModel] = None):
        self.pool = pool
        self.config = config or SchedConfig()
        nprog = len(pool.programs)
        self._timing = timing               # retained: re-tune on death
        self._fixed_width = self.config.gang_width
        self._tuned_alive = len(pool)       # widths tuned for this many
        if self.config.gang_width is not None:
            w = max(1, min(self.config.gang_width, len(pool)))
            self.gang_widths = [w] * nprog
            self._autotuned = False
        else:
            self.gang_widths = [
                auto_gang_width(c, len(pool), timing=timing,
                                cliff=self.config.vmap_cliff,
                                eps=self.config.autotune_eps)
                for c in pool.programs]
            self._autotuned = True
        self._queues: List[Deque[_Parked]] = [deque()
                                              for _ in range(nprog)]
        self._stats = [ProgStats() for _ in range(nprog)]
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._pending = 0           # parked + released-but-unfinished
        self._flush = False
        self._closed = False
        self._outstanding = 0       # released gangs not yet retired
        self._last_aligned = True   # was the last release full-width?
        # completer thread: waits out released gangs and resolves their
        # futures, so the dispatcher can pipeline the next release while
        # the previous one executes (pipeline_depth throttles it)
        self._done_q: "_Queue" = _Queue()
        self._completer = threading.Thread(
            target=self._run_completer, name="repro-sched-completer",
            daemon=True)
        self._completer.start()
        self._dispatcher = threading.Thread(
            target=self._run_dispatcher, name="repro-sched-dispatcher",
            daemon=True)
        self._dispatcher.start()

    # ------------------------------------------------------------------
    def __enter__(self) -> "Scheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _prog_idx(self, program: Union[None, int, CompiledProgram]) -> int:
        if program is None:
            return 0
        if isinstance(program, int):
            if not 0 <= program < len(self.pool.programs):
                raise ValueError(f"program index {program} out of range")
            return program
        for i, c in enumerate(self.pool.programs):
            if c is program:
                return i
        raise ValueError("program was not staged on this scheduler's "
                         "pool (co-stage it with program.compile_multi)")

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, program: Union[None, int, CompiledProgram] = None,
               deadline_us: Optional[float] = None,
               **inputs: np.ndarray) -> SchedFuture:
        """Park one request in its program's admission queue.  Raises
        :class:`QueueFull` immediately under the reject policy when the
        queue is at cap; otherwise returns a future that resolves when
        the released gang finishes (or fails typed under backpressure)."""
        return self._submit(self._prog_idx(program), inputs,
                            session=None, deadline_us=deadline_us)

    def session(self, program: Union[None, int, CompiledProgram] = None,
                slot: Optional[int] = None) -> SchedSession:
        """Open a persistent-state session whose submits go through the
        admission window (see :class:`SchedSession`)."""
        pi = self._prog_idx(program)
        return SchedSession(self, self.pool.session(slot=slot,
                                                    program=pi), pi)

    def _submit(self, pi: int, inputs: Dict[str, np.ndarray],
                session: Optional[Session],
                deadline_us: Optional[float]) -> SchedFuture:
        self.pool.programs[pi].check_inputs(inputs)   # fail in caller
        if deadline_us is None:
            deadline_us = self.config.default_deadline_us
        with self._lock:
            if self._closed:
                raise PoolClosed("submit() on a closed Scheduler")
            q = self._queues[pi]
            st = self._stats[pi]
            if len(q) >= self.config.queue_cap:
                if self.config.policy == "reject":
                    st.rejected += 1
                    raise QueueFull(
                        f"program {pi} admission queue at cap "
                        f"{self.config.queue_cap} (policy=reject)")
                victim = q.popleft()        # shed_oldest
                st.shed += 1
                self._pending -= 1
                victim.future._fail(Shed(
                    f"request #{victim.future.seq} shed: program {pi} "
                    f"queue hit cap {self.config.queue_cap} and a newer "
                    f"request arrived (policy=shed_oldest)"))
            fut = SchedFuture(seq=next(self._seq), prog_idx=pi)
            deadline_at = (fut.submit_at + deadline_us * 1e-6
                           if deadline_us is not None else None)
            q.append(_Parked(future=fut, inputs=dict(inputs),
                             session=session, deadline_at=deadline_at))
            st.submitted += 1
            st.queue_hiwater = max(st.queue_hiwater, len(q))
            self._pending += 1
            self._work.notify_all()
        return fut

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Release every parked request now (in gang-width batches)
        without waiting for windows to fill — e.g. before a drain."""
        with self._lock:
            self._flush = True
            self._work.notify_all()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Flush, then block until every admitted request resolved."""
        self.flush()
        with self._lock:
            if not self._idle.wait_for(lambda: self._pending == 0,
                                       timeout=timeout):
                raise TimeoutError("Scheduler.drain timed out")

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Reject new submits, release and finish everything parked,
        stop the dispatcher.  The pool is left open."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._flush = True
            self._work.notify_all()
        self._dispatcher.join(timeout)
        self._done_q.put(None)              # stop the completer
        self._completer.join(timeout)
        if self._dispatcher.is_alive():     # wedged release: fail loudly
            err = PoolClosed(
                f"Scheduler.close: dispatcher did not drain within "
                f"{timeout}s; failing parked futures")
            with self._lock:
                for q in self._queues:
                    while q:
                        p = q.popleft()
                        self._pending -= 1
                        p.future._fail(err)
                self._idle.notify_all()

    # ------------------------------------------------------------------
    # the dispatcher
    # ------------------------------------------------------------------
    def _run_dispatcher(self) -> None:
        try:
            self._dispatch_loop()
        except BaseException as e:
            # a dead dispatcher must not strand parked waiters
            with self._lock:
                for pi, q in enumerate(self._queues):
                    while q:
                        p = q.popleft()
                        self._pending -= 1
                        self._stats[pi].failed += 1
                        p.future._fail(PoolClosed(
                            f"request #{p.future.seq} lost: scheduler "
                            f"dispatcher died: {e!r}"))
                self._idle.notify_all()
            raise

    def _next_wakeup(self, now: float) -> Optional[float]:
        """Seconds until the earliest FUTURE window or deadline event
        (lock held); None = sleep until notified.  Timers that already
        fired are excluded on purpose: an expired head that stays parked
        is blocked on pool occupancy, and the completer notifies on
        every batch completion — re-arming its lapsed timer would spin
        the dispatcher on the GIL and strangle the very gangs it is
        waiting out."""
        window_s = self.config.window_us * 1e-6
        t: Optional[float] = None
        for q in self._queues:
            if not q:
                continue
            head = q[0].future.submit_at + window_s
            if head > now:
                t = head if t is None else min(t, head)
            for p in q:
                if p.deadline_at is not None and p.deadline_at > now:
                    t = p.deadline_at if t is None else min(t, p.deadline_at)
        for q in self._queues:
            if q and len(self._eligible_of(q)) != len(q):
                # someone is parked for a dead slot: poll so a respawn
                # (which the pool does not signal us about) is noticed
                poll = now + max(window_s, 0.005)
                t = poll if t is None else min(t, poll)
                break
        return None if t is None else t - now

    def _expire_deadlines(self, now: float) -> None:
        """Fail parked requests whose deadline lapsed (lock held)."""
        for pi, q in enumerate(self._queues):
            if not q:
                continue
            keep: Deque[_Parked] = deque()
            for p in q:
                if p.deadline_at is not None and p.deadline_at <= now:
                    self._stats[pi].expired += 1
                    self._pending -= 1
                    p.future._fail(DeadlineExpired(
                        f"request #{p.future.seq} deadline lapsed after "
                        f"{(now - p.future.submit_at) * 1e6:.0f}us parked "
                        f"in program {pi}'s admission queue"))
                else:
                    keep.append(p)
            if len(keep) != len(q):
                q.clear()
                q.extend(keep)

    def _retune_if_needed(self) -> None:
        """Re-tune gang widths when the alive-slot count changed (lock
        held): a pool degraded by a slot death must not stall full-width
        releases waiting for a width it can no longer co-schedule, and a
        respawn restores the original widths.  Auto widths re-run
        :func:`auto_gang_width` against the surviving count; fixed
        widths re-clamp."""
        alive = sum(1 for s in self.pool.slots if not s.dead)
        if alive == self._tuned_alive or alive < 1:
            return
        self._tuned_alive = alive
        if self._autotuned:
            self.gang_widths = [
                auto_gang_width(c, alive, timing=self._timing,
                                cliff=self.config.vmap_cliff,
                                eps=self.config.autotune_eps)
                for c in self.pool.programs]
        else:
            w = max(1, min(self._fixed_width, alive))
            self.gang_widths = [w] * len(self.pool.programs)

    def _eligible_of(self, q: Deque[_Parked]) -> List[_Parked]:
        """Servable-now members of one queue (lock held).  A request
        pinned to a dead slot (or a lost session) stays PARKED — its
        deadline keeps counting toward DeadlineExpired while a respawn
        races to revive the slot — instead of poisoning a released
        batch with the SlotDied the whole gang would then share."""
        if all(s.dead for s in self.pool.slots):
            return []
        out: List[_Parked] = []
        for p in q:
            if p.session is not None:
                st = p.session._state
                if st.lost or self.pool.slots[st.slot_id].dead:
                    continue
            out.append(p)
        return out

    def _sweep_unservable(self) -> None:
        """Flush/close is final: a parked request whose slot never came
        back (or whose session state is lost, or with every slot dead)
        fails typed :class:`SlotDied` now instead of parking forever on
        a drain that would otherwise never finish (lock held)."""
        for pi, q in enumerate(self._queues):
            if not q:
                continue
            keep: Deque[_Parked] = deque()
            swept = False
            for p in q:
                why = None
                if all(s.dead for s in self.pool.slots):
                    why = "every pool slot is dead"
                elif p.session is not None:
                    st = p.session._state
                    if st.lost:
                        why = (f"session {st.sid}'s state was lost when "
                               f"its slot died")
                    elif self.pool.slots[st.slot_id].dead:
                        why = (f"session {st.sid}'s slot {st.slot_id} "
                               f"is dead")
                if why is None:
                    keep.append(p)
                    continue
                swept = True
                self._stats[pi].failed += 1
                self._pending -= 1
                p.future._fail(SlotDied(
                    f"request #{p.future.seq} unservable at flush: "
                    f"{why}"))
            if swept:
                q.clear()
                q.extend(keep)
                self._idle.notify_all()

    def _pick_batch(self, now: float
                    ) -> Optional[Tuple[int, List[_Parked], str]]:
        """FIFO-fair batch selection (lock held): among programs whose
        queue is ready (width reached, window expired, or flushing),
        release the one with the oldest head.  Readiness and membership
        consider only ELIGIBLE requests (see :meth:`_eligible_of`):
        requests parked for a down slot neither release nor block their
        queue-mates."""
        window_s = self.config.window_us * 1e-6
        best: Optional[Tuple[float, int, str]] = None
        for pi, q in enumerate(self._queues):
            if not q:
                continue
            elig = self._eligible_of(q)
            if not elig:
                continue
            width = self.gang_widths[pi]
            if len(elig) >= width:
                reason = "full"
            elif self._flush or self._closed:
                reason = "flush"
            elif (now - elig[0].future.submit_at >= window_s
                    and self._outstanding == 0):
                # window expired AND the pool is idle: releasing a
                # partial gang while gangs are still executing would
                # only park it on busy slot queues — keep collecting
                # instead (continuous batching; deadlines still apply)
                reason = "window"
            else:
                continue
            head = elig[0].future.submit_at
            if best is None or head < best[0]:
                best = (head, pi, reason)
        if best is None:
            return None
        _, pi, reason = best
        q = self._queues[pi]
        elig = self._eligible_of(q)
        batch = elig[:min(self.gang_widths[pi], len(elig))]
        chosen = {id(p) for p in batch}
        keep = [p for p in q if id(p) not in chosen]
        q.clear()
        q.extend(keep)
        return pi, batch, reason

    def _dispatch_loop(self) -> None:
        while True:
            with self._lock:
                while True:
                    if self._closed and self._pending == 0:
                        return
                    now = time.perf_counter()
                    self._retune_if_needed()
                    self._expire_deadlines(now)
                    if self._flush or self._closed:
                        self._sweep_unservable()
                    picked = self._pick_batch(now)
                    if picked is not None:
                        break
                    if self._flush and not any(self._queues):
                        self._flush = False
                    if self._closed and self._pending == 0:
                        return
                    self._work.wait(timeout=self._next_wakeup(now))
                pi, batch, reason = picked
                st = self._stats[pi]
                st.releases += 1
                if reason == "full":
                    st.full_releases += 1
                elif reason == "window":
                    st.window_timeouts += 1
                else:
                    st.flush_releases += 1
                # throttle: at most pipeline_depth released gangs in
                # flight — one executing, the rest parked lockstep on
                # the slot queues awaiting the next round boundary.
                # Only FULL-width batches behind full-width batches may
                # pipeline: a partial gang occupies a slot subset, and
                # piling the next batch behind it would split that
                # batch across idle and busy slots (permanent desync) —
                # so anything partial waits for an idle pool.
                aligned = self._batch_aligned(batch)
                if aligned and self._last_aligned:
                    self._work.wait_for(
                        lambda: self._outstanding <
                        self.config.pipeline_depth)
                else:
                    self._work.wait_for(
                        lambda: self._outstanding == 0)
                self._last_aligned = aligned
                self._outstanding += 1
            self._release(pi, batch)

    def _batch_aligned(self, batch: List[_Parked]) -> bool:
        """True when the batch covers every live slot exactly once —
        the only shape that can pile behind an in-flight gang and still
        co-admit at one round boundary (lock held)."""
        alive = sum(1 for s in self.pool.slots if not s.dead)
        if len(batch) != alive:
            return False
        pinned = [p.session.slot_id for p in batch
                  if p.session is not None]
        return len(set(pinned)) == len(pinned)

    def _release(self, pi: int, batch: List[_Parked]) -> None:
        """Hand one same-program batch to the pool in one burst — the
        members land on distinct slots together and stay lockstep for
        every segment (that is the whole point of the window) — then
        pass it to the completer, which resolves the futures while the
        dispatcher pipelines the next release."""
        prog = self.pool.programs[pi]
        released_at = time.perf_counter()
        pairs: List[Tuple[_Parked, Optional[PoolFuture]]] = []
        try:
            # one atomic enqueue: the pool admits the whole batch at the
            # same round boundary, so it stays lockstep end to end
            pfs = self.pool._enqueue_batch(
                [(p.inputs,
                  p.session._state if p.session is not None else None,
                  prog) for p in batch])
            for p, pf in zip(batch, pfs):
                p.future.released_at = released_at
                p.future.pool_future = pf
                pairs.append((p, pf))
        except BaseException as e:          # dead slot / closed pool
            for p in batch:
                p.future.released_at = released_at
                p.future._fail(e)
                pairs.append((p, None))
        self._done_q.put((pi, pairs))

    def _run_completer(self) -> None:
        while True:
            item = self._done_q.get()
            if item is None:
                return
            pi, pairs = item
            st = self._stats[pi]
            for p, pf in pairs:
                done = 0
                if pf is not None:
                    try:
                        out = pf.wait()
                        p.future.gang_size = max(
                            (s.gang_size for s in pf.stats), default=1)
                        st.max_gang = max(st.max_gang,
                                          p.future.gang_size)
                        p.future._finish(out)
                        done = 1
                    except BaseException as e:
                        p.future._fail(e)
                with self._lock:
                    self._pending -= 1
                    st.completed += done
                    st.failed += 0 if done else 1
                    self._idle.notify_all()
            with self._lock:
                self._outstanding -= 1
                self._work.notify_all()

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def stats(self) -> List[ProgStats]:
        return [replace(s) for s in self._stats]

    def queue_depths(self) -> List[int]:
        with self._lock:
            return [len(q) for q in self._queues]

    def describe(self) -> str:
        """Config + per-program admission state + the pool's own
        describe() — the ops-console dump."""
        c = self.config
        widths = ",".join(str(w) for w in self.gang_widths)
        lines = [
            f"sched[window {c.window_us:g}us, gang widths [{widths}]"
            f"{' (auto)' if self._autotuned else ''}, cap {c.queue_cap}, "
            f"policy {c.policy}"
            + (f", deadline {c.default_deadline_us:g}us"
               if c.default_deadline_us is not None else "")
            + f", vmap cliff {c.vmap_cliff}]"]
        with self._lock:
            depths = [len(q) for q in self._queues]
        for pi, st in enumerate(self._stats):
            lines.append(
                f"  prog{pi}: width {self.gang_widths[pi]}, "
                f"q{depths[pi]} (hiwater {st.queue_hiwater}), "
                f"{st.submitted} submitted, {st.completed} completed, "
                f"{st.releases} releases ({st.full_releases} full, "
                f"{st.window_timeouts} window, {st.flush_releases} "
                f"flush), max gang {st.max_gang}, "
                f"{st.rejected} rejected, {st.shed} shed, "
                f"{st.expired} expired, {st.failed} failed")
        lines.append(self.pool.describe())
        return "\n".join(lines)
