"""VTA hardware template parameters.

The paper's central artifact is a *parameterizable* accelerator template:
the GEMM-core intrinsic shape, data-type widths and SRAM depths are template
parameters, and the ISA encoding is *derived* from them ("the VTA ISA
changes as VTA's architectural parameters are modified").  This module is
the single source of truth for those parameters; `isa.py` derives its field
widths from a `HardwareSpec`, and the runtime/simulator adapt automatically
— reproducing the co-design fluidity the paper describes in §2.2.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


def _log2(x: int) -> int:
    l = int(math.log2(x))
    if 1 << l != x:
        raise ValueError(f"{x} is not a power of two")
    return l


@dataclass(frozen=True)
class HardwareSpec:
    """Template parameters of one VTA instance (defaults: paper's Pynq build)."""

    # --- GEMM core intrinsic shape (single-cycle matrix multiply) ---
    batch: int = 1            # rows of the input/acc tensor register
    block_in: int = 16        # inner (reduction) dimension
    block_out: int = 16       # columns of the acc tensor register

    # --- data type widths, bits ---
    inp_bits: int = 8
    wgt_bits: int = 8
    acc_bits: int = 32
    out_bits: int = 8
    uop_bits: int = 32

    # --- on-chip SRAM sizes, bytes (paper §5: 32kB inp, 256kB wgt,
    #     128kB acc/register-file, 16kB uop cache) ---
    inp_buff_bytes: int = 32 * 1024
    wgt_buff_bytes: int = 256 * 1024
    acc_buff_bytes: int = 128 * 1024
    out_buff_bytes: int = 32 * 1024
    uop_buff_bytes: int = 16 * 1024

    # --- clocking / memory system (used by the cycle-level pipeline model) ---
    freq_mhz: float = 100.0
    dram_rd_bytes_per_cycle: float = 8.0   # effective DMA read bandwidth
    dram_wr_bytes_per_cycle: float = 8.0   # effective DMA write bandwidth
    dram_latency_cycles: int = 200         # fixed DMA setup latency
    alu_init_interval: int = 2             # §2.5: tensor ALU II >= 2
    queue_depth: int = 512                 # command-queue depth (wide window)

    def __post_init__(self):
        # sub-byte storage is weight-only (activations stay int8): the
        # packed WGT element must still be a whole number of bytes
        if self.wgt_bits not in (1, 2, 4, 8):
            raise ValueError(f"wgt_bits must be 1, 2, 4 or 8, "
                             f"got {self.wgt_bits}")
        if self.block_out * self.block_in * self.wgt_bits % 8:
            raise ValueError("wgt element is not byte-aligned: "
                             f"{self.block_out}x{self.block_in}"
                             f"x{self.wgt_bits}b")

    # ------------------------------------------------------------------
    # element ("tensor register") geometry
    # ------------------------------------------------------------------
    @property
    def wgt_packed(self) -> bool:
        """Sub-byte weight storage: DRAM/SRAM-load bytes are b-bit packed;
        the GEMM core still computes on sign-extended int8 values."""
        return self.wgt_bits < 8

    @property
    def inp_elem_bytes(self) -> int:
        return self.batch * self.block_in * self.inp_bits // 8

    @property
    def wgt_elem_bytes(self) -> int:
        return self.block_out * self.block_in * self.wgt_bits // 8

    @property
    def acc_elem_bytes(self) -> int:
        return self.batch * self.block_out * self.acc_bits // 8

    @property
    def out_elem_bytes(self) -> int:
        return self.batch * self.block_out * self.out_bits // 8

    @property
    def uop_elem_bytes(self) -> int:
        return self.uop_bits // 8

    # SRAM depths, in elements
    @property
    def inp_depth(self) -> int:
        return self.inp_buff_bytes // self.inp_elem_bytes

    @property
    def wgt_depth(self) -> int:
        return self.wgt_buff_bytes // self.wgt_elem_bytes

    @property
    def acc_depth(self) -> int:
        return self.acc_buff_bytes // self.acc_elem_bytes

    @property
    def out_depth(self) -> int:
        return self.out_buff_bytes // self.out_elem_bytes

    @property
    def uop_depth(self) -> int:
        return self.uop_buff_bytes // self.uop_elem_bytes

    # ------------------------------------------------------------------
    # derived ISA field widths (address bits per SRAM)
    # ------------------------------------------------------------------
    @property
    def inp_addr_bits(self) -> int:
        return max(1, _log2(self.inp_depth))

    @property
    def wgt_addr_bits(self) -> int:
        return max(1, _log2(self.wgt_depth))

    @property
    def acc_addr_bits(self) -> int:
        return max(1, _log2(self.acc_depth))

    @property
    def uop_addr_bits(self) -> int:
        return max(1, _log2(self.uop_depth))

    # ------------------------------------------------------------------
    # performance identities (used by §2.6 bandwidth benchmark + rooflines)
    # ------------------------------------------------------------------
    @property
    def macs_per_cycle(self) -> int:
        return self.batch * self.block_in * self.block_out

    @property
    def peak_gops(self) -> float:
        """Peak throughput in GOPS (1 MAC = 2 ops). Pynq default: 51.2 GOPS
        for batch=1 … wait: paper quotes ~51 GOPS for the 16x16 unit @100MHz,
        i.e. 16*16*2*100e6 = 51.2e9."""
        return self.macs_per_cycle * 2 * self.freq_mhz / 1e3

    @property
    def gemm_sram_bandwidth_gbps(self) -> dict[str, float]:
        """§2.6: per-buffer bandwidth (Gbit/s) needed to keep the GEMM core
        busy at one matrix multiply per cycle."""
        f = self.freq_mhz * 1e6
        return {
            "inp": self.batch * self.block_in * self.inp_bits * f / 1e9,
            "wgt": self.block_out * self.block_in * self.wgt_bits * f / 1e9,
            # register file is read + written every cycle (accumulate)
            "acc": 2 * self.batch * self.block_out * self.acc_bits * f / 1e9,
        }

    def replace(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)


def pynq() -> HardwareSpec:
    """The paper's evaluation build (§5)."""
    return HardwareSpec()


def lowbit(bits: int = 4, base: HardwareSpec | None = None) -> HardwareSpec:
    """A template instance with packed sub-byte weights (the
    representation-flexibility claim: only the weight width changes; the
    ISA encoding, scheduler and both engines adapt).  The WGT SRAM keeps
    the same element DEPTH (bytes scale down with the element width):
    letting the depth grow 8/bits-fold instead would widen the uop
    address fields past the 32-bit uop budget — the derived-ISA
    constraint surfacing exactly as §2.2 describes."""
    base = base or pynq()
    return base.replace(wgt_bits=bits,
                        wgt_buff_bytes=base.wgt_buff_bytes * bits // 8)


def pynq_batch2() -> HardwareSpec:
    """The §2.6 bandwidth-example config: BATCH=2, 200 MHz."""
    return HardwareSpec(batch=2, freq_mhz=200.0)


# DMA/compute constants fitted against MEASURED Pallas kernel times by
# ``benchmarks.bench_kernels.fit_timing_constants`` (dev container, jax
# 0.4.37 CPU interpret mode, 2026-08): the pynq-template GEMM intrinsic
# sustains ~2.8 GMAC/s through the interpreted kernel (-> ~11 MHz
# effective at 256 MACs/cycle) and the simulated-DRAM memcpy path moves
# ~7 GB/s (-> ~650 B/cycle, ~37 cycles fixed setup).  Re-run the fit on
# new hardware (real TPU: orders of magnitude higher) and pass the result
# to ``calibrated``; these recorded values make RunStats.total_cycles
# predict interpret-mode wall-clock within a small factor on CI.
HOST_FIT = dict(freq_mhz=11.0,
                dram_rd_bytes_per_cycle=650.0,
                dram_wr_bytes_per_cycle=650.0,
                dram_latency_cycles=37)


def calibrated(base: HardwareSpec | None = None,
               fit: dict | None = None) -> HardwareSpec:
    """Template instance whose TimingModel constants are calibrated
    against measured Pallas kernel times, so ``RunStats.total_cycles`` is
    meaningful (predicts wall-clock) on BOTH engines — the simulator
    prices the stream with them directly, and ``PallasBackend`` replays
    the same TimingModel when given one.  Defaults to ``HOST_FIT`` (the
    recorded dev-container fit); pass the output of
    ``benchmarks.bench_kernels.fit_timing_constants()`` for this host."""
    return (base or pynq()).replace(**(fit or HOST_FIT))


def spec_feasible(spec: HardwareSpec) -> str | None:
    """Validate one candidate template instance against every derived-ISA
    constraint: power-of-two SRAM depths, address fields that fit the
    encodings, and the 32-bit uop budget (`uop_bits` must hold the acc
    dst + max(inp, acc) src + wgt address fields).  Returns None when the
    instance is buildable, else the constraint violation message — the
    autotuner's cheap front-gate before it ever compiles a candidate."""
    from .isa import IsaLayout
    from .microop import UopLayout
    try:
        # constructing the derived layouts runs every width/budget check
        UopLayout(spec)
        IsaLayout(spec)
        # depth accessors raise on non-power-of-two SRAM geometry
        spec.inp_addr_bits, spec.wgt_addr_bits, spec.acc_addr_bits
        spec.uop_addr_bits, spec.out_depth
    except (ValueError, ZeroDivisionError) as e:
        return str(e)
    return None


def tpu_like() -> HardwareSpec:
    """A TPU-v5e-flavoured instance of the template: MXU-shaped intrinsic
    (128x128), VMEM-scale buffers.  Used by the kernels' static VMEM
    analysis and the TPU-side napkin math; the behavioural simulator runs
    it exactly like any other template instance."""
    return HardwareSpec(
        batch=8,
        block_in=128,
        block_out=128,
        inp_buff_bytes=4 * 1024 * 1024,
        wgt_buff_bytes=8 * 1024 * 1024,
        acc_buff_bytes=4 * 1024 * 1024,
        out_buff_bytes=2 * 1024 * 1024,
        uop_buff_bytes=64 * 1024,
        freq_mhz=940.0,
        dram_rd_bytes_per_cycle=871.0,   # 819 GB/s HBM @ 0.94 GHz
        dram_wr_bytes_per_cycle=871.0,
        dram_latency_cycles=500,
    )
