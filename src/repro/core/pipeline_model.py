"""Roofline + latency-hiding analysis over the cycle-level simulator.

Reproduces the Fig. 15 methodology: for each workload, measure achieved
GOPS from the timed simulation of the *actual instruction stream* the
runtime emitted (with and without virtual threading), and place it against
the hardware roofline min(peak_gops, bandwidth * intensity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from .conv import ConvShape, schedule_conv2d
from .hwspec import HardwareSpec
from .runtime import Runtime
from .scheduler import Epilogue, schedule_matmul
from .simulator import RunStats, TimingModel


@dataclass
class RooflinePoint:
    name: str
    arithmetic_intensity: float     # ops / DRAM byte (from the timed run)
    gops: float                     # achieved throughput
    utilization: float              # GEMM-core busy fraction
    total_cycles: int
    virtual_threads: int
    roofline_gops: float            # min(peak, bw * intensity)

    @property
    def roofline_fraction(self) -> float:
        return self.gops / self.roofline_gops if self.roofline_gops else 0.0


def hardware_roofline(spec: HardwareSpec, intensity: float) -> float:
    bw_gbps = spec.dram_rd_bytes_per_cycle * spec.freq_mhz * 1e6 / 1e9
    return min(spec.peak_gops, bw_gbps * intensity)


def conv_roofline_point(spec: HardwareSpec, shape: ConvShape, name: str,
                        virtual_threads: int, seed: int = 0,
                        epilogue: Optional[Epilogue] = None) -> RooflinePoint:
    """Schedule + simulate one conv layer; return its roofline placement."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, size=(shape.n, shape.ic, shape.h, shape.w),
                     dtype=np.int8)
    w = rng.integers(-4, 4, size=(shape.oc, shape.ic, shape.kh, shape.kw),
                     dtype=np.int8)
    rt = Runtime(spec)
    schedule_conv2d(rt, x, w, shape, epilogue=epilogue,
                    virtual_threads=virtual_threads)
    stats = rt.synchronize(timing=TimingModel(spec))
    ai = stats.arithmetic_intensity
    return RooflinePoint(
        name=name, arithmetic_intensity=ai, gops=stats.gops(spec.freq_mhz),
        utilization=stats.compute_utilization, total_cycles=stats.total_cycles,
        virtual_threads=virtual_threads,
        roofline_gops=hardware_roofline(spec, ai))


def matmul_roofline_point(spec: HardwareSpec, M: int, N: int, K: int,
                          name: str, virtual_threads: int,
                          seed: int = 0) -> RooflinePoint:
    rng = np.random.default_rng(seed)
    a = rng.integers(-8, 8, size=(M, K), dtype=np.int8)
    w = rng.integers(-4, 4, size=(N, K), dtype=np.int8)
    rt = Runtime(spec)
    schedule_matmul(rt, a, w, virtual_threads=virtual_threads)
    stats = rt.synchronize(timing=TimingModel(spec))
    ai = stats.arithmetic_intensity
    return RooflinePoint(
        name=name, arithmetic_intensity=ai, gops=stats.gops(spec.freq_mhz),
        utilization=stats.compute_utilization, total_cycles=stats.total_cycles,
        virtual_threads=virtual_threads,
        roofline_gops=hardware_roofline(spec, ai))


def peak_compute_utilization(points: List[RooflinePoint]) -> float:
    """The paper's headline metric: max compute utilization across layers."""
    return max((p.utilization for p in points), default=0.0)
