"""Blocked tensor layouts for VTA DMA.

VTA DMAs move *tensor-register elements*: an INP element is a
(BATCH x BLOCK_IN) int8 block, a WGT element (BLOCK_OUT x BLOCK_IN), an
ACC/OUT element (BATCH x BLOCK_OUT).  Host tensors are packed into blocked
layouts so that 2D strided DMA (one instruction per tile) can address them
— the data-layout constraint the NNVM/TVM layers enforce (§1.2, §4.1).
"""
from __future__ import annotations

import numpy as np

from .hwspec import HardwareSpec


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: np.ndarray, axis: int, mult: int) -> np.ndarray:
    n = x.shape[axis]
    pad = _ceil_div(n, mult) * mult - n
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


# ----------------------------------------------------------------------
# generic blocked layouts (parameterized over the block sizes; the
# spec-typed pack_/unpack_ helpers below are instances of these)
# ----------------------------------------------------------------------
def block2d(a: np.ndarray, rb: int, cb: int) -> np.ndarray:
    """(R, C) -> (Rb, Cb, rb, cb); element (r_blk, c_blk)."""
    a = pad_to(pad_to(a, 0, rb), 1, cb)
    R, C = a.shape
    return (a.reshape(R // rb, rb, C // cb, cb)
            .transpose(0, 2, 1, 3).copy())


def unblock2d(blocked: np.ndarray, R: int, C: int) -> np.ndarray:
    """(Rb, Cb, rb, cb) -> (R, C) — inverse of block2d."""
    Rb, Cb, rb, cb = blocked.shape
    full = blocked.transpose(0, 2, 1, 3).reshape(Rb * rb, Cb * cb)
    return full[:R, :C]


def block_nchw(x: np.ndarray, rb: int, cb: int) -> np.ndarray:
    """(N, C, H, W) -> (Nb, Cb, H, W, rb, cb); element (n_blk, c_blk, h, w).
    Covers both conv activations (rb=BATCH, cb=BLOCK_IN) and conv weights
    (rb=BLOCK_OUT, cb=BLOCK_IN over (OC, IC, KH, KW))."""
    x = pad_to(pad_to(x, 0, rb), 1, cb)
    N, C, H, W = x.shape
    return (x.reshape(N // rb, rb, C // cb, cb, H, W)
            .transpose(0, 2, 4, 5, 1, 3).copy())


def unblock_nchw(blocked: np.ndarray, N: int, C: int) -> np.ndarray:
    """(Nb, Cb, H, W, rb, cb) -> (N, C, H, W) — inverse of block_nchw."""
    Nb, Cb, H, W, rb, cb = blocked.shape
    full = (blocked.transpose(0, 4, 1, 5, 2, 3)
            .reshape(Nb * rb, Cb * cb, H, W))
    return full[:N, :C]


# ----------------------------------------------------------------------
# sub-byte weight packing (wgt_bits in {1, 2, 4}).
#
# A WGT tensor-register element stays one DMA unit, but its
# (BLOCK_OUT x BLOCK_IN) values are stored as b-bit two's-complement
# fields packed 8/b per byte, little-endian within the byte (value j of
# the row-major flattened element lands at byte j*b//8, shifted left by
# (j*b) % 8).  `hwspec.wgt_elem_bytes` already scales with wgt_bits, so
# element-granular DMA addressing is unchanged — only the bytes shrink.
# ----------------------------------------------------------------------
def pack_bits(a: np.ndarray, bits: int) -> np.ndarray:
    """Pack int values along the LAST axis into b-bit fields -> uint8.

    The last axis is padded with zeros to a multiple of 8//bits; output
    last axis is ceil(n * bits / 8) bytes.  Values must lie in the b-bit
    two's-complement range — out-of-range input raises (a silent mask
    would corrupt weights bit-exactness is supposed to catch).
    """
    if bits not in (1, 2, 4):
        raise ValueError(f"pack_bits: bits must be 1, 2 or 4, got {bits}")
    a = np.asarray(a)
    qmin, qmax = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if a.size and (a.min() < qmin or a.max() > qmax):
        raise ValueError(
            f"pack_bits: values outside int{bits} range [{qmin}, {qmax}]: "
            f"[{a.min()}, {a.max()}]")
    ppb = 8 // bits                      # values per byte
    a = pad_to(a.astype(np.int16), a.ndim - 1, ppb)
    u = (a & ((1 << bits) - 1)).astype(np.uint8)
    u = u.reshape(a.shape[:-1] + (a.shape[-1] // ppb, ppb))
    shifts = (np.arange(ppb, dtype=np.uint8) * bits)
    return np.bitwise_or.reduce(u << shifts, axis=-1).astype(np.uint8)


def unpack_bits(packed: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`: uint8 bytes -> n sign-extended int8
    values along the last axis (padding tail dropped)."""
    if bits not in (1, 2, 4):
        raise ValueError(f"unpack_bits: bits must be 1, 2 or 4, got {bits}")
    packed = np.asarray(packed, np.uint8)
    ppb = 8 // bits
    shifts = (np.arange(ppb, dtype=np.uint8) * bits)
    u = ((packed[..., None] >> shifts) & ((1 << bits) - 1)).astype(np.int8)
    sign = np.int8(1 << (bits - 1))
    vals = ((u ^ sign) - sign).reshape(packed.shape[:-1] + (-1,))
    return vals[..., :n].copy()


def pack_wgt_elems(blocked: np.ndarray, bits: int) -> np.ndarray:
    """Blocked weights (..., BLOCK_OUT, BLOCK_IN) int8 -> packed
    (..., BLOCK_OUT*BLOCK_IN*bits//8) uint8 — one packed byte-row per
    tensor-register element (== `spec.wgt_elem_bytes`)."""
    bo, bi = blocked.shape[-2], blocked.shape[-1]
    flat = blocked.reshape(blocked.shape[:-2] + (bo * bi,))
    return pack_bits(flat, bits)


def unpack_wgt_elems(packed: np.ndarray, bits: int,
                     block_out: int, block_in: int) -> np.ndarray:
    """Inverse of :func:`pack_wgt_elems` -> (..., BLOCK_OUT, BLOCK_IN) int8."""
    flat = unpack_bits(packed, bits, block_out * block_in)
    return flat.reshape(packed.shape[:-1] + (block_out, block_in))


# ----------------------------------------------------------------------
# matmul layouts:  A:(M,K) int8,  W:(N,K) int8,  C:(M,N)
# ----------------------------------------------------------------------
def pack_inp(a: np.ndarray, spec: HardwareSpec) -> np.ndarray:
    """(M, K) -> (Mb, Kb, BATCH, BLOCK_IN); element (mb, kb)."""
    a = pad_to(pad_to(np.asarray(a, np.int8), 0, spec.batch), 1, spec.block_in)
    M, K = a.shape
    return (a.reshape(M // spec.batch, spec.batch, K // spec.block_in,
                      spec.block_in)
            .transpose(0, 2, 1, 3).copy())


def pack_wgt(w: np.ndarray, spec: HardwareSpec) -> np.ndarray:
    """(N, K) -> (Nb, Kb, BLOCK_OUT, BLOCK_IN); element (nb, kb)."""
    w = pad_to(pad_to(np.asarray(w, np.int8), 0, spec.block_out), 1, spec.block_in)
    N, K = w.shape
    return (w.reshape(N // spec.block_out, spec.block_out,
                      K // spec.block_in, spec.block_in)
            .transpose(0, 2, 1, 3).copy())


def pack_acc(c: np.ndarray, spec: HardwareSpec) -> np.ndarray:
    """(M, N) int32 -> (Mb, Nb, BATCH, BLOCK_OUT)."""
    c = pad_to(pad_to(np.asarray(c, np.int32), 0, spec.batch), 1, spec.block_out)
    M, N = c.shape
    return (c.reshape(M // spec.batch, spec.batch, N // spec.block_out,
                      spec.block_out)
            .transpose(0, 2, 1, 3).copy())


def unpack_out(blocked: np.ndarray, M: int, N: int, spec: HardwareSpec) -> np.ndarray:
    """(Mb, Nb, BATCH, BLOCK_OUT) -> (M, N)."""
    Mb, Nb = blocked.shape[0], blocked.shape[1]
    full = blocked.transpose(0, 2, 1, 3).reshape(Mb * spec.batch,
                                                 Nb * spec.block_out)
    return full[:M, :N]


# ----------------------------------------------------------------------
# conv2d layouts (NCHW, §2.6 / Fig. 9)
# ----------------------------------------------------------------------
def pack_conv_inp(x: np.ndarray, spec: HardwareSpec) -> np.ndarray:
    """(N, C, H, W) -> (Nb, Cb, H, W, BATCH, BLOCK_IN); element (nb,cb,h,w)."""
    x = pad_to(pad_to(np.asarray(x, np.int8), 0, spec.batch), 1, spec.block_in)
    N, C, H, W = x.shape
    return (x.reshape(N // spec.batch, spec.batch, C // spec.block_in,
                      spec.block_in, H, W)
            .transpose(0, 2, 4, 5, 1, 3).copy())


def pack_conv_wgt(w: np.ndarray, spec: HardwareSpec) -> np.ndarray:
    """(OC, IC, KH, KW) -> (OCb, ICb, KH, KW, BLOCK_OUT, BLOCK_IN)."""
    w = pad_to(pad_to(np.asarray(w, np.int8), 0, spec.block_out), 1, spec.block_in)
    OC, IC, KH, KW = w.shape
    return (w.reshape(OC // spec.block_out, spec.block_out,
                      IC // spec.block_in, spec.block_in, KH, KW)
            .transpose(0, 2, 4, 5, 1, 3).copy())


def unpack_conv_out(blocked: np.ndarray, N: int, OC: int, OH: int, OW: int,
                    spec: HardwareSpec) -> np.ndarray:
    """(Nb, OCb, OH, OW, BATCH, BLOCK_OUT) -> (N, OC, OH, OW)."""
    Nb, OCb = blocked.shape[0], blocked.shape[1]
    full = (blocked.transpose(0, 4, 1, 5, 2, 3)
            .reshape(Nb * spec.batch, OCb * spec.block_out, OH, OW))
    return full[:N, :OC]
