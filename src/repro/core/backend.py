"""Pluggable execution backends: one task-ISA stream, two engines (§3).

The paper's runtime supports *heterogeneous execution*: the identical
binary instruction stream runs on a behavioral simulator or on the FPGA,
and the simulator doubles as the differential-testing oracle for the fast
path.  This module reproduces that split for the jax_pallas port:

  * ``SimulatorBackend`` — the cycle-capable numpy engine
    (``simulator.run_program``), bit-exact oracle semantics;
  * ``PallasBackend``   — interprets the *decoded* task-ISA stream,
    coalescing each virtual-thread tile's LOAD/GEMM/ALU/STORE groups into
    calls to the TPU-native Pallas kernels (``kernels.vta_gemm`` and
    ``kernels.tensor_alu``), honoring the same dependence-token protocol;
  * ``CrossBackendChecker`` — runs one encoded stream on every backend
    against cloned devices and diffs the resulting DRAM images, turning
    the simulator into the oracle for the fast path exactly the way the
    paper checks the FPGA against simulation.

Both engines consume the stream *after* ``IsaLayout.encode_stream`` —
there is no side channel: whatever the scheduler lowered is what runs.

Why sequential interpretation is sound: the runtime emits ``dep_push``
flags on instructions that are already in the stream and attaches each
``dep_pop`` to the next instruction it emits, so every token's producer
precedes its consumer in program order.  Program order also preserves
each module's queue order, hence it is one of the legal executions the
token protocol admits (§2.3) — the PallasBackend verifies this while it
runs and raises ``DeadlockError`` on streams that violate it.

jax / Pallas imports are deferred to PallasBackend execution so that
importing :mod:`repro.core` stays numpy-only.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, Union, \
    runtime_checkable

import numpy as np

from .driver import Device
from .hwspec import HardwareSpec
from .isa import (AluInsn, AluOp, DEP_IN_EDGES, DEP_OUT_EDGES, FinishInsn,
                  GemmInsn, Insn, IsaLayout, LoadStoreInsn, MemId, Opcode,
                  route_queue, LOAD_Q, COMPUTE_Q, STORE_Q)
from .simulator import (DeadlockError, ModuleStats, RunStats, Simulator,
                        TimingModel, replay_timing, run_program,
                        _MODULE_NAMES)


# ----------------------------------------------------------------------
# the backend contract
# ----------------------------------------------------------------------
@runtime_checkable
class ExecutionBackend(Protocol):
    """Anything that can run an encoded VTA instruction stream against a
    device and report RunStats.  ``staged_addr`` (when >= 0 / not None)
    names a pre-staged DRAM copy of the same stream: the engine kicks the
    fetch registers at it instead of re-staging — the serving fast path's
    zero-allocation repeat call."""

    name: str

    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None,
                staged_addr: Optional[int] = None) -> RunStats:
        ...


class SimulatorBackend:
    """The paper's behavioral/cycle-level engine (default)."""

    name = "simulator"

    def __init__(self, timing: Optional[TimingModel] = None):
        self.timing = timing

    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None,
                staged_addr: Optional[int] = None) -> RunStats:
        t0 = time.perf_counter()
        stats = run_program(spec, device, stream,
                            timing=timing or self.timing,
                            staged_addr=staged_addr)
        stats.wall_time_s = time.perf_counter() - t0
        stats.backend = self.name
        return stats


# ----------------------------------------------------------------------
# PallasBackend: decoded-stream interpreter over the Pallas kernels
# ----------------------------------------------------------------------
_ALU_NAMES = {AluOp.MIN: "min", AluOp.MAX: "max", AluOp.ADD: "add",
              AluOp.SHR: "shr", AluOp.MUL: "mul"}

# token FIFO name + dep flag consumed per queue / produced per queue
# (shared with the runtime's static validator)
_IN_EDGES = DEP_IN_EDGES
_OUT_EDGES = DEP_OUT_EDGES

# content-addressed decoded-stream cache (see PallasBackend._decode_cached).
# Shared across backend instances AND serving threads: the pool scheduler
# may decode concurrently with a foreground call, so every access holds
# _DECODE_LOCK (pop+reinsert is not atomic under concurrent eviction).
_DECODE_CACHE: Dict[tuple, List[Insn]] = {}
_DECODE_LOCK = threading.Lock()
# LRU bound on the shared cache: generous by default (a long-lived
# multi-program server holds a handful of streams per program), but
# configurable so it can never grow without limit.  Evictions are
# counted — cumulatively here, per run in RunStats.decode_evictions.
_DECODE_CACHE_CAP = 256
_DECODE_EVICTIONS = 0


def set_decode_cache_cap(cap: int) -> int:
    """Re-bound the process-wide decoded-stream LRU cache at `cap`
    entries (0 disables retention entirely), trimming least-recently-hit
    entries immediately if it is over the new bound.  Returns the number
    of entries trimmed by this call."""
    global _DECODE_CACHE_CAP, _DECODE_EVICTIONS
    if cap < 0:
        raise ValueError(f"decode cache cap must be >= 0, got {cap}")
    trimmed = 0
    with _DECODE_LOCK:
        _DECODE_CACHE_CAP = cap
        while len(_DECODE_CACHE) > cap:
            _DECODE_CACHE.pop(next(iter(_DECODE_CACHE)))
            trimmed += 1
        _DECODE_EVICTIONS += trimmed
    return trimmed


def decode_cache_info() -> Dict[str, int]:
    """Live size / bound / lifetime eviction count of the shared
    decoded-stream cache (ops introspection)."""
    with _DECODE_LOCK:
        return {"size": len(_DECODE_CACHE), "cap": _DECODE_CACHE_CAP,
                "evictions": _DECODE_EVICTIONS}


@dataclass
class _GemmChunk:
    """One coalesced GEMM instruction: the acc-element grid it wrote and a
    snapshot of its operands.  ``grid`` may equal the owning tile's full
    (reset) grid — the blocked-matmul case — or cover a sub-region of it,
    which is the direct-conv structure: one instruction per output row
    ``oh``, each accumulating kh*kw*cbt uops into its row of the tile."""
    grid: np.ndarray                    # (iter_out, iter_in) acc element ids
    a: np.ndarray                       # (io*batch, U*block_in) int8
    w: np.ndarray                       # (ii*block_out, U*block_in) int8


@dataclass
class _PendingTile:
    """A lazily-evaluated accumulator tile: the coalesced record of one
    virtual-thread context's reset + GEMM chunks + ALU epilogue, resolved
    with batched ``vta_gemm`` Pallas calls (plus fused ALU chains) when
    the tile is stored or otherwise observed."""
    grid: np.ndarray                    # canonical (reset) grid of acc ids
    indices: np.ndarray                 # sorted unique ids (overlap queries)
    chunks: List[_GemmChunk] = field(default_factory=list)
    # epilogue: ("imm", op, imm) | ("tensor", op, (R, C) int32 matrix)
    alu_chain: List[tuple] = field(default_factory=list)


@dataclass
class _RunState:
    """Per-execute() interpreter state, passed explicitly so one
    PallasBackend instance can be shared (and re-entered) safely."""
    sim: Simulator                          # SRAM state + eager semantics
    pending: Dict[int, _PendingTile] = field(default_factory=dict)


class PallasBackend:
    """Executes a decoded task-ISA stream through the Pallas kernels.

    LOADs update numpy SRAM state eagerly (DMA semantics are reused from
    the Simulator).  GEMM/ALU instructions whose micro-coded affine index
    pattern matches the blocked-matmul / direct-conv / tile-epilogue
    structure are *coalesced* per accumulator tile and resolved by
    ``vta_gemm`` / ``tensor_alu`` when the tile is stored; anything else
    falls back to the simulator's eager per-instruction semantics, so
    arbitrary valid streams still execute correctly — just without the
    fast path.  ``RunStats.coalesced_*`` / ``eager_*`` count which route
    each compute instruction took (see :func:`assert_fast_path`).

    ``coalesce_subgrids=False`` restricts coalescing to instructions whose
    grid equals the tile's reset grid exactly (the pre-generalization
    behavior, which sent direct-conv schedules to the eager loop) — kept
    as an A/B switch for benchmarks and debugging.  ``batch_tiles=False``
    likewise disables the batched tile dispatch (one kernel launch per
    pending tile, the pre-serving-path behavior).
    """

    name = "pallas"

    #: auto LUT selection: per-tile activation rows at or below this are
    #: "decode-shaped" (weight traffic dominates; the table transform is
    #: cheap) and route to the LUT-GEMM kernel when weights are sub-byte
    LUT_MAX_ROWS = 16

    def __init__(self, interpret: Optional[bool] = None,
                 check_tokens: bool = True,
                 coalesce_subgrids: bool = True,
                 batch_tiles: bool = True,
                 cache_decode: bool = True,
                 use_lut: Optional[bool] = None):
        # interpret=None -> auto (native on TPU, interpreter elsewhere)
        self.interpret = interpret
        self.check_tokens = check_tokens
        self.coalesce_subgrids = coalesce_subgrids
        self.batch_tiles = batch_tiles
        self.cache_decode = cache_decode
        # use_lut: None -> auto (sub-byte weights AND decode-shaped tiles);
        # True forces the LUT kernel for every sub-byte GEMM; False pins
        # the dense kernel (A/B baseline).  int8 specs never use it.
        self.use_lut = use_lut

    def _lut_select(self, spec: HardwareSpec, rows: int) -> bool:
        """Per-shape kernel choice for one GEMM launch group: T-MAC LUT
        lookup vs dense MXU GEMM.  Both are bit-exact; this is purely a
        roofline call, so the fuzzer sweeps it freely."""
        if not spec.wgt_packed or self.use_lut is False:
            return False
        return bool(self.use_lut) or rows <= self.LUT_MAX_ROWS

    # ------------------------------------------------------------------
    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None,
                staged_addr: Optional[int] = None) -> RunStats:
        """Same control handshake as the hardware path: the stream is
        DMA'd to DRAM (or a pre-staged copy at `staged_addr` is kicked —
        zero per-call allocation), the fetch registers are set, and the
        engine runs to FINISH.  With `timing`, the same TimingModel
        cycle-accounting the simulator performs is replayed over the
        decoded stream, so RunStats.total_cycles is meaningful on both
        engines (wall_time_s stays this engine's real clock).

        A single-device execute is a gang of one — every launch-batching
        decision below is shared with :meth:`execute_gang`, so the whole
        test suite exercises the same code path the device pool serves
        through."""
        return self.execute_gang(spec, [device], stream, timing=timing,
                                 staged_addr=staged_addr)[0]

    def execute_gang(self, spec: HardwareSpec, devices: Sequence[Device],
                     stream: np.ndarray,
                     timing: Optional[TimingModel] = None,
                     staged_addr: Optional[int] = None) -> List[RunStats]:
        """Run ONE encoded stream on N devices in lockstep (SPMD over a
        device pool): the stream — hence every scheduling, coalescing and
        materialization decision — is identical across devices; only the
        DRAM data differs.  Each kernel launch therefore batches the
        peer tiles of ALL gang members along the existing vmapped tile
        axis, paying the per-launch dispatch cost once for the pool —
        the sharded batch dispatch that makes pooled serving throughput
        scale with pool size.  Returns one RunStats per device
        (``gang_size`` records the gang width; ``wall_time_s`` is the
        shared gang window, not a per-device slice)."""
        t0 = time.perf_counter()
        isa = IsaLayout(spec)
        if staged_addr is None:
            # per-device staging may land at different addresses; the
            # staged CONTENT is identical, so decode from the first
            addr = [d.stage_stream(stream) for d in devices][0]
        else:
            addr = staged_addr
            for d in devices:
                d.kick_stream(addr, stream.shape[0])
        raw = devices[0].dram.read(
            addr, stream.shape[0] * isa.insn_bytes,
            dtype=np.uint64, shape=(stream.shape[0], isa.insn_words))
        insns, evicted = self._decode_cached(spec, isa, raw)
        statss = self._run_gang(spec, devices, insns)
        wall = time.perf_counter() - t0
        rep = None
        if timing is not None:
            # cycle replay happens OUTSIDE the wall-clock window: the
            # pure-python scheduler pass prices the stream, it is not
            # part of this engine's execution time
            rep = replay_timing(spec, insns, timing)
        for d, stats in zip(devices, statss):
            d.regs.set_done()
            stats.backend = self.name
            stats.wall_time_s = wall
            stats.gang_size = len(devices)
            stats.decode_evictions = evicted
            if rep is not None:
                stats.total_cycles = rep.total_cycles
                for nm, ms in rep.modules.items():
                    stats.modules[nm].busy_cycles = ms.busy_cycles
                    stats.modules[nm].stall_on_token = ms.stall_on_token
        return statss

    def _decode_cached(self, spec: HardwareSpec, isa: IsaLayout,
                       raw: np.ndarray) -> Tuple[List[Insn], int]:
        """Decode the raw stream words, memoized by content digest: a
        serving loop re-running one pre-staged stream pays the (pure
        python) decode exactly once.  Keyed on the bytes actually read
        from DRAM, so there is still no side channel.  Returns
        ``(insns, evicted)`` where `evicted` counts LRU entries this
        call pushed out of the bounded cache (set_decode_cache_cap)."""
        import hashlib
        global _DECODE_EVICTIONS
        if not self.cache_decode:
            return isa.decode_stream(raw), 0
        key = (spec, hashlib.sha1(raw.tobytes()).hexdigest())
        with _DECODE_LOCK:
            hit = _DECODE_CACHE.pop(key, None)
            if hit is not None:
                _DECODE_CACHE[key] = hit   # re-insert: LRU order by last hit
                return hit, 0
        insns = isa.decode_stream(raw)
        evicted = 0
        with _DECODE_LOCK:
            while len(_DECODE_CACHE) >= max(1, _DECODE_CACHE_CAP):
                # evict the least-recently-used entry; hot streams survive
                _DECODE_CACHE.pop(next(iter(_DECODE_CACHE)))
                evicted += 1
            if _DECODE_CACHE_CAP > 0:
                _DECODE_CACHE[key] = insns
            _DECODE_EVICTIONS += evicted
        return insns, evicted

    # ------------------------------------------------------------------
    def _run_gang(self, spec: HardwareSpec, devices: Sequence[Device],
                  insns: List[Insn]) -> List[RunStats]:
        """Interpret one decoded stream against N per-device states in
        lockstep.  Control flow (structure detection, tile bookkeeping,
        materialization triggers) is data-independent — it derives from
        the stream and the uop SRAM, which are identical across the gang
        — so every decision is taken once on state 0 and applied to all;
        only the operand data differs per state.  Invariant: the states'
        ``pending`` dicts stay key-synchronized throughout."""
        states = [_RunState(sim=Simulator(spec, d)) for d in devices]
        statss = [RunStats(modules={n: ModuleStats()
                                    for n in _MODULE_NAMES.values()})
                  for _ in devices]
        tokens = {"l2c": 0, "c2l": 0, "c2s": 0, "s2c": 0}

        for insn in insns:
            q = route_queue(insn)
            if self.check_tokens:
                # token protocol is stream-determined: check once
                for fifo, flag in _IN_EDGES[q]:
                    if getattr(insn.dep, flag):
                        if tokens[fifo] == 0:
                            raise DeadlockError(
                                f"{type(insn).__name__} pops empty dependence"
                                f" FIFO {fifo}: stream is not a legal "
                                f"program-order execution")
                        tokens[fifo] -= 1
            for stats in statss:
                stats.modules[_MODULE_NAMES[q]].insn_count += 1

            if isinstance(insn, FinishInsn):
                pass
            elif isinstance(insn, LoadStoreInsn):
                if insn.opcode == Opcode.STORE:
                    lo = insn.sram_base
                    hi = insn.sram_base + insn.y_size * insn.x_size
                    self._materialize_range(states, lo, hi, statss)
                    for st, stats in zip(states, statss):
                        st.sim._do_store(insn, stats)
                else:
                    if insn.memory_type in (MemId.ACC, MemId.OUT):
                        # both land in tile-owned state: ACC loads overwrite
                        # accumulators, OUT loads overwrite the write-through
                        # mirror a later STORE reads
                        width = insn.x_pad_0 + insn.x_size + insn.x_pad_1
                        rows = insn.y_pad_0 + insn.y_size + insn.y_pad_1
                        self._materialize_range(
                            states, insn.sram_base,
                            insn.sram_base + rows * width, statss)
                    for st, stats in zip(states, statss):
                        st.sim._do_load(insn, stats)
            elif isinstance(insn, GemmInsn):
                self._gemm(states, insn, statss)
            elif isinstance(insn, AluInsn):
                self._alu(states, insn, statss)
            else:
                raise TypeError(type(insn))

            if self.check_tokens:
                for fifo, flag in _OUT_EDGES[q]:
                    if getattr(insn.dep, flag):
                        tokens[fifo] += 1
                        for stats in statss:
                            stats.tokens_pushed += 1

        # a well-formed stream leaves nothing pending, but flush anyway so
        # partial streams (no FINISH/store) still leave coherent SRAM
        if states[0].pending:
            self._materialize_group(states, list(states[0].pending), statss,
                                    batch_peers=False)
        return statss

    # ------------------------------------------------------------------
    # pending-tile bookkeeping
    # ------------------------------------------------------------------
    def _materialize_range(self, states: Sequence[_RunState], lo: int,
                           hi: int, statss: Sequence[RunStats]) -> None:
        st0 = states[0]
        need = []
        for base in list(st0.pending):
            t = st0.pending[base]
            if t.indices[0] < hi and lo <= t.indices[-1]:
                if np.any((t.indices >= lo) & (t.indices < hi)):
                    need.append(base)
        if need:
            # store / ACC-load trigger: peer virtual-thread tiles of the
            # same op are complete here (their epilogues precede the
            # group's first store in program order) — batch them along
            self._materialize_group(states, need, statss, batch_peers=True)

    def _materialize_indices(self, states: Sequence[_RunState],
                             idx: np.ndarray,
                             statss: Sequence[RunStats]) -> None:
        st0 = states[0]
        need = [base for base in list(st0.pending)
                if np.isin(idx, st0.pending[base].indices,
                           assume_unique=False).any()]
        if need:
            # eager-fallback trigger: other pending tiles may still be
            # mid-accumulation, resolve only what is forced
            self._materialize_group(states, need, statss, batch_peers=False)

    def _materialize_group(self, states: Sequence[_RunState],
                           keys: Sequence[int], statss: Sequence[RunStats],
                           batch_peers: bool) -> None:
        """Resolve the pending tiles at `keys` in EVERY gang state —
        plus, with batch_peers, any structurally-identical pending peers
        — grouping same-plan tiles into ONE (vmapped) kernel launch per
        GEMM stage instead of one launch per tile.  With a gang of N the
        launch batches N× the tiles: the per-launch dispatch cost is
        paid once for the pool (sharded batch dispatch)."""
        plan0: Dict[int, tuple] = {}     # state-0 plans, keyed by base
        if batch_peers and self.batch_tiles and states[0].pending:
            # peer sweep decided on state 0 by structural match; the
            # chosen KEYS are popped from every state so the pending
            # dicts stay synchronized.  A peer whose plan key diverges
            # on another state (e.g. coincidentally-equal weight bytes
            # merged there) still resolves correctly — it just lands in
            # its own launch group below.
            sigs, pre_sigs = set(), set()
            for k in keys:
                t = states[0].pending[k]
                if t.chunks:
                    plan0[k] = self._plan_tile(t)
                    sigs.add(self._plan_key(t, plan0[k]))
                    pre_sigs.add(self._pre_key(t))
            peer_keys = []
            if sigs:
                for base in list(states[0].pending):
                    if base in keys:
                        continue
                    peer = states[0].pending[base]
                    if not peer.chunks or self._pre_key(peer) not in pre_sigs:
                        continue
                    plan = self._plan_tile(peer)
                    if self._plan_key(peer, plan) in sigs:
                        peer_keys.append(base)
                        plan0[base] = plan
            keys = list(keys) + peer_keys
        entries: List[Tuple[int, int, _PendingTile]] = \
            [(si, k, st.pending.pop(k))
             for si, st in enumerate(states) for k in keys]
        if not self.batch_tiles:
            for si, _, t in entries:
                self._materialize(states[si], t, statss[si])
            return
        groups: Dict[tuple, List[Tuple[int, _PendingTile, tuple]]] = {}
        for si, k, t in entries:
            if t.chunks:
                plan = plan0[k] if si == 0 and k in plan0 \
                    else self._plan_tile(t)
                groups.setdefault(self._plan_key(t, plan), []).append(
                    (si, t, plan))
            else:
                self._materialize(states[si], t, statss[si])  # reset/ALU-only
        for grp in groups.values():
            tiles_g = [t for _, t, _ in grp]
            plans_g = [p for _, _, p in grp]
            stats_g = [statss[si] for si, _, _ in grp]
            accs = self._resolve_tiles(tiles_g, plans_g, stats_g,
                                       states[0].sim.spec)
            for (si, tile, _), acc in zip(grp, accs):
                self._writeback(states[si], tile, acc, statss[si])

    @staticmethod
    def _overlaps_pending(st: _RunState, idx: np.ndarray) -> bool:
        return any(np.isin(idx, t.indices).any()
                   for t in st.pending.values())

    @staticmethod
    def _decode_structure(insn, uops, dsts, srcs, wgts):
        """Detect the 2-level-affine blocked-matmul index structure:
        dst = f(i0, i1), src = g(i0, u), wgt = h(i1, u) with all dsts
        distinct.  Returns (dst_grid, src_idx, wgt_idx) or None."""
        io, ii, U = insn.iter_out, insn.iter_in, len(uops)
        D = dsts.reshape(io, ii, U)
        S = srcs.reshape(io, ii, U)
        W = wgts.reshape(io, ii, U)
        if not (D == D[:, :, :1]).all():
            return None
        grid = D[:, :, 0]
        if np.unique(grid).size != grid.size:
            return None
        if not (S == S[:, :1, :]).all():
            return None
        if not (W == W[:1, :, :]).all():
            return None
        return grid, S[:, 0, :], W[0, :, :]

    def _find_containing(self, st: _RunState, grid: np.ndarray
                         ) -> Optional[Tuple[int, _PendingTile]]:
        """The pending tile this GEMM accumulates into: an exact grid
        match (blocked matmul / im2col), or — with sub-grid coalescing —
        any tile whose reset region contains every dst id (the direct-conv
        per-output-row structure).  Returns (pending key, tile) so a gang
        caller can fetch the same tile in every peer state."""
        base = int(grid.min())
        tile = st.pending.get(base)
        if tile is not None and tile.grid.shape == grid.shape \
                and (tile.grid == grid).all():
            return base, tile
        if not self.coalesce_subgrids:
            return None
        ids = grid.ravel()
        lo, hi = int(ids.min()), int(ids.max())
        for k, t in st.pending.items():
            if lo >= t.indices[0] and hi <= t.indices[-1] \
                    and np.isin(ids, t.indices).all():
                return k, t
        return None

    # ------------------------------------------------------------------
    # GEMM
    # ------------------------------------------------------------------
    def _gemm(self, states: Sequence[_RunState], insn: GemmInsn,
              statss: Sequence[RunStats]) -> None:
        sim0 = states[0].sim
        uops = sim0.uop_layout.decode_kernel(
            sim0.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        dsts, srcs, wgts = sim0._affine_indices(insn, uops)
        struct = self._decode_structure(insn, uops, dsts, srcs, wgts)
        if struct is None:
            self._materialize_indices(states, np.unique(dsts), statss)
            for st, stats in zip(states, statss):
                st.sim._do_gemm(insn, stats)
                stats.eager_gemm_insns += 1
            return
        grid, src_idx, wgt_idx = struct

        if insn.reset:
            # reset opens a fresh accumulation tile; whatever overlapped
            # before is dead (never observed) for an exact-region match,
            # and must be resolved first otherwise
            base = int(grid.min())
            prev = states[0].pending.get(base)
            if prev is not None and prev.grid.shape == grid.shape \
                    and (prev.grid == grid).all():
                for st in states:
                    del st.pending[base]
            else:
                self._materialize_indices(states, np.unique(grid), statss)
            for st in states:
                st.pending[base] = _PendingTile(
                    grid=grid, indices=np.unique(grid))
            return

        found = self._find_containing(states[0], grid)
        if found is None or found[1].alu_chain:
            # accumulate-onto-existing-values, post-epilogue, or
            # partially-overlapping GEMM: resolve lazies, then run the
            # eager oracle semantics
            self._materialize_indices(states, np.unique(dsts), statss)
            for st, stats in zip(states, statss):
                st.sim._do_gemm(insn, stats)
                stats.eager_gemm_insns += 1
            return
        key = found[0]
        s = sim0.spec
        U = src_idx.shape[1]
        for st, stats in zip(states, statss):
            sim = st.sim
            # snapshot operands NOW: virtual threading will overwrite
            # these SRAM contexts before the tile is stored
            A = sim.inp_sram[src_idx]        # (io, U, batch, block_in)
            Wm = sim.wgt_sram[wgt_idx]       # (ii, U, block_out, block_in)
            A2 = np.ascontiguousarray(
                A.transpose(0, 2, 1, 3).reshape(grid.shape[0] * s.batch,
                                                U * s.block_in))
            W2 = np.ascontiguousarray(
                Wm.transpose(0, 2, 1, 3).reshape(grid.shape[1] * s.block_out,
                                                 U * s.block_in))
            st.pending[key].chunks.append(_GemmChunk(grid=grid, a=A2, w=W2))
            stats.coalesced_gemm_insns += 1
            stats.gemm_macs += (grid.size * U * s.batch
                                * s.block_in * s.block_out)

    # ------------------------------------------------------------------
    # ALU
    # ------------------------------------------------------------------
    def _alu(self, states: Sequence[_RunState], insn: AluInsn,
             statss: Sequence[RunStats]) -> None:
        sim0 = states[0].sim
        uops = sim0.uop_layout.decode_kernel(
            sim0.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        s = sim0.spec
        dsts, srcs, _ = sim0._affine_indices(insn, uops)
        if len(uops) == 1:
            # tile-epilogue shape: one uop, each dst written exactly once;
            # src may be any affine function of the loop indices (the bias
            # add reads a per-column staging row, self ops read dst)
            grid = dsts.reshape(insn.iter_out, insn.iter_in)
            src_grid = srcs.reshape(insn.iter_out, insn.iter_in)
            base = int(grid.min())
            tile0 = states[0].pending.get(base)
            if (tile0 is not None and np.unique(grid).size == grid.size
                    and tile0.grid.shape == grid.shape
                    and (tile0.grid == grid).all()):
                op = _ALU_NAMES[insn.alu_opcode]
                if insn.use_imm:
                    for st, stats in zip(states, statss):
                        st.pending[base].alu_chain.append(
                            ("imm", op, int(insn.imm)))
                        stats.alu_ops += grid.size * s.batch * s.block_out
                        stats.coalesced_alu_insns += 1
                    return
                # tensor-tensor: src must be readable now (eager region)
                if not self._overlaps_pending(states[0],
                                              np.unique(src_grid)):
                    for st, stats in zip(states, statss):
                        src_mat = self._to_matrix(
                            st.sim.acc_sram[src_grid], s)
                        st.pending[base].alu_chain.append(
                            ("tensor", op, src_mat))
                        stats.alu_ops += grid.size * s.batch * s.block_out
                        stats.coalesced_alu_insns += 1
                    return
            # vector-ALU fast path: a dense single-uop op over the *eager*
            # region (no pending lazy tile) — e.g. the chunked
            # schedule_vector_binop stream — resolves through one
            # tensor_alu Pallas call instead of the eager per-row loop
            if (np.unique(grid).size == grid.size
                    and not self._overlaps_pending(states[0],
                                                   np.unique(dsts))
                    and (insn.use_imm
                         or not self._overlaps_pending(states[0],
                                                       np.unique(srcs)))):
                self._alu_eager_region(states, insn, grid, src_grid, statss)
                return
        # fallback: eager semantics on materialized state
        need = np.unique(dsts if insn.use_imm
                         else np.concatenate([dsts, srcs]))
        self._materialize_indices(states, need, statss)
        for st, stats in zip(states, statss):
            st.sim._do_alu(insn, stats)
            stats.eager_alu_insns += 1

    def _alu_eager_region(self, states: Sequence[_RunState], insn: AluInsn,
                          grid: np.ndarray, src_grid: np.ndarray,
                          statss: Sequence[RunStats]) -> None:
        """Run one dense ALU instruction over already-materialized
        accumulator state through the tensor_alu Pallas kernel, keeping the
        §2.5 write-through OUT mirror coherent.  Gang members row-stack
        into a single launch (the region shape is identical across the
        gang; only the data differs)."""
        import jax.numpy as jnp

        from ..kernels.tensor_alu import tensor_alu
        s = states[0].sim.spec
        op = _ALU_NAMES[insn.alu_opcode]
        dst_mats = [self._to_matrix(st.sim.acc_sram[grid], s)
                    for st in states]
        R = dst_mats[0].shape[0]
        big = dst_mats[0] if len(states) == 1 \
            else np.concatenate(dst_mats, axis=0)
        if insn.use_imm:
            out = tensor_alu(jnp.asarray(big),
                             chain=((op, int(insn.imm)),),
                             use_pallas=True, interpret=self.interpret)
        else:
            src_mats = [self._to_matrix(st.sim.acc_sram[src_grid], s)
                        for st in states]
            big_src = src_mats[0] if len(states) == 1 \
                else np.concatenate(src_mats, axis=0)
            out = tensor_alu(jnp.asarray(big), jnp.asarray(big_src),
                             chain=((op, None),),
                             use_pallas=True, interpret=self.interpret)
        out = np.asarray(out, dtype=np.int32)
        io, ii = grid.shape
        touched = np.unique(grid)
        for i, (st, stats) in enumerate(zip(states, statss)):
            sim = st.sim
            sim.acc_sram[grid] = self._from_matrix(
                out[i * R:(i + 1) * R], io, ii, s)
            sim.out_sram[touched] = sim.acc_sram[touched].astype(np.int8)
            stats.alu_ops += grid.size * s.batch * s.block_out
            stats.coalesced_alu_insns += 1

    # ------------------------------------------------------------------
    # tile resolution through the Pallas kernels
    # ------------------------------------------------------------------
    @staticmethod
    def _to_matrix(blocked: np.ndarray, spec: HardwareSpec) -> np.ndarray:
        """(io, ii, batch, block_out) -> (io*batch, ii*block_out)."""
        io, ii = blocked.shape[0], blocked.shape[1]
        return np.ascontiguousarray(
            blocked.transpose(0, 2, 1, 3).reshape(io * spec.batch,
                                                  ii * spec.block_out))

    @staticmethod
    def _from_matrix(mat: np.ndarray, io: int, ii: int,
                     spec: HardwareSpec) -> np.ndarray:
        """(io*batch, ii*block_out) -> (io, ii, batch, block_out)."""
        return (mat.reshape(io, spec.batch, ii, spec.block_out)
                .transpose(0, 2, 1, 3))

    def _materialize(self, st: _RunState, tile: _PendingTile,
                     stats: RunStats) -> None:
        s = st.sim.spec
        io, ii = tile.grid.shape
        R, C = io * s.batch, ii * s.block_out
        if tile.chunks:
            plan = self._plan_tile(tile)
            acc = self._resolve_tiles([tile], [plan], [stats], s)[0]
        elif tile.alu_chain:
            acc = self._alu_chain(np.zeros((R, C), np.int32), tile.alu_chain)
        else:
            acc = np.zeros((R, C), np.int32)
        self._writeback(st, tile, acc, stats)

    def _writeback(self, st: _RunState, tile: _PendingTile, acc: np.ndarray,
                   stats: RunStats) -> None:
        sim = st.sim
        s = sim.spec
        io, ii = tile.grid.shape
        sim.acc_sram[tile.grid] = self._from_matrix(acc, io, ii, s)
        # §2.5 write-through mirror: OUT narrows with a truncating cast
        sim.out_sram[tile.indices] = \
            sim.acc_sram[tile.indices].astype(np.int8)
        stats.tiles_resolved += 1

    @staticmethod
    def _requant_shift(chain: Sequence[tuple]) -> Optional[int]:
        """If the epilogue is exactly [SHR s >= 0,] MAX -128, MIN 127 it is
        the kernel's fused requant epilogue; returns s (0 when no shift)."""
        ops = list(chain)
        shift = 0
        if ops and ops[0][:2] == ("imm", "shr") and ops[0][2] >= 0:
            shift = ops[0][2]
            ops = ops[1:]
        if [o[:3] for o in ops] == [("imm", "max", -128), ("imm", "min", 127)]:
            return shift
        return None

    def _plan_tile(self, tile: _PendingTile):
        """Stage 1+2 of tile resolution (pure bookkeeping, no kernels):
        chunks that accumulated onto the *same* grid (the reduction loop)
        concatenate along K; grids that multiplied the *same* weight tile
        — the direct-conv structure, one instruction per output row —
        row-stack into one GEMM per distinct weight tile.  Returns
        (wgroups, shift): wgroups = [(W, [(grid, A), ...]), ...]; shift is
        the requant shift when the ALU chain fuses into the kernel
        epilogue (chunk grids pairwise disjoint + canonical shr/clip
        chain), else None."""
        merged: List[Tuple[np.ndarray, List[np.ndarray], List[np.ndarray]]] \
            = []
        index: Dict[tuple, int] = {}
        for c in tile.chunks:
            key = (c.grid.shape, c.grid.tobytes())
            if key in index:
                _, As, Ws = merged[index[key]]
                As.append(c.a)
                Ws.append(c.w)
            else:
                index[key] = len(merged)
                merged.append((c.grid, [c.a], [c.w]))
        groups = [(g, np.concatenate(As, axis=1), np.concatenate(Ws, axis=1))
                  for g, As, Ws in merged]

        n_ids = sum(g.size for g, _, _ in groups)
        disjoint = np.unique(
            np.concatenate([g.ravel() for g, _, _ in groups])).size == n_ids
        shift = self._requant_shift(tile.alu_chain) if disjoint else None

        wgroups: List[Tuple[np.ndarray,
                            List[Tuple[np.ndarray, np.ndarray]]]] = []
        windex: Dict[tuple, int] = {}
        for g, A, W in groups:
            key = (W.shape, W.tobytes())
            if key in windex:
                wgroups[windex[key]][1].append((g, A))
            else:
                windex[key] = len(wgroups)
                wgroups.append((W, [(g, A)]))
        return wgroups, shift

    @staticmethod
    def _pre_key(tile: _PendingTile) -> tuple:
        """O(#chunks) structural fingerprint (no data copies) used to
        pre-filter batch-peer candidates before the full plan is built."""
        base = int(tile.indices[0])
        return (tile.grid.shape, (tile.grid - base).tobytes(),
                tuple((c.grid.shape, c.a.shape, c.w.shape)
                      for c in tile.chunks),
                tuple((k, op, x) if k == "imm" else (k, op, x.shape)
                      for k, op, x in tile.alu_chain))

    @staticmethod
    def _plan_key(tile: _PendingTile, plan) -> tuple:
        """Structural signature of a tile's resolution plan.  Tiles with
        equal keys (peer virtual-thread contexts of one op) run the same
        kernel shapes over the same relative index structure and can be
        resolved by ONE vmapped launch per GEMM stage."""
        wgroups, shift = plan
        base = int(tile.indices[0])
        alu_sig = tuple(
            (k, op, x) if k == "imm" else (k, op, x.shape)
            for k, op, x in tile.alu_chain)
        return (shift, tile.grid.shape, (tile.grid - base).tobytes(),
                alu_sig,
                tuple((W.shape,
                       tuple((g.shape, (g - base).tobytes(), A.shape)
                             for g, A in parts))
                      for W, parts in wgroups))

    def _resolve_tiles(self, tiles: Sequence[_PendingTile],
                       plans: Sequence[tuple], statss: Sequence[RunStats],
                       spec: HardwareSpec) -> List[np.ndarray]:
        """Execute structurally-identical tile plans: per GEMM stage the
        tiles' padded operands stack along a leading tile axis and run as
        ONE ``vta_gemm`` launch (``jax.vmap`` over the tile axis; plain
        call when there is a single tile) — cutting per-tile dispatch
        overhead; requant fuses into the kernel epilogue exactly as in
        the per-tile path.  Non-fused ALU chains apply to the row-stacked
        tile batch in one ``tensor_alu`` pass per chain step.  Returns
        one assembled (R, C) int32 accumulator matrix per tile.

        ``statss`` is parallel to ``tiles`` (gang members contribute
        tiles with their own RunStats); each distinct stats object counts
        every launch it participated in exactly once."""
        import functools

        import jax
        import jax.numpy as jnp

        from ..kernels._compat import resolve_interpret
        from ..kernels.lut_gemm.kernel import lut_gemm_pallas
        from ..kernels.vta_gemm.kernel import vta_gemm_pallas
        interpret = resolve_interpret(self.interpret)

        T = len(tiles)
        wgroups0, shift = plans[0]
        results_per_tile: List[List[Tuple[np.ndarray, np.ndarray]]] = \
            [[] for _ in range(T)]
        for wi in range(len(wgroups0)):
            bm = bn = bk = 128
            A_alls: List[np.ndarray] = []
            Ws: List[np.ndarray] = []
            for wgroups, _shift in plans:
                W, parts = wgroups[wi]
                A_all = parts[0][1] if len(parts) == 1 else \
                    np.concatenate([A for _, A in parts], axis=0)
                A_alls.append(A_all)
                Ws.append(W)
            Rg, K = A_alls[0].shape
            Cg = Ws[0].shape[0]
            Rp = -(-Rg // bm) * bm
            Cp = -(-Cg // bn) * bn
            Kp = -(-K // bk) * bk
            kw = dict(interpret=interpret)
            if shift is not None:
                kw.update(epilogue="requant", shift=shift)
            # per-shape kernel choice: sub-byte weights on decode-shaped
            # tiles go through the T-MAC LUT kernel (same operands, same
            # epilogue contract, bit-identical output)
            use_lut = self._lut_select(spec, Rg)

            def gemm_call(Ap, Wp):
                if use_lut:
                    return lut_gemm_pallas(Ap, Wp, bits=spec.wgt_bits, **kw)
                return vta_gemm_pallas(Ap, Wp, **kw)
            # tiles whose weight DATA is identical (gang members serving
            # the same constant weights) can row-concat into one taller
            # GEMM instead of spending a padded vmap lane each — the
            # gang's requests fill the bm-row tile the padding would have
            # wasted.  Choose by padded-row cost; ~64 rows approximates
            # the fixed per-launch dispatch cost of an extra call.
            subgroups: Dict[bytes, List[int]] = {}
            for t, W in enumerate(Ws):
                subgroups.setdefault(W.tobytes(), []).append(t)
            cost_vmap = T * Rp
            cost_concat = sum(-(-(len(g) * Rg) // bm) * bm
                              for g in subgroups.values()) \
                + 64 * (len(subgroups) - 1)
            mats: List[Optional[np.ndarray]] = [None] * T
            if len(subgroups) < T and cost_concat < cost_vmap:
                for g in subgroups.values():
                    Rp2 = -(-(len(g) * Rg) // bm) * bm
                    Ap = np.zeros((Rp2, Kp), np.int8)
                    for j, t in enumerate(g):
                        Ap[j * Rg:(j + 1) * Rg, :K] = A_alls[t]
                    Wp = np.zeros((Kp, Cp), np.int8)
                    Wp[:K, :Cg] = Ws[g[0]].T
                    out = np.asarray(gemm_call(jnp.asarray(Ap),
                                               jnp.asarray(Wp)))
                    for s_ in {id(statss[t]): statss[t] for t in g}.values():
                        s_.tile_batches += 1
                        s_.lut_launches += int(use_lut)
                    for j, t in enumerate(g):
                        mats[t] = out[j * Rg:(j + 1) * Rg,
                                      :Cg].astype(np.int32)
            else:
                Aps, Wps = [], []
                for t in range(T):
                    Ap = np.zeros((Rp, Kp), np.int8)
                    Ap[:Rg, :K] = A_alls[t]
                    Wp = np.zeros((Kp, Cp), np.int8)
                    Wp[:K, :Cg] = Ws[t].T
                    Aps.append(Ap)
                    Wps.append(Wp)
                if T == 1:
                    outs = [gemm_call(jnp.asarray(Aps[0]),
                                      jnp.asarray(Wps[0]))]
                elif use_lut:
                    outs = jax.vmap(functools.partial(
                        lut_gemm_pallas, bits=spec.wgt_bits, **kw))(
                        jnp.asarray(np.stack(Aps)),
                        jnp.asarray(np.stack(Wps)))
                else:
                    outs = jax.vmap(functools.partial(vta_gemm_pallas,
                                                      **kw))(
                        jnp.asarray(np.stack(Aps)),
                        jnp.asarray(np.stack(Wps)))
                for s_ in {id(s_): s_ for s_ in statss}.values():
                    s_.tile_batches += 1
                    s_.lut_launches += int(use_lut)
                outs = np.asarray(outs)
                for t in range(T):
                    mats[t] = outs[t][:Rg, :Cg].astype(np.int32)
            for t in range(T):
                mat = mats[t]
                off = 0
                for g, A in plans[t][0][wi][1]:
                    rows = A.shape[0]
                    results_per_tile[t].append((g, mat[off:off + rows]))
                    off += rows

        accs: List[np.ndarray] = []
        for t, tile in enumerate(tiles):
            results = results_per_tile[t]
            g0, m0 = results[0]
            if len(results) == 1 and g0.shape == tile.grid.shape \
                    and (g0 == tile.grid).all():
                acc = m0
            else:
                acc = self._scatter(results, tile.grid, spec)
            accs.append(acc)
        if shift is None and tiles[0].alu_chain:
            accs = self._alu_chain_batch(accs,
                                         [t.alu_chain for t in tiles])
        return accs

    def _alu_chain_batch(self, accs: List[np.ndarray],
                         chains: Sequence[Sequence[tuple]]
                         ) -> List[np.ndarray]:
        """Apply structurally-identical per-tile ALU chains to the whole
        tile batch in one pass: accumulators row-stack into a single
        matrix, tensor operands (bias rows) stack the same way, and each
        chain step becomes ONE tensor_alu launch for all tiles."""
        T = len(accs)
        if T == 1:
            return [self._alu_chain(accs[0], chains[0])]
        R = accs[0].shape[0]
        x = np.concatenate(accs, axis=0)
        chain: List[tuple] = []
        for i, entry in enumerate(chains[0]):
            if entry[0] == "imm":
                chain.append(entry)
            else:
                chain.append(("tensor", entry[1],
                              np.concatenate([c[i][2] for c in chains],
                                             axis=0)))
        out = self._alu_chain(x, chain)
        return [out[t * R:(t + 1) * R] for t in range(T)]

    def _scatter(self, results: Sequence[Tuple[np.ndarray, np.ndarray]],
                 grid: np.ndarray, spec: HardwareSpec) -> np.ndarray:
        """Accumulate per-group sub-grid results into a matrix in `grid`'s
        orientation (uncovered reset-region elements stay zero)."""
        io, ii = grid.shape
        flat = grid.ravel()
        order = np.argsort(flat)
        acc = np.zeros((grid.size, spec.batch, spec.block_out), np.int32)
        for g, mat in results:
            blocked = self._from_matrix(mat, g.shape[0], g.shape[1], spec) \
                .reshape(-1, spec.batch, spec.block_out)
            pos = order[np.searchsorted(flat, g.ravel(), sorter=order)]
            np.add.at(acc, pos, blocked)
        return self._to_matrix(
            acc.reshape(io, ii, spec.batch, spec.block_out), spec)

    def _alu_chain(self, acc, chain: Sequence[tuple]) -> "np.ndarray":
        """Apply the recorded epilogue; consecutive immediate ops fuse into
        one tensor_alu pass (the §2.5 resource-balance trade).  `acc` may
        be a numpy or on-device array; returns the same shape."""
        import jax.numpy as jnp

        from ..kernels.tensor_alu import tensor_alu
        x = jnp.asarray(acc)
        i = 0
        while i < len(chain):
            if chain[i][0] == "imm":
                j = i
                ops = []
                while j < len(chain) and chain[j][0] == "imm":
                    ops.append((chain[j][1], chain[j][2]))
                    j += 1
                x = tensor_alu(x, chain=tuple(ops), use_pallas=True,
                               interpret=self.interpret)
                i = j
            else:
                _, op, src = chain[i]
                x = tensor_alu(x, jnp.asarray(src), chain=((op, None),),
                               use_pallas=True, interpret=self.interpret)
                i += 1
        return np.asarray(x, dtype=np.int32)


def assert_fast_path(stats: Union[RunStats, Sequence[RunStats]],
                     allow_eager_alu: bool = False) -> None:
    """Assert that a PallasBackend run took zero eager-loop iterations.

    The eager per-uop numpy loop is the correctness net, not the product:
    schedules that are supposed to be on the kernel fast path (matmul,
    direct conv, im2col conv, 1x1-via-GEMM, dense vector ALU) must never
    hit it.  Accepts one RunStats or a sequence (e.g.
    ``CompiledProgram.last_stats``)."""
    all_stats = [stats] if isinstance(stats, RunStats) else list(stats)
    for s in all_stats:
        if s.backend != "pallas":
            continue
        if s.eager_gemm_insns:
            raise AssertionError(
                f"{s.eager_gemm_insns} GEMM instruction(s) fell back to "
                f"the eager loop ({s.coalesced_gemm_insns} coalesced)")
        if s.eager_alu_insns and not allow_eager_alu:
            raise AssertionError(
                f"{s.eager_alu_insns} ALU instruction(s) fell back to "
                f"the eager loop ({s.coalesced_alu_insns} coalesced)")


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
_REGISTRY = {"simulator": SimulatorBackend, "pallas": PallasBackend}

BackendLike = Union[None, str, ExecutionBackend]


def resolve_backend(backend: BackendLike = None) -> ExecutionBackend:
    """None -> SimulatorBackend; a name -> registry lookup; an instance
    passes through unchanged."""
    if backend is None:
        return SimulatorBackend()
    if isinstance(backend, str):
        try:
            return _REGISTRY[backend]()
        except KeyError:
            raise ValueError(f"unknown execution backend {backend!r}; "
                             f"known: {sorted(_REGISTRY)}") from None
    return backend


# ----------------------------------------------------------------------
# differential testing across engines
# ----------------------------------------------------------------------
@dataclass
class BackendRun:
    backend: str
    stats: RunStats
    device: Device


@dataclass
class CrossBackendReport:
    runs: List[BackendRun]
    matches: bool
    mismatched_bytes: int

    def run_for(self, name: str) -> BackendRun:
        for r in self.runs:
            if r.backend == name:
                return r
        raise KeyError(name)

    def device_for(self, name: str) -> Device:
        return self.run_for(name).device

    def stats_for(self, name: str) -> RunStats:
        return self.run_for(name).stats

    def speedup(self, slow: str = "simulator", fast: str = "pallas") -> float:
        return (self.stats_for(slow).wall_time_s
                / max(self.stats_for(fast).wall_time_s, 1e-12))


class CrossBackendChecker:
    """Run one encoded task-ISA stream on several backends against cloned
    devices and diff the resulting DRAM images byte-for-byte — the
    simulator-vs-hardware differential flow of the paper, with the
    simulator as the oracle for the Pallas fast path."""

    def __init__(self, backends: Sequence[BackendLike] = ("simulator",
                                                          "pallas")):
        self.backends = [resolve_backend(b) for b in backends]
        if len(self.backends) < 2:
            raise ValueError("need at least two backends to cross-check")

    def run(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
            timing: Optional[TimingModel] = None) -> CrossBackendReport:
        runs = []
        for b in self.backends:
            dev = device.clone()
            runs.append(BackendRun(b.name, b.execute(spec, dev, stream,
                                                     timing=timing), dev))
        ref = runs[0].device.dram.mem
        mismatched = 0
        for r in runs[1:]:
            mismatched += int(np.count_nonzero(ref != r.device.dram.mem))
        return CrossBackendReport(runs=runs, matches=mismatched == 0,
                                  mismatched_bytes=mismatched)

    def check_runtime(self, rt, timing: Optional[TimingModel] = None,
                      adopt: str = "simulator") -> CrossBackendReport:
        """Finalize `rt`'s pending stream, run it on every backend, then
        adopt the named backend's memory image into rt.device so scheduled
        results remain readable through the usual read_* helpers."""
        stream = rt.finalize_stream()
        report = self.run(rt.spec, rt.device, stream, timing=timing)
        rt.device.copy_from(report.device_for(adopt))
        rt.stats_history.extend(r.stats for r in report.runs)
        rt.reset_stream()
        return report
