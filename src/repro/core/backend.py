"""Pluggable execution backends: one task-ISA stream, two engines (§3).

The paper's runtime supports *heterogeneous execution*: the identical
binary instruction stream runs on a behavioral simulator or on the FPGA,
and the simulator doubles as the differential-testing oracle for the fast
path.  This module reproduces that split for the jax_pallas port:

  * ``SimulatorBackend`` — the cycle-capable numpy engine
    (``simulator.run_program``), bit-exact oracle semantics;
  * ``PallasBackend``   — interprets the *decoded* task-ISA stream,
    coalescing each virtual-thread tile's LOAD/GEMM/ALU/STORE groups into
    calls to the TPU-native Pallas kernels (``kernels.vta_gemm`` and
    ``kernels.tensor_alu``), honoring the same dependence-token protocol;
  * ``CrossBackendChecker`` — runs one encoded stream on every backend
    against cloned devices and diffs the resulting DRAM images, turning
    the simulator into the oracle for the fast path exactly the way the
    paper checks the FPGA against simulation.

Both engines consume the stream *after* ``IsaLayout.encode_stream`` —
there is no side channel: whatever the scheduler lowered is what runs.

Why sequential interpretation is sound: the runtime emits ``dep_push``
flags on instructions that are already in the stream and attaches each
``dep_pop`` to the next instruction it emits, so every token's producer
precedes its consumer in program order.  Program order also preserves
each module's queue order, hence it is one of the legal executions the
token protocol admits (§2.3) — the PallasBackend verifies this while it
runs and raises ``DeadlockError`` on streams that violate it.

jax / Pallas imports are deferred to PallasBackend execution so that
importing :mod:`repro.core` stays numpy-only.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, Union, \
    runtime_checkable

import numpy as np

from .driver import Device
from .hwspec import HardwareSpec
from .isa import (AluInsn, AluOp, DEP_IN_EDGES, DEP_OUT_EDGES, FinishInsn,
                  GemmInsn, Insn, IsaLayout, LoadStoreInsn, MemId, Opcode,
                  route_queue, LOAD_Q, COMPUTE_Q, STORE_Q)
from .simulator import (DeadlockError, ModuleStats, RunStats, Simulator,
                        TimingModel, run_program, _MODULE_NAMES)


# ----------------------------------------------------------------------
# the backend contract
# ----------------------------------------------------------------------
@runtime_checkable
class ExecutionBackend(Protocol):
    """Anything that can run an encoded VTA instruction stream against a
    device and report RunStats."""

    name: str

    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None) -> RunStats:
        ...


class SimulatorBackend:
    """The paper's behavioral/cycle-level engine (default)."""

    name = "simulator"

    def __init__(self, timing: Optional[TimingModel] = None):
        self.timing = timing

    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None) -> RunStats:
        t0 = time.perf_counter()
        stats = run_program(spec, device, stream, timing=timing or self.timing)
        stats.wall_time_s = time.perf_counter() - t0
        stats.backend = self.name
        return stats


# ----------------------------------------------------------------------
# PallasBackend: decoded-stream interpreter over the Pallas kernels
# ----------------------------------------------------------------------
_ALU_NAMES = {AluOp.MIN: "min", AluOp.MAX: "max", AluOp.ADD: "add",
              AluOp.SHR: "shr", AluOp.MUL: "mul"}

# token FIFO name + dep flag consumed per queue / produced per queue
# (shared with the runtime's static validator)
_IN_EDGES = DEP_IN_EDGES
_OUT_EDGES = DEP_OUT_EDGES


@dataclass
class _PendingTile:
    """A lazily-evaluated accumulator tile: the coalesced record of one
    virtual-thread context's reset + GEMM chunks + ALU epilogue, resolved
    with one ``vta_gemm`` Pallas call (plus fused ALU chains) when the
    tile is stored or otherwise observed."""
    grid: np.ndarray                    # (iter_out, iter_in) acc element ids
    indices: np.ndarray                 # sorted unique ids (overlap queries)
    # snapshot GEMM operands: list of (A2 (R, k) int8, W2 (C, k) int8)
    chunks: List[Tuple[np.ndarray, np.ndarray]] = field(default_factory=list)
    # epilogue: ("imm", op, imm) | ("tensor", op, (R, C) int32 matrix)
    alu_chain: List[tuple] = field(default_factory=list)


@dataclass
class _RunState:
    """Per-execute() interpreter state, passed explicitly so one
    PallasBackend instance can be shared (and re-entered) safely."""
    sim: Simulator                          # SRAM state + eager semantics
    pending: Dict[int, _PendingTile] = field(default_factory=dict)


class PallasBackend:
    """Executes a decoded task-ISA stream through the Pallas kernels.

    LOADs update numpy SRAM state eagerly (DMA semantics are reused from
    the Simulator).  GEMM/ALU instructions whose micro-coded affine index
    pattern matches the blocked-matmul / tile-epilogue structure are
    *coalesced* per accumulator tile and resolved by ``vta_gemm`` /
    ``tensor_alu`` when the tile is stored; anything else falls back to
    the simulator's eager per-instruction semantics, so arbitrary valid
    streams still execute correctly — just without the fast path.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None,
                 check_tokens: bool = True):
        # interpret=None -> auto (native on TPU, interpreter elsewhere)
        self.interpret = interpret
        self.check_tokens = check_tokens

    # ------------------------------------------------------------------
    def execute(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
                timing: Optional[TimingModel] = None) -> RunStats:
        """Same control handshake as the hardware path: the stream is
        DMA'd to DRAM, the fetch registers are kicked, and the engine
        runs to FINISH.  `timing` is accepted for interface parity but
        ignored — this engine reports wall-clock, not cycles."""
        t0 = time.perf_counter()
        isa = IsaLayout(spec)
        addr = device.stage_stream(stream)
        raw = device.dram.read(
            addr, stream.shape[0] * isa.insn_bytes,
            dtype=np.uint64, shape=(stream.shape[0], isa.insn_words))
        stats = self._run(spec, device, isa.decode_stream(raw))
        device.regs.set_done()
        stats.backend = self.name
        stats.wall_time_s = time.perf_counter() - t0
        return stats

    # ------------------------------------------------------------------
    def _run(self, spec: HardwareSpec, device: Device,
             insns: List[Insn]) -> RunStats:
        st = _RunState(sim=Simulator(spec, device))
        sim = st.sim
        stats = RunStats(modules={n: ModuleStats()
                                  for n in _MODULE_NAMES.values()})
        tokens = {"l2c": 0, "c2l": 0, "c2s": 0, "s2c": 0}

        for insn in insns:
            q = route_queue(insn)
            if self.check_tokens:
                for fifo, flag in _IN_EDGES[q]:
                    if getattr(insn.dep, flag):
                        if tokens[fifo] == 0:
                            raise DeadlockError(
                                f"{type(insn).__name__} pops empty dependence"
                                f" FIFO {fifo}: stream is not a legal "
                                f"program-order execution")
                        tokens[fifo] -= 1
            mstats = stats.modules[_MODULE_NAMES[q]]
            mstats.insn_count += 1

            if isinstance(insn, FinishInsn):
                pass
            elif isinstance(insn, LoadStoreInsn):
                if insn.opcode == Opcode.STORE:
                    lo = insn.sram_base
                    hi = insn.sram_base + insn.y_size * insn.x_size
                    self._materialize_range(st, lo, hi, stats)
                    sim._do_store(insn, stats)
                else:
                    if insn.memory_type in (MemId.ACC, MemId.OUT):
                        # both land in tile-owned state: ACC loads overwrite
                        # accumulators, OUT loads overwrite the write-through
                        # mirror a later STORE reads
                        width = insn.x_pad_0 + insn.x_size + insn.x_pad_1
                        rows = insn.y_pad_0 + insn.y_size + insn.y_pad_1
                        self._materialize_range(
                            st, insn.sram_base, insn.sram_base + rows * width,
                            stats)
                    sim._do_load(insn, stats)
            elif isinstance(insn, GemmInsn):
                self._gemm(st, insn, stats)
            elif isinstance(insn, AluInsn):
                self._alu(st, insn, stats)
            else:
                raise TypeError(type(insn))

            if self.check_tokens:
                for fifo, flag in _OUT_EDGES[q]:
                    if getattr(insn.dep, flag):
                        tokens[fifo] += 1
                        stats.tokens_pushed += 1

        # a well-formed stream leaves nothing pending, but flush anyway so
        # partial streams (no FINISH/store) still leave coherent SRAM
        for base in list(st.pending):
            self._materialize(st, st.pending[base], stats)
            del st.pending[base]
        return stats

    # ------------------------------------------------------------------
    # pending-tile bookkeeping
    # ------------------------------------------------------------------
    def _materialize_range(self, st: _RunState, lo: int, hi: int,
                           stats: RunStats) -> None:
        for base in list(st.pending):
            t = st.pending[base]
            if t.indices[0] < hi and lo <= t.indices[-1]:
                if np.any((t.indices >= lo) & (t.indices < hi)):
                    self._materialize(st, t, stats)
                    del st.pending[base]

    def _materialize_indices(self, st: _RunState, idx: np.ndarray,
                             stats: RunStats) -> None:
        for base in list(st.pending):
            t = st.pending[base]
            if np.isin(idx, t.indices, assume_unique=False).any():
                self._materialize(st, t, stats)
                del st.pending[base]

    @staticmethod
    def _overlaps_pending(st: _RunState, idx: np.ndarray) -> bool:
        return any(np.isin(idx, t.indices).any()
                   for t in st.pending.values())

    @staticmethod
    def _decode_structure(insn, uops, dsts, srcs, wgts):
        """Detect the 2-level-affine blocked-matmul index structure:
        dst = f(i0, i1), src = g(i0, u), wgt = h(i1, u) with all dsts
        distinct.  Returns (dst_grid, src_idx, wgt_idx) or None."""
        io, ii, U = insn.iter_out, insn.iter_in, len(uops)
        D = dsts.reshape(io, ii, U)
        S = srcs.reshape(io, ii, U)
        W = wgts.reshape(io, ii, U)
        if not (D == D[:, :, :1]).all():
            return None
        grid = D[:, :, 0]
        if np.unique(grid).size != grid.size:
            return None
        if not (S == S[:, :1, :]).all():
            return None
        if not (W == W[:1, :, :]).all():
            return None
        return grid, S[:, 0, :], W[0, :, :]

    # ------------------------------------------------------------------
    # GEMM
    # ------------------------------------------------------------------
    def _gemm(self, st: _RunState, insn: GemmInsn, stats: RunStats) -> None:
        sim = st.sim
        uops = sim.uop_layout.decode_kernel(
            sim.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        dsts, srcs, wgts = sim._affine_indices(insn, uops)
        struct = self._decode_structure(insn, uops, dsts, srcs, wgts)
        if struct is None:
            self._materialize_indices(st, np.unique(dsts), stats)
            sim._do_gemm(insn, stats)
            return
        grid, src_idx, wgt_idx = struct

        if insn.reset:
            # reset opens a fresh accumulation tile; whatever overlapped
            # before is dead (never observed) for an exact-region match,
            # and must be resolved first otherwise
            base = int(grid.min())
            prev = st.pending.get(base)
            if prev is not None and prev.grid.shape == grid.shape \
                    and (prev.grid == grid).all():
                del st.pending[base]
            else:
                self._materialize_indices(st, np.unique(grid), stats)
            st.pending[base] = _PendingTile(
                grid=grid, indices=np.unique(grid))
            return

        base = int(grid.min())
        tile = st.pending.get(base)
        if (tile is None or tile.alu_chain
                or tile.grid.shape != grid.shape
                or not (tile.grid == grid).all()):
            # accumulate-onto-existing-values (or post-epilogue) GEMM:
            # resolve lazies, then run the eager oracle semantics
            self._materialize_indices(st, np.unique(dsts), stats)
            sim._do_gemm(insn, stats)
            return
        # snapshot operands NOW: virtual threading will overwrite these
        # SRAM contexts before the tile is stored
        s = sim.spec
        U = src_idx.shape[1]
        A = sim.inp_sram[src_idx]            # (io, U, batch, block_in)
        Wm = sim.wgt_sram[wgt_idx]           # (ii, U, block_out, block_in)
        A2 = np.ascontiguousarray(
            A.transpose(0, 2, 1, 3).reshape(grid.shape[0] * s.batch,
                                            U * s.block_in))
        W2 = np.ascontiguousarray(
            Wm.transpose(0, 2, 1, 3).reshape(grid.shape[1] * s.block_out,
                                             U * s.block_in))
        tile.chunks.append((A2, W2))
        stats.gemm_macs += (grid.size * U * s.batch
                            * s.block_in * s.block_out)

    # ------------------------------------------------------------------
    # ALU
    # ------------------------------------------------------------------
    def _alu(self, st: _RunState, insn: AluInsn, stats: RunStats) -> None:
        sim = st.sim
        uops = sim.uop_layout.decode_kernel(
            sim.uop_sram[insn.uop_bgn:insn.uop_end])
        if not uops or insn.iter_out == 0 or insn.iter_in == 0:
            return
        s = sim.spec
        dsts, srcs, _ = sim._affine_indices(insn, uops)
        if len(uops) == 1:
            # tile-epilogue shape: one uop, each dst written exactly once;
            # src may be any affine function of the loop indices (the bias
            # add reads a per-column staging row, self ops read dst)
            grid = dsts.reshape(insn.iter_out, insn.iter_in)
            src_grid = srcs.reshape(insn.iter_out, insn.iter_in)
            tile = st.pending.get(int(grid.min()))
            if (tile is not None and np.unique(grid).size == grid.size
                    and tile.grid.shape == grid.shape
                    and (tile.grid == grid).all()):
                op = _ALU_NAMES[insn.alu_opcode]
                if insn.use_imm:
                    tile.alu_chain.append(("imm", op, int(insn.imm)))
                    stats.alu_ops += grid.size * s.batch * s.block_out
                    return
                # tensor-tensor: src must be readable now (eager region)
                if not self._overlaps_pending(st, np.unique(src_grid)):
                    src_mat = self._to_matrix(sim.acc_sram[src_grid], s)
                    tile.alu_chain.append(("tensor", op, src_mat))
                    stats.alu_ops += grid.size * s.batch * s.block_out
                    return
            # vector-ALU fast path: a dense single-uop op over the *eager*
            # region (no pending lazy tile) — e.g. the chunked
            # schedule_vector_binop stream — resolves through one
            # tensor_alu Pallas call instead of the eager per-row loop
            if (np.unique(grid).size == grid.size
                    and not self._overlaps_pending(st, np.unique(dsts))
                    and (insn.use_imm
                         or not self._overlaps_pending(st,
                                                      np.unique(srcs)))):
                self._alu_eager_region(st, insn, grid, src_grid, stats)
                return
        # fallback: eager semantics on materialized state
        need = np.unique(dsts if insn.use_imm
                         else np.concatenate([dsts, srcs]))
        self._materialize_indices(st, need, stats)
        sim._do_alu(insn, stats)

    def _alu_eager_region(self, st: _RunState, insn: AluInsn,
                          grid: np.ndarray, src_grid: np.ndarray,
                          stats: RunStats) -> None:
        """Run one dense ALU instruction over already-materialized
        accumulator state through the tensor_alu Pallas kernel, keeping the
        §2.5 write-through OUT mirror coherent."""
        import jax.numpy as jnp

        from ..kernels.tensor_alu import tensor_alu
        sim = st.sim
        s = sim.spec
        op = _ALU_NAMES[insn.alu_opcode]
        dst_mat = self._to_matrix(sim.acc_sram[grid], s)
        if insn.use_imm:
            out = tensor_alu(jnp.asarray(dst_mat),
                             chain=((op, int(insn.imm)),),
                             use_pallas=True, interpret=self.interpret)
        else:
            src_mat = self._to_matrix(sim.acc_sram[src_grid], s)
            out = tensor_alu(jnp.asarray(dst_mat), jnp.asarray(src_mat),
                             chain=((op, None),),
                             use_pallas=True, interpret=self.interpret)
        io, ii = grid.shape
        sim.acc_sram[grid] = self._from_matrix(
            np.asarray(out, dtype=np.int32), io, ii, s)
        touched = np.unique(grid)
        sim.out_sram[touched] = sim.acc_sram[touched].astype(np.int8)
        stats.alu_ops += grid.size * s.batch * s.block_out

    # ------------------------------------------------------------------
    # tile resolution through the Pallas kernels
    # ------------------------------------------------------------------
    @staticmethod
    def _to_matrix(blocked: np.ndarray, spec: HardwareSpec) -> np.ndarray:
        """(io, ii, batch, block_out) -> (io*batch, ii*block_out)."""
        io, ii = blocked.shape[0], blocked.shape[1]
        return np.ascontiguousarray(
            blocked.transpose(0, 2, 1, 3).reshape(io * spec.batch,
                                                  ii * spec.block_out))

    @staticmethod
    def _from_matrix(mat: np.ndarray, io: int, ii: int,
                     spec: HardwareSpec) -> np.ndarray:
        """(io*batch, ii*block_out) -> (io, ii, batch, block_out)."""
        return (mat.reshape(io, spec.batch, ii, spec.block_out)
                .transpose(0, 2, 1, 3))

    def _materialize(self, st: _RunState, tile: _PendingTile,
                     stats: RunStats) -> None:
        sim = st.sim
        s = sim.spec
        io, ii = tile.grid.shape
        R, C = io * s.batch, ii * s.block_out
        if tile.chunks:
            acc = self._resolve_tile(tile, R, C)
        elif tile.alu_chain:
            acc = self._alu_chain(np.zeros((R, C), np.int32), tile.alu_chain)
        else:
            acc = np.zeros((R, C), np.int32)
        sim.acc_sram[tile.grid] = self._from_matrix(acc, io, ii, s)
        # §2.5 write-through mirror: OUT narrows with a truncating cast
        sim.out_sram[tile.indices] = \
            sim.acc_sram[tile.indices].astype(np.int8)

    @staticmethod
    def _requant_shift(chain: Sequence[tuple]) -> Optional[int]:
        """If the epilogue is exactly [SHR s >= 0,] MAX -128, MIN 127 it is
        the kernel's fused requant epilogue; returns s (0 when no shift)."""
        ops = list(chain)
        shift = 0
        if ops and ops[0][:2] == ("imm", "shr") and ops[0][2] >= 0:
            shift = ops[0][2]
            ops = ops[1:]
        if [o[:3] for o in ops] == [("imm", "max", -128), ("imm", "min", 127)]:
            return shift
        return None

    def _resolve_tile(self, tile: _PendingTile, R: int, C: int) -> np.ndarray:
        """One Pallas pipeline per tile: the concatenated-K GEMM, with the
        ALU chain either fused into the kernel's requant epilogue (the
        canonical shift+clip case) or chained on-device; a single host
        transfer at the end."""
        import jax.numpy as jnp

        from ..kernels._compat import resolve_interpret
        from ..kernels.vta_gemm.kernel import vta_gemm_pallas
        interpret = resolve_interpret(self.interpret)

        A = np.concatenate([a for a, _ in tile.chunks], axis=1)
        W2 = np.concatenate([w for _, w in tile.chunks], axis=1)
        K = A.shape[1]
        bm = bn = bk = 128
        Rp, Cp, Kp = -(-R // bm) * bm, -(-C // bn) * bn, -(-K // bk) * bk
        Ap = np.zeros((Rp, Kp), np.int8)
        Ap[:R, :K] = A
        Wp = np.zeros((Kp, Cp), np.int8)
        Wp[:K, :C] = W2.T

        shift = self._requant_shift(tile.alu_chain)
        if shift is not None:
            out = vta_gemm_pallas(jnp.asarray(Ap), jnp.asarray(Wp),
                                  epilogue="requant", shift=shift,
                                  interpret=interpret)
            return np.asarray(out)[:R, :C].astype(np.int32)
        acc = vta_gemm_pallas(jnp.asarray(Ap), jnp.asarray(Wp),
                              interpret=interpret)
        if tile.alu_chain:
            # padded rows/cols carry garbage through the chain; sliced off
            acc = self._alu_chain(acc, tile.alu_chain, pad_to=(Rp, Cp))
        return np.asarray(acc)[:R, :C]

    def _alu_chain(self, acc, chain: Sequence[tuple],
                   pad_to: Optional[Tuple[int, int]] = None) -> "np.ndarray":
        """Apply the recorded epilogue; consecutive immediate ops fuse into
        one tensor_alu pass (the §2.5 resource-balance trade).  `acc` may
        be a numpy or on-device array; returns the same (padded) shape."""
        import jax.numpy as jnp

        from ..kernels.tensor_alu import tensor_alu
        x = jnp.asarray(acc)
        i = 0
        while i < len(chain):
            if chain[i][0] == "imm":
                j = i
                ops = []
                while j < len(chain) and chain[j][0] == "imm":
                    ops.append((chain[j][1], chain[j][2]))
                    j += 1
                x = tensor_alu(x, chain=tuple(ops), use_pallas=True,
                               interpret=self.interpret)
                i = j
            else:
                _, op, src = chain[i]
                if pad_to is not None and src.shape != tuple(pad_to):
                    padded = np.zeros(pad_to, np.int32)
                    padded[:src.shape[0], :src.shape[1]] = src
                    src = padded
                x = tensor_alu(x, jnp.asarray(src), chain=((op, None),),
                               use_pallas=True, interpret=self.interpret)
                i += 1
        return np.asarray(x, dtype=np.int32)


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
_REGISTRY = {"simulator": SimulatorBackend, "pallas": PallasBackend}

BackendLike = Union[None, str, ExecutionBackend]


def resolve_backend(backend: BackendLike = None) -> ExecutionBackend:
    """None -> SimulatorBackend; a name -> registry lookup; an instance
    passes through unchanged."""
    if backend is None:
        return SimulatorBackend()
    if isinstance(backend, str):
        try:
            return _REGISTRY[backend]()
        except KeyError:
            raise ValueError(f"unknown execution backend {backend!r}; "
                             f"known: {sorted(_REGISTRY)}") from None
    return backend


# ----------------------------------------------------------------------
# differential testing across engines
# ----------------------------------------------------------------------
@dataclass
class BackendRun:
    backend: str
    stats: RunStats
    device: Device


@dataclass
class CrossBackendReport:
    runs: List[BackendRun]
    matches: bool
    mismatched_bytes: int

    def run_for(self, name: str) -> BackendRun:
        for r in self.runs:
            if r.backend == name:
                return r
        raise KeyError(name)

    def device_for(self, name: str) -> Device:
        return self.run_for(name).device

    def stats_for(self, name: str) -> RunStats:
        return self.run_for(name).stats

    def speedup(self, slow: str = "simulator", fast: str = "pallas") -> float:
        return (self.stats_for(slow).wall_time_s
                / max(self.stats_for(fast).wall_time_s, 1e-12))


class CrossBackendChecker:
    """Run one encoded task-ISA stream on several backends against cloned
    devices and diff the resulting DRAM images byte-for-byte — the
    simulator-vs-hardware differential flow of the paper, with the
    simulator as the oracle for the Pallas fast path."""

    def __init__(self, backends: Sequence[BackendLike] = ("simulator",
                                                          "pallas")):
        self.backends = [resolve_backend(b) for b in backends]
        if len(self.backends) < 2:
            raise ValueError("need at least two backends to cross-check")

    def run(self, spec: HardwareSpec, device: Device, stream: np.ndarray,
            timing: Optional[TimingModel] = None) -> CrossBackendReport:
        runs = []
        for b in self.backends:
            dev = device.clone()
            runs.append(BackendRun(b.name, b.execute(spec, dev, stream,
                                                     timing=timing), dev))
        ref = runs[0].device.dram.mem
        mismatched = 0
        for r in runs[1:]:
            mismatched += int(np.count_nonzero(ref != r.device.dram.mem))
        return CrossBackendReport(runs=runs, matches=mismatched == 0,
                                  mismatched_bytes=mismatched)

    def check_runtime(self, rt, timing: Optional[TimingModel] = None,
                      adopt: str = "simulator") -> CrossBackendReport:
        """Finalize `rt`'s pending stream, run it on every backend, then
        adopt the named backend's memory image into rt.device so scheduled
        results remain readable through the usual read_* helpers."""
        stream = rt.finalize_stream()
        report = self.run(rt.spec, rt.device, stream, timing=timing)
        rt.device.copy_from(report.device_for(adopt))
        rt.stats_history.extend(r.stats for r in report.runs)
        rt.reset_stream()
        return report
