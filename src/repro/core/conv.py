"""2D convolution lowered onto VTA (§2.6, Fig. 9, §4.2).

Three lowering modes, selected per shape by :func:`select_conv_lowering`
and surfaced as an inspectable scheduling decision on
``CompiledProgram.describe()``:

``direct`` (default, any shape) — tensorizes NCHW conv2d onto the GEMM
intrinsic *without* im2col anywhere: the load module's 2D strided DMA
inserts spatial zero-padding on the fly, and the micro-op kernel's
2-level affine loop walks (kh, kw, icb) — the access-pattern compression
the paper describes in §2.5.  Emits one GEMM instruction per output row,
which the PallasBackend coalescer row-stacks into one batched ``vta_gemm``
call per tile (the direct-conv fast path).

``via_matmul`` (kh=kw=1, stride=1, pad=0) — pointwise convs consume the
blocked NCHW plane *in place* as a K-major matrix through ``lower_matmul``
transposed mode; works for batch-blocked template instances too (each
image block is one transposed matmul; ``tpu_like()``-style specs included).

``im2col`` (stride=1) — builds the im2col matrix *in SRAM* with
one 2D padded DMA per (icb, kh, kw) gather row, then runs the pure
transposed-GEMM schedule over it: a single coalescable GEMM instruction
per tile instead of one per output row.  Trades kh*kw-fold inp-SRAM
duplication (the §2.5 argument for the direct schedule) for the smallest
possible instruction stream — profitable when a shape is uop-cache- or
insn-issue-bound.

Selection rules (``select_conv_lowering``): auto picks ``via_matmul`` for
eligible pointwise shapes (structural 1:1 mapping); for every other
stride-1 shape the choice between ``direct`` and ``im2col`` comes from
REPLAYED CYCLES — each candidate is lowered into a scratch stream and
priced on the calibrated TimingModel (:func:`predict_conv_cycles`), the
cheaper one wins.  Strided shapes take ``direct`` (im2col's gather rows
must be DMA-contiguous).  Explicit requests are validated and constraint
violations raise at graph-build time with the legal alternatives in the
message.

Direct-schedule SRAM layouts per virtual-thread context:
  inp  tile: (cbt, iht, IWp)    idx = (cb*iht + ih)*IWp + iw
  wgt  tile: (ocbt, cbt*KH*KW)  idx = ocb*cbt*KH*KW + (cb*KH+kh)*KW + kw
  acc  tile: (ocbt, oht, OW)    idx = (ocb*oht + oh)*OW + ow     (+ bias slot)

One GEMM instruction per output row `oh_l`:
  i0 = ow   (extent OW,   dst*1,        src*S,  wgt*0)
  i1 = ocb  (extent ocbt, dst*oht*OW,   src*0,  wgt*cbt*KH*KW)
  uops enumerate (cb, kh, kw).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from . import layout
from .hwspec import HardwareSpec
from .isa import AluOp, IsaLayout, MemId
from .runtime import Runtime, UopBuilder, UopKernel
from .scheduler import (Epilogue, SramPartition, _ceil_div, _ThreadDeps,
                        emit_fenced_load_group, interleave_virtual_threads,
                        lower_matmul)


@dataclass(frozen=True)
class ConvShape:
    """One conv2d workload (Table 1 row)."""
    n: int
    h: int
    w: int
    ic: int
    oc: int
    kh: int
    kw: int
    stride: int
    pad: int

    @property
    def oh(self) -> int:
        return (self.h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def ow(self) -> int:
        return (self.w + 2 * self.pad - self.kw) // self.stride + 1

    @property
    def macs(self) -> int:
        return self.n * self.oc * self.oh * self.ow * self.ic * self.kh * self.kw

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / 1e9

    def dram_bytes(self, spec: HardwareSpec) -> int:
        """Minimum DRAM traffic (one pass over each tensor, int8/int32)."""
        inp = self.n * self.ic * self.h * self.w
        wgt = self.oc * self.ic * self.kh * self.kw
        out = self.n * self.oc * self.oh * self.ow
        return inp + wgt + out

    @property
    def arithmetic_intensity(self) -> float:
        return 2.0 * self.macs / self.dram_bytes(HardwareSpec())


@dataclass
class ConvPlan:
    shape: ConvShape
    tiles: Tuple[int, int, int]      # (oht, ocbt, cbt)
    x_addr: int
    w_addr: int
    y_addr: int
    Nb: int
    Cb: int
    OCb: int
    mode: str = "direct"             # which lowering produced the stream


def choose_conv_tiles(shape: ConvShape, spec: HardwareSpec,
                      virtual_threads: int, bias: bool,
                      sram: Optional[SramPartition] = None
                      ) -> Tuple[int, int, int]:
    sram = sram or SramPartition.full(spec)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    IWp = shape.w + 2 * shape.pad
    inp_cap = sram.inp_depth // virtual_threads
    wgt_cap = sram.wgt_depth // virtual_threads
    acc_cap = sram.acc_depth // virtual_threads

    def fits(oht, ocbt, cbt):
        iht = (oht - 1) * shape.stride + shape.kh
        a = oht * shape.ow * ocbt + (ocbt if bias else 0)
        return (cbt * iht * IWp <= inp_cap
                and ocbt * cbt * shape.kh * shape.kw <= wgt_cap
                and a <= acc_cap)

    oht, ocbt, cbt = 1, 1, 1
    if not fits(1, 1, 1):
        raise ValueError(
            f"conv tile (1,1,1) does not fit SRAM for {shape} "
            f"(inp needs {shape.kh * IWp} of {inp_cap}) — offload to CPU")
    changed = True
    while changed:
        changed = False
        for grow in ("cbt", "ocbt", "oht"):
            o2, c2, b2 = oht, ocbt, cbt
            if grow == "cbt" and cbt < Cb:
                b2 = min(Cb, cbt * 2)
            elif grow == "ocbt" and ocbt < OCb:
                c2 = min(OCb, ocbt * 2)
            elif grow == "oht" and oht < shape.oh:
                o2 = min(shape.oh, oht * 2)
            if (o2, c2, b2) != (oht, ocbt, cbt) and fits(o2, c2, b2):
                oht, ocbt, cbt = o2, c2, b2
                changed = True
    return oht, ocbt, cbt


def lower_conv2d(rt: Runtime, *, x_base: int, w_base: int, y_base: int,
                 shape: ConvShape, epilogue: Optional[Epilogue] = None,
                 bias_base: int = -1, virtual_threads: int = 2,
                 sram: Optional[SramPartition] = None,
                 fenced: bool = False) -> Tuple[int, int, int]:
    """Emit the direct-conv schedule into rt's open stream (element
    addresses of already-staged blocked buffers, like ``lower_matmul``).
    ``fenced`` claims a preceding ``buffer_fence`` token on the first x
    load, after free-running the first weight tile (see ``lower_matmul``).
    Returns the chosen (oht, ocbt, cbt) tiles."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    if (ep.bias_blocked is not None) != (bias_base >= 0):
        raise ValueError("epilogue.bias_blocked and bias_base must agree")
    sram = sram or SramPartition.full(spec)
    S, KH, KW, pad = shape.stride, shape.kh, shape.kw, shape.pad
    OH, OW = shape.oh, shape.ow
    IWp = shape.w + 2 * pad
    H, W = shape.h, shape.w
    Nb = _ceil_div(shape.n, spec.batch)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    b_base = bias_base

    vt = virtual_threads
    oht, ocbt, cbt = choose_conv_tiles(shape, spec, vt,
                                       ep.bias_blocked is not None, sram=sram)
    iht = (oht - 1) * S + KH
    inp_ctx = sram.inp_depth // vt
    wgt_ctx = sram.wgt_depth // vt
    acc_ctx = sram.acc_depth // vt
    deps = [_ThreadDeps() for _ in range(vt)]

    def gemm_kernel(oh_l, cbt_c, ocbt_c, acc_base, inp_base, wgt_base) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(OW, dst_factor=1, src_factor=S, wgt_factor=0)
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=0,
                         wgt_factor=cbt_c * KH * KW)
            for cb in range(cbt_c):
                for kh in range(KH):
                    for kw in range(KW):
                        b.push(dst=acc_base + oh_l * OW,
                               src=inp_base + (cb * iht + oh_l * S + kh) * IWp + kw,
                               wgt=wgt_base + (cb * KH + kh) * KW + kw)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build, key=f"cv.{shape}.{oh_l}.{cbt_c}.{ocbt_c}.{acc_base}.{inp_base}.{wgt_base}")

    def reset_kernel(ocbt_c, oht_c, acc_base) -> UopKernel:
        # note: the ocb stride in the acc tile is the *full* oht (layout),
        # even when an edge tile only computes oht_c < oht rows.
        def build(b: UopBuilder):
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=0)
            b.loop_begin(oht_c * OW, dst_factor=1, src_factor=0)
            b.push(dst=acc_base, src=0)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build, key=f"cvrst.{shape}.{ocbt_c}.{oht_c}.{acc_base}")

    def alu_kernel(ocbt_c, oht_c, acc_base, src_base, s_fo, s_fi, tag) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=s_fo)
            b.loop_begin(oht_c * OW, dst_factor=1, src_factor=s_fi)
            b.push(dst=acc_base, src=src_base)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build, key=f"cvalu.{shape}.{tag}.{ocbt_c}.{oht_c}.{acc_base}.{src_base}.{s_fo}.{s_fi}")

    n_oh, n_oc, n_cb = _ceil_div(OH, oht), _ceil_div(OCb, ocbt), _ceil_div(Cb, cbt)
    fence_pending = [fenced]   # claimed by the first x load emitted

    def tile_program(coord, t):
        """Phase generator for one (nb, oh-tile, oc-tile); see
        scheduler.interleave_virtual_threads for the pairing argument."""
        nb, ot, jt = coord
        d = deps[t]
        oh0 = ot * oht
        oht_c = min(oht, OH - oh0)
        iht_c = (oht_c - 1) * S + KH
        ocb0 = jt * ocbt
        ocbt_c = min(ocbt, OCb - ocb0)
        acc_base = sram.acc_base + t * acc_ctx
        bias_sram = sram.acc_base + t * acc_ctx + oht * OW * ocbt
        inp_base0 = sram.inp_base + t * inp_ctx
        wgt_base0 = sram.wgt_base + t * wgt_ctx

        first = True
        for kt in range(n_cb):
            cb0 = kt * cbt
            cbt_c = min(cbt, Cb - cb0)
            # ---- load group ----
            d.begin_load_group(rt)
            h_start = oh0 * S - pad
            y_pad_0 = max(0, -h_start)
            y_pad_1 = max(0, h_start + iht_c - H)
            y_size = iht_c - y_pad_0 - y_pad_1

            def load_x(cb0=cb0, cbt_c=cbt_c, y_size=y_size,
                       y_pad_0=y_pad_0, y_pad_1=y_pad_1, h_start=h_start):
                for cb in range(cbt_c):
                    plane = x_base + ((nb * Cb + cb0 + cb) * H
                                      + (h_start + y_pad_0)) * W
                    rt.load_buffer_2d(
                        MemId.INP, inp_base0 + cb * iht * IWp,
                        plane, y_size=y_size, x_size=W, x_stride=W,
                        y_pad_0=y_pad_0, y_pad_1=y_pad_1,
                        x_pad_0=pad, x_pad_1=pad)

            def load_w(cb0=cb0, cbt_c=cbt_c):
                rt.load_buffer_2d(
                    MemId.WGT, wgt_base0,
                    w_base + ((ocb0 * Cb + cb0) * KH) * KW,
                    y_size=ocbt_c, x_size=cbt_c * KH * KW,
                    x_stride=Cb * KH * KW)

            emit_fenced_load_group(rt, fence_pending, load_x, load_w)
            d.end_load_group(rt)
            yield
            # ---- compute group ----
            d.begin_compute_group(rt, pops_acc=first)
            if first:
                rt.push_gemm(reset_kernel(ocbt_c, oht_c, acc_base),
                             reset=True)
                if b_base >= 0:
                    rt.load_buffer_2d(MemId.ACC, bias_sram,
                                      b_base + ocb0, y_size=1,
                                      x_size=ocbt_c, x_stride=OCb)
                first = False
            for oh_l in range(oht_c):
                rt.push_gemm(gemm_kernel(oh_l, cbt_c, ocbt_c,
                                         acc_base, inp_base0, wgt_base0))
            d.end_compute_group_frees_loads(rt)
            yield

        # ---- epilogue ----
        if b_base >= 0:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, bias_sram,
                                   1, 0, "bias"),
                        op=AluOp.ADD, use_imm=False)
        if ep.shift:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.SHR, imm=ep.shift)
        if ep.relu:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MAX, imm=0)
        if ep.clip_lo is not None:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MAX, imm=ep.clip_lo)
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MIN, imm=ep.clip_hi)
        # ---- store: one 2D store per output-channel block (own phase so
        # peer tiles are fully recorded at the group's first store) ----
        d.compute_to_store(rt, own_insn=ep.n_alu_passes > 0)
        yield
        d.begin_store(rt)
        for ocb in range(ocbt_c):
            rt.store_buffer_2d(
                acc_base + ocb * oht * OW,
                ((nb * OCb + ocb0 + ocb) * OH + oh0) * OW + y_base,
                y_size=oht_c, x_size=OW, x_stride=OW)
        d.end_store(rt)
        yield

    tiles = [(nb, ot, jt) for nb in range(Nb)
             for ot in range(n_oh) for jt in range(n_oc)]
    interleave_virtual_threads(tiles, vt, tile_program)
    return oht, ocbt, cbt


CONV_LOWERINGS = ("direct", "im2col", "via_matmul")


def conv1x1_eligible(shape: ConvShape, spec: HardwareSpec) -> bool:
    """Pointwise convs with unit stride map 1:1 onto the transposed-matmul
    lowering: a blocked NCHW plane is a K-major (channel-block, pixel)
    matrix whose elements carry the image block in the tensor-register
    rows, so batch-blocked template instances (``tpu_like()``) work the
    same way — one transposed matmul per image *block*."""
    return (shape.kh == 1 and shape.kw == 1 and shape.stride == 1
            and shape.pad == 0)


def conv_im2col_eligible(shape: ConvShape) -> bool:
    """The im2col gather loads one (icb, kh, kw) row of the K-major SRAM
    tile per 2D DMA; elements within a DMA row are contiguous, so the
    output-pixel axis must walk the image with unit stride."""
    return shape.stride == 1


def _ep_cost_sig(ep) -> Tuple:
    """What an epilogue costs in the timing replay: its ALU-pass set and
    whether a bias DMA happens — never the bias VALUES."""
    if ep is None:
        return ()
    return (ep.shift, ep.clip_lo, ep.clip_hi, ep.relu,
            ep.bias_blocked is not None)


_PREDICT_MEMO: dict = {}


def predict_conv_cycles(shape: ConvShape, spec: HardwareSpec, mode: str,
                        *, epilogue=None, virtual_threads: int = 2,
                        timing=None) -> int:
    """Replayed TimingModel cycles of ONE conv2d node lowered in `mode`.

    Emits the real lowering into a scratch runtime (synthetic base
    addresses — the replay prices DMA sizes and uop iteration counts,
    never the addresses) and replays it on the calibrated model.  This
    is the cycle oracle behind auto lowering selection and the
    autotuner; memoized per (mode, shape, spec, vt, epilogue-cost,
    timing), so a compile touches each distinct decision once.  Raises
    ValueError when `mode` cannot lower `shape` (e.g. SRAM too small)."""
    from .driver import Device
    from .simulator import TimingModel, replay_timing
    tm = timing or TimingModel(spec)
    key = (mode, shape, spec, virtual_threads, _ep_cost_sig(epilogue),
           type(tm).__name__, tm.spec)
    got = _PREDICT_MEMO.get(key)
    if got is not None:
        return got
    lower = {"direct": lower_conv2d, "im2col": lower_conv_im2col,
             "via_matmul": lower_conv1x1}[mode]
    rt = Runtime(spec, device=Device(dram_size=1 << 22))
    bias = 0 if (epilogue is not None
                 and epilogue.bias_blocked is not None) else -1
    lower(rt, x_base=0, w_base=0, y_base=0, shape=shape,
          epilogue=epilogue, bias_base=bias,
          virtual_threads=virtual_threads)
    cycles = replay_timing(spec, rt.stream, tm).total_cycles
    _PREDICT_MEMO[key] = cycles
    return cycles


def cheapest_conv_lowering(shape: ConvShape, spec: HardwareSpec, *,
                           candidates: Tuple[str, ...] = ("direct",
                                                          "im2col"),
                           epilogue=None, virtual_threads: int = 2,
                           timing=None) -> Tuple[str, dict]:
    """Cycle-compare candidate lowerings on the TimingModel: returns
    ``(winner, {mode: predicted_cycles})``.  Shape-ineligible or
    SRAM-infeasible modes are dropped (priced at None in the map); ties
    break toward the earlier candidate.  Raises if NO candidate can
    lower the shape."""
    cycles: dict = {}
    for mode in candidates:
        if mode == "im2col" and not conv_im2col_eligible(shape):
            cycles[mode] = None
            continue
        if mode == "via_matmul" and not conv1x1_eligible(shape, spec):
            cycles[mode] = None
            continue
        try:
            cycles[mode] = predict_conv_cycles(
                shape, spec, mode, epilogue=epilogue,
                virtual_threads=virtual_threads, timing=timing)
        except ValueError:
            cycles[mode] = None
    feasible = [(c, m) for m, c in cycles.items() if c is not None]
    if not feasible:
        raise ValueError(f"no candidate lowering in {candidates} can "
                         f"lower {shape} on this spec")
    return min(feasible)[1], cycles


def select_conv_lowering(shape: ConvShape, spec: HardwareSpec,
                         requested: Optional[str] = None, *,
                         epilogue=None, virtual_threads: int = 2,
                         timing=None) -> str:
    """Resolve (and validate) the lowering mode for one conv2d node.

    requested=None/"auto": pointwise unit-stride shapes take
    ``via_matmul`` (a structural 1:1 mapping, not a cost call); every
    other eligible shape is decided by REPLAYED CYCLES — ``direct`` vs
    ``im2col`` lowered into a scratch stream and priced on the
    TimingModel (:func:`cheapest_conv_lowering`), never by a hardcoded
    profitability rule.  An explicitly requested mode is validated
    against its shape constraints and raises a ValueError naming the
    legal alternatives — this is what makes bad graph configurations
    fail at build time instead of deep inside a lowering pass."""
    if requested in (None, "auto"):
        if conv1x1_eligible(shape, spec):
            return "via_matmul"
        if not conv_im2col_eligible(shape):
            return "direct"
        return cheapest_conv_lowering(
            shape, spec, epilogue=epilogue,
            virtual_threads=virtual_threads, timing=timing)[0]
    if requested == "via_matmul":
        if not conv1x1_eligible(shape, spec):
            raise ValueError(
                f"lowering='via_matmul' requires a pointwise unit-stride "
                f"conv (kh=kw=1, stride=1, pad=0); got kh={shape.kh} "
                f"kw={shape.kw} stride={shape.stride} pad={shape.pad}. "
                f"Use lowering='direct' (any shape) or 'im2col' (stride=1).")
        return requested
    if requested == "im2col":
        if not conv_im2col_eligible(shape):
            raise ValueError(
                f"lowering='im2col' requires stride=1 (the im2col gather "
                f"rows must be DMA-contiguous); got stride={shape.stride}. "
                f"Use lowering='direct'.")
        return requested
    if requested == "direct":
        return requested
    raise ValueError(f"unknown conv lowering {requested!r}; choose from "
                     f"{CONV_LOWERINGS} or None for auto")


def lower_conv1x1(rt: Runtime, *, x_base: int, w_base: int, y_base: int,
                  shape: ConvShape, epilogue: Optional[Epilogue] = None,
                  bias_base: int = -1, virtual_threads: int = 2,
                  sram: Optional[SramPartition] = None,
                  fenced: bool = False) -> None:
    """1x1-conv fast path: lower through the transposed GEMM schedule so
    these nodes hit the Pallas GEMM fast path (ResNet C3/C8/C11-style
    pointwise layers).  The blocked conv activation/weight/output buffers
    are consumed *in place* — no host-side im2col, no relayout.  For
    batch-blocked specs each image block is one transposed matmul whose
    tensor-register rows carry the images."""
    spec = rt.spec
    if not conv1x1_eligible(shape, spec):
        raise ValueError(f"{shape} is not 1x1-fast-path eligible")
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    HW = shape.h * shape.w
    Nb = _ceil_div(shape.n, spec.batch)
    for nb in range(Nb):
        if nb:
            # image blocks reuse the same SRAM partition: rendezvous first
            rt.join_barrier()
        lower_matmul(rt,
                     a_base=x_base + nb * Cb * HW,
                     w_base=w_base,
                     c_base=y_base + nb * OCb * HW,
                     Mb=HW, Nb=OCb, Kb=Cb,
                     epilogue=epilogue, bias_base=bias_base,
                     virtual_threads=virtual_threads, sram=sram,
                     transposed=True,
                     fenced=fenced and nb == 0)


def choose_im2col_tiles(shape: ConvShape, spec: HardwareSpec,
                        virtual_threads: int, bias: bool,
                        sram: Optional[SramPartition] = None
                        ) -> Tuple[int, int, int]:
    """(oht, ocbt, cbt) for the im2col schedule: the K-major SRAM tile is
    (cbt*KH*KW) x (oht*OW), so the inp footprint carries the kh*kw
    duplication the direct schedule avoids."""
    sram = sram or SramPartition.full(spec)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    OW = shape.ow
    inp_cap = sram.inp_depth // virtual_threads
    wgt_cap = sram.wgt_depth // virtual_threads
    acc_cap = sram.acc_depth // virtual_threads
    # affine dst factors must encode mtt = oht*OW (transposed-mode layout)
    max_factor = (1 << IsaLayout(spec).factor_bits) - 1

    def fits(oht, ocbt, cbt):
        mtt = oht * OW
        ktt = cbt * shape.kh * shape.kw
        a = mtt * ocbt + (ocbt if bias else 0)
        return (ktt * mtt <= inp_cap and ocbt * ktt <= wgt_cap
                and a <= acc_cap and mtt <= max_factor)

    if not fits(1, 1, 1):
        raise ValueError(
            f"im2col tile (1,1,1) does not fit SRAM for {shape} "
            f"(inp needs {shape.kh * shape.kw * OW} of {inp_cap}) — "
            f"use lowering='direct' or offload to CPU")
    oht, ocbt, cbt = 1, 1, 1
    changed = True
    while changed:
        changed = False
        for grow in ("cbt", "ocbt", "oht"):
            o2, c2, b2 = oht, ocbt, cbt
            if grow == "cbt" and cbt < Cb:
                b2 = min(Cb, cbt * 2)
            elif grow == "ocbt" and ocbt < OCb:
                c2 = min(OCb, ocbt * 2)
            elif grow == "oht" and oht < shape.oh:
                o2 = min(shape.oh, oht * 2)
            if (o2, c2, b2) != (oht, ocbt, cbt) and fits(o2, c2, b2):
                oht, ocbt, cbt = o2, c2, b2
                changed = True
    return oht, ocbt, cbt


def lower_conv_im2col(rt: Runtime, *, x_base: int, w_base: int, y_base: int,
                      shape: ConvShape, epilogue: Optional[Epilogue] = None,
                      bias_base: int = -1, virtual_threads: int = 2,
                      sram: Optional[SramPartition] = None,
                      fenced: bool = False) -> Tuple[int, int, int]:
    """im2col-in-SRAM lowering: gather the K-major im2col tile with one 2D
    padded DMA per (icb, kh, kw) row, then run ``lower_matmul``'s
    transposed-mode GEMM/epilogue/store structure over it — a single
    coalescable GEMM instruction per (k-chunk, tile) instead of the direct
    schedule's one-per-output-row.  Requires stride == 1 (gather rows must
    be DMA-contiguous); any kh/kw/pad.  Returns (oht, ocbt, cbt)."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    if (ep.bias_blocked is not None) != (bias_base >= 0):
        raise ValueError("epilogue.bias_blocked and bias_base must agree")
    if not conv_im2col_eligible(shape):
        raise ValueError(f"{shape} is not im2col-eligible (stride != 1)")
    sram = sram or SramPartition.full(spec)
    KH, KW, pad = shape.kh, shape.kw, shape.pad
    OH, OW = shape.oh, shape.ow
    H, W = shape.h, shape.w
    Nb = _ceil_div(shape.n, spec.batch)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    Kfull = Cb * KH * KW
    b_base = bias_base

    vt = virtual_threads
    oht, ocbt, cbt = choose_im2col_tiles(shape, spec, vt,
                                         ep.bias_blocked is not None,
                                         sram=sram)
    inp_ctx = sram.inp_depth // vt
    wgt_ctx = sram.wgt_depth // vt
    acc_ctx = sram.acc_depth // vt
    deps = [_ThreadDeps() for _ in range(vt)]

    # transposed-mode micro-kernels (lower_matmul's K-major structure):
    # acc tile is N-major over pixels, dst = acc_base + m + n*mtt
    def gemm_kernel(mtt, ntt, ktt, acc_base, inp_base, wgt_base) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(mtt, dst_factor=1, src_factor=1, wgt_factor=0)
            b.loop_begin(ntt, dst_factor=mtt, src_factor=0, wgt_factor=ktt)
            for k in range(ktt):
                b.push(dst=acc_base, src=inp_base + k * mtt, wgt=wgt_base + k)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build,
            key=f"i2c.{shape}.{mtt}.{ntt}.{ktt}.{acc_base}.{inp_base}.{wgt_base}")

    def reset_kernel(mtt, ntt, acc_base) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(mtt, dst_factor=1, src_factor=0)
            b.loop_begin(ntt, dst_factor=mtt, src_factor=0)
            b.push(dst=acc_base, src=0)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build, key=f"i2crst.{shape}.{mtt}.{ntt}.{acc_base}")

    def alu_kernel(mtt, ntt, acc_base, src_base, s_fo, s_fi, tag) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(mtt, dst_factor=1, src_factor=s_fo)
            b.loop_begin(ntt, dst_factor=mtt, src_factor=s_fi)
            b.push(dst=acc_base, src=src_base)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build,
            key=f"i2calu.{shape}.{tag}.{mtt}.{ntt}.{acc_base}.{src_base}.{s_fo}.{s_fi}")

    n_oh, n_oc, n_cb = _ceil_div(OH, oht), _ceil_div(OCb, ocbt), \
        _ceil_div(Cb, cbt)
    fence_pending = [fenced]   # claimed by the first gather load emitted

    def tile_program(coord, t):
        nb, ot, jt = coord
        d = deps[t]
        oh0 = ot * oht
        oht_c = min(oht, OH - oh0)
        mtt = oht_c * OW
        ocb0 = jt * ocbt
        ocbt_c = min(ocbt, OCb - ocb0)
        acc_base = sram.acc_base + t * acc_ctx
        bias_sram = sram.acc_base + t * acc_ctx + oht * OW * ocbt
        inp_base0 = sram.inp_base + t * inp_ctx
        wgt_base0 = sram.wgt_base + t * wgt_ctx

        first = True
        for kt in range(n_cb):
            cb0 = kt * cbt
            cbt_c = min(cbt, Cb - cb0)
            ktt = cbt_c * KH * KW
            # ---- load group: the im2col gather (one DMA per k-row) ----
            d.begin_load_group(rt)

            def load_x(cb0=cb0, cbt_c=cbt_c, oht_c=oht_c, mtt=mtt, oh0=oh0):
                for cb in range(cbt_c):
                    plane = x_base + (nb * Cb + cb0 + cb) * H * W
                    for kh in range(KH):
                        row0 = oh0 + kh - pad       # stride==1: oh walks h
                        y_pad_0 = min(oht_c, max(0, -row0))
                        y_pad_1 = min(oht_c - y_pad_0,
                                      max(0, row0 + oht_c - H))
                        y_size = oht_c - y_pad_0 - y_pad_1
                        for kw in range(KW):
                            col0 = kw - pad
                            x_pad_0 = min(OW, max(0, -col0))
                            x_pad_1 = min(OW - x_pad_0,
                                          max(0, col0 + OW - W))
                            k_local = (cb * KH + kh) * KW + kw
                            rt.load_buffer_2d(
                                MemId.INP, inp_base0 + k_local * mtt,
                                plane + (row0 + y_pad_0) * W
                                + (col0 + x_pad_0),
                                y_size=y_size,
                                x_size=OW - x_pad_0 - x_pad_1, x_stride=W,
                                y_pad_0=y_pad_0, y_pad_1=y_pad_1,
                                x_pad_0=x_pad_0, x_pad_1=x_pad_1)

            def load_w(cb0=cb0, ktt=ktt):
                rt.load_buffer_2d(
                    MemId.WGT, wgt_base0,
                    w_base + ocb0 * Kfull + cb0 * KH * KW,
                    y_size=ocbt_c, x_size=ktt, x_stride=Kfull)

            emit_fenced_load_group(rt, fence_pending, load_x, load_w)
            d.end_load_group(rt)
            yield
            # ---- compute group ----
            d.begin_compute_group(rt, pops_acc=first)
            if first:
                rt.push_gemm(reset_kernel(mtt, ocbt_c, acc_base), reset=True)
                if b_base >= 0:
                    rt.load_buffer_2d(MemId.ACC, bias_sram, b_base + ocb0,
                                      y_size=1, x_size=ocbt_c, x_stride=OCb)
                first = False
            rt.push_gemm(gemm_kernel(mtt, ocbt_c, ktt, acc_base,
                                     inp_base0, wgt_base0))
            d.end_compute_group_frees_loads(rt)
            yield

        # ---- epilogue (transposed-mode source factors) ----
        if b_base >= 0:
            rt.push_alu(alu_kernel(mtt, ocbt_c, acc_base, bias_sram,
                                   0, 1, "bias"),
                        op=AluOp.ADD, use_imm=False)
        if ep.shift:
            rt.push_alu(alu_kernel(mtt, ocbt_c, acc_base, acc_base,
                                   1, mtt, "self"),
                        op=AluOp.SHR, imm=ep.shift)
        clip_lo = ep.folded_clip_lo
        if ep.relu and clip_lo is None:
            rt.push_alu(alu_kernel(mtt, ocbt_c, acc_base, acc_base,
                                   1, mtt, "self"),
                        op=AluOp.MAX, imm=0)
        if clip_lo is not None:
            rt.push_alu(alu_kernel(mtt, ocbt_c, acc_base, acc_base,
                                   1, mtt, "self"),
                        op=AluOp.MAX, imm=clip_lo)
            rt.push_alu(alu_kernel(mtt, ocbt_c, acc_base, acc_base,
                                   1, mtt, "self"),
                        op=AluOp.MIN, imm=ep.clip_hi)
        # ---- store: one 2D store, rows = output-channel blocks (own
        # phase so peer tiles are fully recorded at the group's store) ----
        d.compute_to_store(rt, own_insn=ep.n_alu_passes > 0)
        yield
        d.begin_store(rt)
        rt.store_buffer_2d(
            acc_base,
            (nb * OCb + ocb0) * OH * OW + oh0 * OW + y_base,
            y_size=ocbt_c, x_size=mtt, x_stride=OH * OW)
        d.end_store(rt)
        yield

    tiles = [(nb, ot, jt) for nb in range(Nb)
             for ot in range(n_oh) for jt in range(n_oc)]
    interleave_virtual_threads(tiles, vt, tile_program)
    return oht, ocbt, cbt


def schedule_conv2d(rt: Runtime, x: np.ndarray, w: np.ndarray,
                    shape: ConvShape, epilogue: Optional[Epilogue] = None,
                    virtual_threads: int = 2,
                    sram: Optional[SramPartition] = None,
                    via_matmul: bool = False,
                    lowering: Optional[str] = None) -> ConvPlan:
    """Lower y = conv2d(x, w) (+epilogue) onto VTA: stages the blocked
    operands in DRAM and delegates stream emission to the lowering pass
    picked by ``lowering`` ("direct" | "im2col" | "via_matmul"; validated
    by ``select_conv_lowering``).  ``via_matmul=True`` is the back-compat
    spelling of lowering="via_matmul" that silently degrades to "direct"
    for ineligible shapes."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    assert x.shape == (shape.n, shape.ic, shape.h, shape.w)
    assert w.shape == (shape.oc, shape.ic, shape.kh, shape.kw)
    if lowering is not None:
        mode = select_conv_lowering(shape, spec, lowering)
    elif via_matmul and conv1x1_eligible(shape, spec):
        mode = "via_matmul"
    else:
        mode = "direct"

    xb = layout.pack_conv_inp(x, spec)
    wb = layout.pack_conv_wgt(w, spec)
    Nb, Cb = xb.shape[0], xb.shape[1]
    OCb = wb.shape[0]
    x_addr = rt.copy_to_device(xb, align=spec.inp_elem_bytes)
    w_addr = rt.copy_to_device(wb, align=spec.wgt_elem_bytes)
    y_addr = rt.buffer_alloc(Nb * OCb * shape.oh * shape.ow
                             * spec.out_elem_bytes,
                             align=spec.out_elem_bytes)
    b_base = -1
    if ep.bias_blocked is not None:
        b_addr = rt.copy_to_device(
            np.ascontiguousarray(ep.bias_blocked, np.int32),
            align=spec.acc_elem_bytes)
        b_base = rt.to_elem_addr(b_addr, MemId.ACC)

    kw = dict(x_base=rt.to_elem_addr(x_addr, MemId.INP),
              w_base=rt.to_elem_addr(w_addr, MemId.WGT),
              y_base=rt.to_elem_addr(y_addr, MemId.OUT),
              shape=shape, epilogue=ep, bias_base=b_base,
              virtual_threads=virtual_threads, sram=sram)
    if mode == "via_matmul":
        lower_conv1x1(rt, **kw)
        tiles = (0, 0, 0)   # GEMM-path tiling; not a conv (oht, ocbt, cbt)
    elif mode == "im2col":
        tiles = lower_conv_im2col(rt, **kw)
    else:
        tiles = lower_conv2d(rt, **kw)
    return ConvPlan(shape=shape, tiles=tiles, x_addr=x_addr,
                    w_addr=w_addr, y_addr=y_addr, Nb=Nb, Cb=Cb, OCb=OCb,
                    mode=mode)


def read_conv_result(rt: Runtime, plan: ConvPlan) -> np.ndarray:
    spec = rt.spec
    s = plan.shape
    blocked = rt.copy_from_device(
        plan.y_addr,
        plan.Nb * plan.OCb * s.oh * s.ow * spec.out_elem_bytes, np.int8,
        (plan.Nb, plan.OCb, s.oh, s.ow, spec.batch, spec.block_out))
    return layout.unpack_conv_out(blocked, s.n, s.oc, s.oh, s.ow, spec)


def conv2d_reference(x: np.ndarray, w: np.ndarray, shape: ConvShape,
                     epilogue: Optional[Epilogue] = None,
                     spec: Optional[HardwareSpec] = None) -> np.ndarray:
    """Pure-numpy integer oracle."""
    ep = epilogue or Epilogue()
    S, KH, KW, pad = shape.stride, shape.kh, shape.kw, shape.pad
    xp = np.pad(x.astype(np.int64),
                ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    OH, OW = shape.oh, shape.ow
    acc = np.zeros((shape.n, shape.oc, OH, OW), np.int64)
    for kh in range(KH):
        for kw in range(KW):
            xs = xp[:, :, kh:kh + OH * S:S, kw:kw + OW * S:S]
            acc += np.einsum("nchw,oc->nohw", xs, w[:, :, kh, kw].astype(np.int64))
    if ep.bias_blocked is not None:
        flat = ep.bias_blocked[:, 0, :].reshape(-1)[:shape.oc]
        acc += flat.astype(np.int64)[None, :, None, None]
    if ep.shift:
        acc = acc >> ep.shift
    if ep.relu:
        acc = np.maximum(acc, 0)
    if ep.clip_lo is not None:
        acc = np.clip(acc, ep.clip_lo, ep.clip_hi)
    return acc.astype(np.int32).astype(np.int8)
