"""Direct 2D convolution lowered onto VTA (§2.6, Fig. 9, §4.2).

Tensorizes NCHW conv2d onto the GEMM intrinsic *without* host-side im2col:
the load module's 2D strided DMA inserts spatial zero-padding on the fly,
and the micro-op kernel's 2-level affine loop walks (kh, kw, icb) — the
access-pattern compression the paper describes in §2.5.

SRAM layouts per virtual-thread context:
  inp  tile: (cbt, iht, IWp)    idx = (cb*iht + ih)*IWp + iw
  wgt  tile: (ocbt, cbt*KH*KW)  idx = ocb*cbt*KH*KW + (cb*KH+kh)*KW + kw
  acc  tile: (ocbt, oht, OW)    idx = (ocb*oht + oh)*OW + ow     (+ bias slot)

One GEMM instruction per output row `oh_l`:
  i0 = ow   (extent OW,   dst*1,        src*S,  wgt*0)
  i1 = ocb  (extent ocbt, dst*oht*OW,   src*0,  wgt*cbt*KH*KW)
  uops enumerate (cb, kh, kw).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from . import layout
from .hwspec import HardwareSpec
from .isa import AluOp, MemId
from .runtime import Runtime, UopBuilder, UopKernel
from .scheduler import (Epilogue, SramPartition, _ceil_div, _ThreadDeps,
                        interleave_virtual_threads, lower_matmul)


@dataclass(frozen=True)
class ConvShape:
    """One conv2d workload (Table 1 row)."""
    n: int
    h: int
    w: int
    ic: int
    oc: int
    kh: int
    kw: int
    stride: int
    pad: int

    @property
    def oh(self) -> int:
        return (self.h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def ow(self) -> int:
        return (self.w + 2 * self.pad - self.kw) // self.stride + 1

    @property
    def macs(self) -> int:
        return self.n * self.oc * self.oh * self.ow * self.ic * self.kh * self.kw

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / 1e9

    def dram_bytes(self, spec: HardwareSpec) -> int:
        """Minimum DRAM traffic (one pass over each tensor, int8/int32)."""
        inp = self.n * self.ic * self.h * self.w
        wgt = self.oc * self.ic * self.kh * self.kw
        out = self.n * self.oc * self.oh * self.ow
        return inp + wgt + out

    @property
    def arithmetic_intensity(self) -> float:
        return 2.0 * self.macs / self.dram_bytes(HardwareSpec())


@dataclass
class ConvPlan:
    shape: ConvShape
    tiles: Tuple[int, int, int]      # (oht, ocbt, cbt)
    x_addr: int
    w_addr: int
    y_addr: int
    Nb: int
    Cb: int
    OCb: int


def choose_conv_tiles(shape: ConvShape, spec: HardwareSpec,
                      virtual_threads: int, bias: bool,
                      sram: Optional[SramPartition] = None
                      ) -> Tuple[int, int, int]:
    sram = sram or SramPartition.full(spec)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    IWp = shape.w + 2 * shape.pad
    inp_cap = sram.inp_depth // virtual_threads
    wgt_cap = sram.wgt_depth // virtual_threads
    acc_cap = sram.acc_depth // virtual_threads

    def fits(oht, ocbt, cbt):
        iht = (oht - 1) * shape.stride + shape.kh
        a = oht * shape.ow * ocbt + (ocbt if bias else 0)
        return (cbt * iht * IWp <= inp_cap
                and ocbt * cbt * shape.kh * shape.kw <= wgt_cap
                and a <= acc_cap)

    oht, ocbt, cbt = 1, 1, 1
    if not fits(1, 1, 1):
        raise ValueError(
            f"conv tile (1,1,1) does not fit SRAM for {shape} "
            f"(inp needs {shape.kh * IWp} of {inp_cap}) — offload to CPU")
    changed = True
    while changed:
        changed = False
        for grow in ("cbt", "ocbt", "oht"):
            o2, c2, b2 = oht, ocbt, cbt
            if grow == "cbt" and cbt < Cb:
                b2 = min(Cb, cbt * 2)
            elif grow == "ocbt" and ocbt < OCb:
                c2 = min(OCb, ocbt * 2)
            elif grow == "oht" and oht < shape.oh:
                o2 = min(shape.oh, oht * 2)
            if (o2, c2, b2) != (oht, ocbt, cbt) and fits(o2, c2, b2):
                oht, ocbt, cbt = o2, c2, b2
                changed = True
    return oht, ocbt, cbt


def lower_conv2d(rt: Runtime, *, x_base: int, w_base: int, y_base: int,
                 shape: ConvShape, epilogue: Optional[Epilogue] = None,
                 bias_base: int = -1, virtual_threads: int = 2,
                 sram: Optional[SramPartition] = None) -> Tuple[int, int, int]:
    """Emit the direct-conv schedule into rt's open stream (element
    addresses of already-staged blocked buffers, like ``lower_matmul``).
    Returns the chosen (oht, ocbt, cbt) tiles."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    if (ep.bias_blocked is not None) != (bias_base >= 0):
        raise ValueError("epilogue.bias_blocked and bias_base must agree")
    sram = sram or SramPartition.full(spec)
    S, KH, KW, pad = shape.stride, shape.kh, shape.kw, shape.pad
    OH, OW = shape.oh, shape.ow
    IWp = shape.w + 2 * pad
    H, W = shape.h, shape.w
    Nb = _ceil_div(shape.n, spec.batch)
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    b_base = bias_base

    vt = virtual_threads
    oht, ocbt, cbt = choose_conv_tiles(shape, spec, vt,
                                       ep.bias_blocked is not None, sram=sram)
    iht = (oht - 1) * S + KH
    inp_ctx = sram.inp_depth // vt
    wgt_ctx = sram.wgt_depth // vt
    acc_ctx = sram.acc_depth // vt
    deps = [_ThreadDeps() for _ in range(vt)]

    def gemm_kernel(oh_l, cbt_c, ocbt_c, acc_base, inp_base, wgt_base) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(OW, dst_factor=1, src_factor=S, wgt_factor=0)
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=0,
                         wgt_factor=cbt_c * KH * KW)
            for cb in range(cbt_c):
                for kh in range(KH):
                    for kw in range(KW):
                        b.push(dst=acc_base + oh_l * OW,
                               src=inp_base + (cb * iht + oh_l * S + kh) * IWp + kw,
                               wgt=wgt_base + (cb * KH + kh) * KW + kw)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build, key=f"cv.{shape}.{oh_l}.{cbt_c}.{ocbt_c}.{acc_base}.{inp_base}.{wgt_base}")

    def reset_kernel(ocbt_c, oht_c, acc_base) -> UopKernel:
        # note: the ocb stride in the acc tile is the *full* oht (layout),
        # even when an edge tile only computes oht_c < oht rows.
        def build(b: UopBuilder):
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=0)
            b.loop_begin(oht_c * OW, dst_factor=1, src_factor=0)
            b.push(dst=acc_base, src=0)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(build, key=f"cvrst.{shape}.{ocbt_c}.{oht_c}.{acc_base}")

    def alu_kernel(ocbt_c, oht_c, acc_base, src_base, s_fo, s_fi, tag) -> UopKernel:
        def build(b: UopBuilder):
            b.loop_begin(ocbt_c, dst_factor=oht * OW, src_factor=s_fo)
            b.loop_begin(oht_c * OW, dst_factor=1, src_factor=s_fi)
            b.push(dst=acc_base, src=src_base)
            b.loop_end(); b.loop_end()
        return rt.uop_kernel(
            build, key=f"cvalu.{shape}.{tag}.{ocbt_c}.{oht_c}.{acc_base}.{src_base}.{s_fo}.{s_fi}")

    n_oh, n_oc, n_cb = _ceil_div(OH, oht), _ceil_div(OCb, ocbt), _ceil_div(Cb, cbt)

    def tile_program(coord, t):
        """Phase generator for one (nb, oh-tile, oc-tile); see
        scheduler.interleave_virtual_threads for the pairing argument."""
        nb, ot, jt = coord
        d = deps[t]
        oh0 = ot * oht
        oht_c = min(oht, OH - oh0)
        iht_c = (oht_c - 1) * S + KH
        ocb0 = jt * ocbt
        ocbt_c = min(ocbt, OCb - ocb0)
        acc_base = sram.acc_base + t * acc_ctx
        bias_sram = sram.acc_base + t * acc_ctx + oht * OW * ocbt
        inp_base0 = sram.inp_base + t * inp_ctx
        wgt_base0 = sram.wgt_base + t * wgt_ctx

        first = True
        for kt in range(n_cb):
            cb0 = kt * cbt
            cbt_c = min(cbt, Cb - cb0)
            # ---- load group ----
            d.begin_load_group(rt)
            h_start = oh0 * S - pad
            y_pad_0 = max(0, -h_start)
            y_pad_1 = max(0, h_start + iht_c - H)
            y_size = iht_c - y_pad_0 - y_pad_1
            for cb in range(cbt_c):
                plane = x_base + ((nb * Cb + cb0 + cb) * H
                                  + (h_start + y_pad_0)) * W
                rt.load_buffer_2d(
                    MemId.INP, inp_base0 + cb * iht * IWp,
                    plane, y_size=y_size, x_size=W, x_stride=W,
                    y_pad_0=y_pad_0, y_pad_1=y_pad_1,
                    x_pad_0=pad, x_pad_1=pad)
            rt.load_buffer_2d(
                MemId.WGT, wgt_base0,
                w_base + ((ocb0 * Cb + cb0) * KH) * KW,
                y_size=ocbt_c, x_size=cbt_c * KH * KW,
                x_stride=Cb * KH * KW)
            d.end_load_group(rt)
            yield
            # ---- compute group ----
            d.begin_compute_group(rt, pops_acc=first)
            if first:
                rt.push_gemm(reset_kernel(ocbt_c, oht_c, acc_base),
                             reset=True)
                if b_base >= 0:
                    rt.load_buffer_2d(MemId.ACC, bias_sram,
                                      b_base + ocb0, y_size=1,
                                      x_size=ocbt_c, x_stride=OCb)
                first = False
            for oh_l in range(oht_c):
                rt.push_gemm(gemm_kernel(oh_l, cbt_c, ocbt_c,
                                         acc_base, inp_base0, wgt_base0))
            d.end_compute_group_frees_loads(rt)
            yield

        # ---- epilogue ----
        if b_base >= 0:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, bias_sram,
                                   1, 0, "bias"),
                        op=AluOp.ADD, use_imm=False)
        if ep.shift:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.SHR, imm=ep.shift)
        if ep.relu:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MAX, imm=0)
        if ep.clip_lo is not None:
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MAX, imm=ep.clip_lo)
            rt.push_alu(alu_kernel(ocbt_c, oht_c, acc_base, acc_base,
                                   oht * OW, 1, "self"),
                        op=AluOp.MIN, imm=ep.clip_hi)
        # ---- store: one 2D store per output-channel block ----
        d.compute_to_store(rt)
        d.begin_store(rt)
        for ocb in range(ocbt_c):
            rt.store_buffer_2d(
                acc_base + ocb * oht * OW,
                ((nb * OCb + ocb0 + ocb) * OH + oh0) * OW + y_base,
                y_size=oht_c, x_size=OW, x_stride=OW)
        d.end_store(rt)
        yield

    tiles = [(nb, ot, jt) for nb in range(Nb)
             for ot in range(n_oh) for jt in range(n_oc)]
    interleave_virtual_threads(tiles, vt, tile_program)
    return oht, ocbt, cbt


def conv1x1_eligible(shape: ConvShape, spec: HardwareSpec) -> bool:
    """Pointwise convs with unit stride map 1:1 onto the transposed-matmul
    lowering (a blocked NCHW plane is a K-major (channel-block, pixel)
    matrix).  batch > 1 template instances block the image dim into the
    GEMM batch rows, which breaks the pixel-major mapping."""
    return (shape.kh == 1 and shape.kw == 1 and shape.stride == 1
            and shape.pad == 0 and spec.batch == 1)


def lower_conv1x1(rt: Runtime, *, x_base: int, w_base: int, y_base: int,
                  shape: ConvShape, epilogue: Optional[Epilogue] = None,
                  bias_base: int = -1, virtual_threads: int = 2,
                  sram: Optional[SramPartition] = None) -> None:
    """1x1-conv fast path: lower through the transposed GEMM schedule so
    these nodes hit the Pallas GEMM fast path (ResNet C3/C8/C11-style
    pointwise layers).  The blocked conv activation/weight/output buffers
    are consumed *in place* — no host-side im2col, no relayout."""
    spec = rt.spec
    if not conv1x1_eligible(shape, spec):
        raise ValueError(f"{shape} is not 1x1-fast-path eligible")
    Cb = _ceil_div(shape.ic, spec.block_in)
    OCb = _ceil_div(shape.oc, spec.block_out)
    HW = shape.h * shape.w
    for nb in range(shape.n):          # batch == 1 => Nb == n image planes
        if nb:
            # image planes reuse the same SRAM partition: rendezvous first
            rt.join_barrier()
        lower_matmul(rt,
                     a_base=x_base + nb * Cb * HW,
                     w_base=w_base,
                     c_base=y_base + nb * OCb * HW,
                     Mb=HW, Nb=OCb, Kb=Cb,
                     epilogue=epilogue, bias_base=bias_base,
                     virtual_threads=virtual_threads, sram=sram,
                     transposed=True)


def schedule_conv2d(rt: Runtime, x: np.ndarray, w: np.ndarray,
                    shape: ConvShape, epilogue: Optional[Epilogue] = None,
                    virtual_threads: int = 2,
                    sram: Optional[SramPartition] = None,
                    via_matmul: bool = False) -> ConvPlan:
    """Lower y = conv2d(x, w) (+epilogue) onto VTA.  Thin wrapper over
    ``lower_conv2d`` (or ``lower_conv1x1`` when ``via_matmul`` and the
    shape is pointwise-eligible): stages the blocked operands in DRAM and
    delegates stream emission to the lowering pass."""
    spec = rt.spec
    ep = epilogue or Epilogue()
    assert x.shape == (shape.n, shape.ic, shape.h, shape.w)
    assert w.shape == (shape.oc, shape.ic, shape.kh, shape.kw)

    xb = layout.pack_conv_inp(x, spec)
    wb = layout.pack_conv_wgt(w, spec)
    Nb, Cb = xb.shape[0], xb.shape[1]
    OCb = wb.shape[0]
    x_addr = rt.copy_to_device(xb, align=spec.inp_elem_bytes)
    w_addr = rt.copy_to_device(wb, align=spec.wgt_elem_bytes)
    y_addr = rt.buffer_alloc(Nb * OCb * shape.oh * shape.ow
                             * spec.out_elem_bytes,
                             align=spec.out_elem_bytes)
    b_base = -1
    if ep.bias_blocked is not None:
        b_addr = rt.copy_to_device(
            np.ascontiguousarray(ep.bias_blocked, np.int32),
            align=spec.acc_elem_bytes)
        b_base = rt.to_elem_addr(b_addr, MemId.ACC)

    kw = dict(x_base=rt.to_elem_addr(x_addr, MemId.INP),
              w_base=rt.to_elem_addr(w_addr, MemId.WGT),
              y_base=rt.to_elem_addr(y_addr, MemId.OUT),
              shape=shape, epilogue=ep, bias_base=b_base,
              virtual_threads=virtual_threads, sram=sram)
    if via_matmul and conv1x1_eligible(shape, spec):
        lower_conv1x1(rt, **kw)
        tiles = (0, 0, 0)   # GEMM-path tiling; not a conv (oht, ocbt, cbt)
    else:
        tiles = lower_conv2d(rt, **kw)
    return ConvPlan(shape=shape, tiles=tiles, x_addr=x_addr,
                    w_addr=w_addr, y_addr=y_addr, Nb=Nb, Cb=Cb, OCb=OCb)


def read_conv_result(rt: Runtime, plan: ConvPlan) -> np.ndarray:
    spec = rt.spec
    s = plan.shape
    blocked = rt.copy_from_device(
        plan.y_addr,
        plan.Nb * plan.OCb * s.oh * s.ow * spec.out_elem_bytes, np.int8,
        (plan.Nb, plan.OCb, s.oh, s.ow, spec.batch, spec.block_out))
    return layout.unpack_conv_out(blocked, s.n, s.oc, s.oh, s.ow, spec)


def conv2d_reference(x: np.ndarray, w: np.ndarray, shape: ConvShape,
                     epilogue: Optional[Epilogue] = None,
                     spec: Optional[HardwareSpec] = None) -> np.ndarray:
    """Pure-numpy integer oracle."""
    ep = epilogue or Epilogue()
    S, KH, KW, pad = shape.stride, shape.kh, shape.kw, shape.pad
    xp = np.pad(x.astype(np.int64),
                ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    OH, OW = shape.oh, shape.ow
    acc = np.zeros((shape.n, shape.oc, OH, OW), np.int64)
    for kh in range(KH):
        for kw in range(KW):
            xs = xp[:, :, kh:kh + OH * S:S, kw:kw + OW * S:S]
            acc += np.einsum("nchw,oc->nohw", xs, w[:, :, kh, kw].astype(np.int64))
    if ep.bias_blocked is not None:
        flat = ep.bias_blocked[:, 0, :].reshape(-1)[:shape.oc]
        acc += flat.astype(np.int64)[None, :, None, None]
    if ep.shift:
        acc = acc >> ep.shift
    if ep.relu:
        acc = np.maximum(acc, 0)
    if ep.clip_lo is not None:
        acc = np.clip(acc, ep.clip_lo, ep.clip_hi)
    return acc.astype(np.int32).astype(np.int8)
